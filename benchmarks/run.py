import os
import sys
# The comm/memory/throughput benches analyse the production meshes, which
# requires the 512-device host platform BEFORE jax initializes. This is
# deliberate and local to this entrypoint (smoke tests see 1 device).
# --smoke uses an 8-device toy mesh instead so CI finishes in minutes.
_N_DEV = 8 if "--smoke" in sys.argv else 512
os.environ.setdefault("XLA_FLAGS",
                      f"--xla_force_host_platform_device_count={_N_DEV}")

"""Benchmark driver -- one workload per paper table/figure.

  paper artifact            -> workload
  Table VII (comm volume)   -> comm_volume
  Tables V/VI (max batch)   -> max_batch
  Fig. 5/6 (throughput)     -> throughput_model
  Fig. 9 (bw sensitivity)   -> bw_sensitivity
  SS III-B (memory)         -> memory
  kernels (substrate)       -> kernels

The axis bodies, timed arms, and artifact schemas live in
``benchmarks/harness/`` (workloads / execution / results); this file
only selects the workload list, drives each axis, and reports.

``--smoke`` runs the reduced toy-mesh matrix (one axis per subsystem,
every analytic acceptance assertion); add ``--timed`` to ALSO measure
warmed-up wall-clock step times (median/p90 over N fenced steps) for
the declared arms of each axis.  Every invocation writes a timestamped
run dir ``results/runs/<stamp>/`` (manifest.json + one schema-validated
artifact per axis) -- the unit ``benchmarks/compare.py`` diffs against
``results/baseline/`` -- plus the flat ``results/bench_smoke_*.json``
files older consumers glob.

Prints ``name,us_per_call,derived`` CSV per the repo convention.
"""
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:
    sys.path.insert(0, str(_ROOT))

import argparse
import json
import time
import traceback

from benchmarks.harness import execution, results, workloads

RESULTS = results.RESULTS


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI path: kernel oracles + toy-mesh comm "
                         "schema check + mixed-mode dry-run + cross-step "
                         "on/off axis + crash-resume parity")
    ap.add_argument("--timed", action="store_true",
                    help="also measure wall-clock step times for each "
                         "axis's declared arms (warmup excluded, "
                         "block_until_ready fenced, median/p90)")
    ap.add_argument("--warmup-steps", type=int, default=2,
                    help="steps run before the timed region per arm")
    ap.add_argument("--timed-steps", type=int, default=5,
                    help="individually timed steps per arm")
    ap.add_argument("--axis", action="append", default=[],
                    help="run only the named axes (repeatable)")
    ap.add_argument("--mode-override", action="append", default=[],
                    metavar="GLOB=MODE",
                    help="per-tensor strategy override applied on top of "
                         "every bench cell's mode (repeatable) -- compare "
                         "mixed layouts against the pure-mode tables")
    args = ap.parse_args(argv)
    mode_overrides = ()
    if args.mode_override:
        from repro.core.strategy import parse_mode_override
        mode_overrides = tuple(parse_mode_override(s)
                               for s in args.mode_override)

    wl = (workloads.SMOKE_WORKLOADS if args.smoke
          else workloads.FULL_WORKLOADS)
    if args.axis:
        unknown = set(args.axis) - {w.name for w in wl}
        if unknown:
            ap.error(f"unknown axes {sorted(unknown)}; known: "
                     f"{[w.name for w in wl]}")
        wl = tuple(w for w in wl if w.name in args.axis)

    ctx = execution.RunContext(
        mode_overrides=mode_overrides, timed=args.timed,
        timing=execution.TimingSpec(warmup_steps=args.warmup_steps,
                                    timed_steps=args.timed_steps))
    RESULTS.mkdir(exist_ok=True)
    rd = results.RunDir.create(smoke=args.smoke, timed=args.timed)
    all_out = {}
    failures = 0
    for w in wl:
        t0 = time.time()
        try:
            doc = execution.run_workload(w, ctx)
            flat = RESULTS / w.flat if w.flat else None
            rd.write_axis(doc, flat_path=flat)
            all_out[w.name] = doc
            status = "ok"
        except Exception as e:
            traceback.print_exc()
            all_out[w.name] = {"error": str(e)}
            rd.record_failure(w.name, str(e))
            status = "FAILED"
            failures += 1
        print(f"# bench {w.name}: {status} ({time.time()-t0:.0f}s)")
    rd.finalize()
    out_name = "bench_smoke.json" if args.smoke else "bench_results.json"
    with open(RESULTS / out_name, "w") as f:
        json.dump(all_out, f, indent=2, default=float)
    print(f"# run dir: {rd.path}")
    print("name,us_per_call,derived")
    for name, us, derived in ctx.rows:
        print(f"{name},{us:.1f},{derived:.6g}")
    if args.smoke and failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
