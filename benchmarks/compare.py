"""Regression gate over benchmark runs: diff a new timestamped run dir
(``results/runs/<stamp>/``) against the committed ``results/baseline/``
and fail on any metric that regresses beyond its noise band.

  PYTHONPATH=src python -m benchmarks.compare                    # latest run
  PYTHONPATH=src python -m benchmarks.compare results/runs/<stamp>
  PYTHONPATH=src python -m benchmarks.compare --refresh-baseline

Per metric, regression is direction-aware and relative:

  lower-is-better :  new > base * (1 + band) + eps
  higher-is-better:  new < base * (1 - band) - eps

with the noise band taken from the NEW run's artifact (the tree under
test declares its tolerances -- band changes are reviewed as part of
the PR diff, and a band of 0.0 demands bit-stable equality).  Analytic
metrics are deterministic re-derivations, so their default band is
tight; wall-clock (timed) metrics carry wide bands because CI machines
differ.  See ARCHITECTURE.md "Benchmark harness" for the baseline
refresh procedure.

The gate is strict about bookkeeping, with readable errors:
  * an axis or metric present in baseline but missing from the new run
    fails (a silently dropped assertion looks exactly like this);
  * a schema_version mismatch on either side fails with instructions
    to regenerate (``results.validate`` raises it);
  * new axes/metrics that have no baseline counterpart are reported
    but do not gate -- they start gating once the baseline is
    refreshed.
"""
from __future__ import annotations

import argparse
import shutil
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:
    sys.path.insert(0, str(_ROOT))

from benchmarks.harness import results
from benchmarks.harness.results import SchemaError, metrics_of

EPS = 1e-12


def compare_metric(base, new):
    """(status, rel_change) for one baseline/new Metric pair.

    status: 'ok' | 'improved' | 'REGRESSED'.  rel_change is signed,
    positive = got worse, in units of the baseline value."""
    band = new.resolved_band()
    if base.value == 0:
        rel = float("inf") if new.value != 0 else 0.0
    else:
        rel = (new.value - base.value) / abs(base.value)
    if new.direction == "lower":
        worse = rel
        regressed = new.value > base.value * (1 + band) + EPS
    else:
        worse = -rel
        regressed = new.value < base.value * (1 - band) - EPS
    if regressed:
        return "REGRESSED", worse
    return ("improved" if worse < -EPS else "ok"), worse


def compare_runs(baseline_dir: Path, run_dir: Path):
    """Returns (report_rows, errors). errors is non-empty on any gate
    failure (regression, missing metric/axis, schema problem)."""
    base_manifest, base_docs = results.load_run(baseline_dir)
    new_manifest, new_docs = results.load_run(run_dir)
    rows, errors = [], []
    for axis, bdoc in sorted(base_docs.items()):
        ndoc = new_docs.get(axis)
        if ndoc is None:
            errors.append(
                f"axis {axis!r}: present in baseline but missing from "
                f"{run_dir} -- if the axis was intentionally removed, "
                "refresh results/baseline/")
            continue
        bm, nm = metrics_of(bdoc), metrics_of(ndoc)
        for name, base in sorted(bm.items()):
            new = nm.get(name)
            if new is None:
                errors.append(
                    f"axis {axis!r}: metric {name!r} present in baseline "
                    "but missing from the new run -- a dropped assertion "
                    "looks exactly like this; if intentional, refresh "
                    "results/baseline/")
                continue
            if new.direction != base.direction:
                errors.append(
                    f"axis {axis!r}: metric {name!r} changed direction "
                    f"({base.direction!r} -> {new.direction!r}) -- "
                    "refresh results/baseline/ to re-anchor it")
                continue
            status, worse = compare_metric(base, new)
            rows.append({"axis": axis, "metric": name, "kind": new.kind,
                         "baseline": base.value, "new": new.value,
                         "worse_rel": worse,
                         "band": new.resolved_band(), "status": status})
            if status == "REGRESSED":
                errors.append(
                    f"axis {axis!r}: metric {name!r} regressed "
                    f"{worse:+.3%} (baseline {base.value:.6g} -> "
                    f"{new.value:.6g}, {new.direction} is better, "
                    f"noise band {new.resolved_band():.3g})")
        for name in sorted(set(nm) - set(bm)):
            rows.append({"axis": axis, "metric": name,
                         "kind": nm[name].kind, "baseline": None,
                         "new": nm[name].value, "worse_rel": 0.0,
                         "band": nm[name].resolved_band(),
                         "status": "new"})
    for axis in sorted(set(new_docs) - set(base_docs)):
        rows.append({"axis": axis, "metric": "(whole axis)",
                     "kind": "-", "baseline": None, "new": None,
                     "worse_rel": 0.0, "band": None, "status": "new"})
    return rows, errors


def render(rows) -> str:
    lines = [f"{'axis':<18} {'metric':<34} {'kind':<8} "
             f"{'baseline':>12} {'new':>12} {'worse':>9} {'band':>7} "
             f"status"]
    for r in rows:
        fb = ("-" if r["baseline"] is None else f"{r['baseline']:.5g}")
        fn = ("-" if r["new"] is None else f"{r['new']:.5g}")
        band = "-" if r["band"] is None else f"{r['band']:.3g}"
        lines.append(f"{r['axis']:<18} {r['metric']:<34} {r['kind']:<8} "
                     f"{fb:>12} {fn:>12} {r['worse_rel']:>+8.2%} "
                     f"{band:>7} {r['status']}")
    return "\n".join(lines)


def latest_run(runs_root: Path) -> Path:
    candidates = sorted(p for p in runs_root.iterdir()
                        if (p / "manifest.json").exists())
    if not candidates:
        raise SchemaError(f"no benchmark runs under {runs_root} -- run "
                          "`python -m benchmarks.run --smoke --timed` "
                          "first")
    return candidates[-1]


def refresh_baseline(run_dir: Path, baseline_dir: Path) -> None:
    manifest, docs = results.load_run(run_dir)   # validates everything
    if manifest.get("failures"):
        raise SchemaError(
            f"{run_dir} has failed axes {sorted(manifest['failures'])} "
            "-- a baseline must come from a fully green run")
    if baseline_dir.exists():
        shutil.rmtree(baseline_dir)
    shutil.copytree(run_dir, baseline_dir)
    print(f"baseline refreshed from {run_dir} "
          f"({len(docs)} axes) -> {baseline_dir}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("run", nargs="?", default=None,
                    help="run dir to gate (default: latest under "
                         "results/runs/)")
    ap.add_argument("--baseline", default=str(results.BASELINE),
                    help="baseline run dir (default results/baseline/)")
    ap.add_argument("--refresh-baseline", action="store_true",
                    help="replace the baseline with the given run "
                         "instead of gating")
    args = ap.parse_args(argv)
    try:
        run_dir = (Path(args.run) if args.run
                   else latest_run(results.RUNS))
        if args.refresh_baseline:
            refresh_baseline(run_dir, Path(args.baseline))
            return 0
        rows, errors = compare_runs(Path(args.baseline), run_dir)
    except SchemaError as e:
        print(f"compare: {e}", file=sys.stderr)
        return 1
    print(f"# baseline: {args.baseline}")
    print(f"# run:      {run_dir}")
    print(render(rows))
    if errors:
        print(f"\n{len(errors)} gate failure(s):", file=sys.stderr)
        for e in errors:
            print(f"  FAIL {e}", file=sys.stderr)
        return 1
    n_improved = sum(r["status"] == "improved" for r in rows)
    print(f"\ngate OK: {len(rows)} metrics within noise bands "
          f"({n_improved} improved)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
