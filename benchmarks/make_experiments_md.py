"""Assemble EXPERIMENTS.md from results/*.json + the analysis text.

  PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
RESULTS = ROOT / "results"

from benchmarks.roofline_table import render


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    if x >= 1e-6 or x == 0:
        return f"{x*1e6:.0f}us"
    return f"{x*1e9:.1f}ns"   # toy smoke cells land here


def _load(name):
    """Full-run results (dryrun --all, benchmarks.run without --smoke) are
    hours of compile time and are NOT produced by CI; the smoke path only
    writes bench_smoke*.json. Returning None lets the affected sections
    degrade to a regeneration note instead of crashing, so EXPERIMENTS.md
    can be rebuilt (e.g. to refresh §CI smoke artifacts) on a machine that
    only ran the smoke benches."""
    p = RESULTS / name
    if not p.exists():
        return None
    return json.load(open(p))


_MISSING = ("_(results/{name} not present -- regenerate with {cmd}, then "
            "re-run `python -m benchmarks.make_experiments_md`)_")


def perf_table():
    iters = _load("perf_iterations.json")
    if iters is None:
        return _MISSING.format(name="perf_iterations.json",
                               cmd="the §Perf iteration runs")
    out = ["| cell | iteration | compute | ici | dcn | memory | dominant | "
           "roofline frac | HBM GiB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in iters:
        out.append(
            f"| {r['cell']} | {r['iter']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['ici_s'])} | {fmt_s(r['dcn_s'])} | "
            f"{fmt_s(r['memory_s'])} | {r['dominant']} | "
            f"{r['roofline']:.3f} | {r['hbm_GiB']:.1f} |")
    return "\n".join(out)


def bench_numbers():
    return _load("bench_results.json")


def smoke_appendix():
    """Summarize EVERY results/bench_smoke*.json the CI smoke path wrote
    (discovered by glob, not a hard-coded list, so new smoke axes --
    prefetch-depth, mixed-mode, cross-step, ... -- appear here the day
    they land)."""
    files = sorted(RESULTS.glob("bench_smoke*.json"))
    if not files:
        return "_(no bench_smoke*.json present -- run " \
               "`python benchmarks/run.py --smoke`)_"
    out = ["| file | benches / axes | rows |", "|---|---|---|"]
    for f in files:
        try:
            data = json.load(open(f))
        except Exception as e:  # keep the table rendering over one bad file
            out.append(f"| {f.name} | unreadable: {e} | — |")
            continue
        if "axis" in data:      # a harness per-axis artifact
            arms = sorted(((data.get("timing") or {}).get("arms") or {}))
            keys = (f"axis {data['axis']} "
                    f"(schema v{data.get('schema_version')}, "
                    f"{len(data.get('metrics', []))} gated metrics"
                    + (f", timed arms: {', '.join(arms)}" if arms else "")
                    + ")")
            n = (len(data["rows"]) if isinstance(data.get("rows"), list)
                 else len(data.get("metrics", [])))
        elif "rows" in data:    # a pre-harness single-bench smoke file
            keys, n = "smoke", len(data["rows"])
        else:                   # the aggregate bench_smoke.json
            keys = ", ".join(sorted(data))
            n = sum(len(v["rows"])
                    for v in data.values()
                    if isinstance(v, dict)
                    and isinstance(v.get("rows"), list))
        out.append(f"| {f.name} | {keys} | {n} |")
    return "\n".join(out)


def timed_table():
    """Wall-clock step timings from the latest timestamped run dir
    (results/runs/<stamp>/): per axis arm the warmed-up median/p90/mean
    over the fenced timed steps.  Absolute numbers are
    machine-dependent -- the regression gate (benchmarks/compare.py)
    holds them inside wide noise bands vs results/baseline/, while the
    analytic byte metrics in the same artifacts carry tight bands."""
    manifests = sorted((RESULTS / "runs").glob("*/manifest.json"))
    if not manifests:
        return _MISSING.format(
            name="runs/<stamp>/manifest.json",
            cmd="`python -m benchmarks.run --smoke --timed`")
    run_dir = manifests[-1].parent
    manifest = json.load(open(manifests[-1]))
    out = ["| axis | arm | median/step | p90 | mean | timed steps |",
           "|---|---|---|---|---|---|"]
    n_arms = 0
    for axis, name in manifest.get("artifacts", {}).items():
        doc = json.load(open(run_dir / name))
        t = doc.get("timing")
        if not t:
            continue
        for label, a in sorted(t["arms"].items()):
            out.append(f"| {axis} | {label} | {fmt_s(a['median_s'])} | "
                       f"{fmt_s(a['p90_s'])} | {fmt_s(a['mean_s'])} | "
                       f"{a['n']} |")
            n_arms += 1
    if not n_arms:
        return _MISSING.format(
            name=f"timing blocks in {run_dir.name}",
            cmd="`python -m benchmarks.run --smoke --timed`")
    env = manifest.get("env", {})
    out.append("")
    out.append(
        f"Run `{manifest['stamp']}` ({env.get('platform', 'unknown')}, "
        f"jax {env.get('jax', '?')}, backend {env.get('backend', '?')}). "
        "Warmup steps excluded; each timed step is fenced with "
        "`jax.block_until_ready` on the full step output. The serve "
        "axis's arms report the measured inter-token-latency "
        "distribution instead of a train-step time.")
    return "\n".join(out)


def fused_table():
    """Gather-fused collective matmul axis (bench_fused_smoke): per
    (mode, fused) arm the measured overlap credit and what it buys --
    the exposed-collective delta column is unfused minus fused
    ``collective_exposed_s`` for the same mode, strictly positive for
    every eligible strategy by the bench's acceptance assert."""
    data = _load("bench_smoke_fused.json")
    if data is None:
        return _MISSING.format(name="bench_smoke_fused.json",
                               cmd="`python benchmarks/run.py --smoke`")
    base = {r["mode"]: r["collective_exposed_s"] for r in data["rows"]
            if r["fused_matmul"] == "none"}
    out = ["| mode | fused | fused leaves | overlap credit | "
           "exposed collective | delta vs unfused | losses |",
           "|---|---|---|---|---|---|---|"]
    for r in data["rows"]:
        d = base.get(r["mode"], r["collective_exposed_s"]) \
            - r["collective_exposed_s"]
        delta = "—" if r["fused_matmul"] == "none" else f"-{fmt_s(d)}"
        ls = " ".join(f"{x:.6f}" for x in r["losses"])
        out.append(
            f"| {r['mode']} | {r['fused_matmul']} | "
            f"{r['n_fused_leaves']} | "
            f"{fmt_s(r['fused_credit_applied_s'])} | "
            f"{fmt_s(r['collective_exposed_s'])} | {delta} | {ls} |")
    out.append("")
    out.append(f"Losses fused-on vs fused-off are **bit-identical** "
               f"(asserted, not allclose); `both` re-associates the bf16 "
               f"backward reduction (max relative drift "
               f"{data['both_loss_drift_rel']:.1e}, bound "
               f"{data['drift_bound']:g}) and is bit-exact against its "
               f"own ring oracles instead (tests/test_fused_matmul.py).")
    return "\n".join(out)


def serve_table():
    """Continuous-batching serve axis (bench_serve_smoke): the repo's
    first wall-clock-timed perf artifact -- request throughput plus
    TTFT/TPOT/ITL latency percentiles per admission policy, measured on
    the machine that wrote results/bench_smoke_serve.json."""
    data = _load("bench_smoke_serve.json")
    if data is None:
        return _MISSING.format(name="bench_smoke_serve.json",
                               cmd="`python benchmarks/run.py --smoke`")
    out = ["| policy | req/s | tok/s | TTFT p50 | TTFT p99 | TPOT p50 | "
           "ITL p50 | ITL p99 |",
           "|---|---|---|---|---|---|---|---|"]
    for policy in ("continuous", "static"):
        a = data["arms"][policy]
        out.append(
            f"| {policy} | {a['throughput_rps']:.1f} | "
            f"{a['throughput_tok_s']:.1f} | {fmt_s(a['ttft_s']['p50'])} | "
            f"{fmt_s(a['ttft_s']['p99'])} | {fmt_s(a['tpot_s']['p50'])} | "
            f"{fmt_s(a['itl_s']['p50'])} | {fmt_s(a['itl_s']['p99'])} |")
    w, kv = data["workload"], data["kv"]
    out.append("")
    out.append(
        f"Workload: {w['n_requests']} requests, bimodal prompts "
        f"(min {w['min_prompt']}, cap {w['seq_len']}), heavy-tailed "
        f"generation lengths in [{w['gen_lo']}, {w['gen_hi']}] "
        f"(serve_workload.py, seed {w['seed']}). Paged KV: "
        f"{kv['page_size']}-token pages, {kv['pages_per_replica']} "
        f"pages/replica ({kv['kv_page_bytes_per_chip']/1e6:.2f} MB/chip, "
        f"planner-accounted). Continuous admission is "
        f"**{data['continuous_vs_static_rps']:.2f}x** the "
        f"wait-for-full-batch baseline on the same jitted steps "
        f"(asserted > 1 by the bench); decode logits under the paged "
        f"cache are bit-identical to the contiguous single-request path "
        f"(tests/test_serve_engine.py).")
    return "\n".join(out)


def peft_table():
    """PEFT end-to-end axis (bench_smoke_peft): a TRAINED LoRA
    fine-tune where the residency layer parks the frozen trunk
    pod-replicated/host-cached -- per arm the traced stage-1 (DCN)
    all-gather bytes, the plan-tree analytic counterpart, and the
    per-step losses (identical across arms by construction)."""
    data = _load("bench_smoke_peft.json")
    if data is None:
        return _MISSING.format(name="bench_smoke_peft.json",
                               cmd="`python benchmarks/run.py --smoke`")
    out = ["| arm | stage-1 DCN AG bytes/step (traced) | analytic | "
           "host cache B/chip | losses |",
           "|---|---|---|---|---|"]
    names = {0: "fcdp (trunk frozen_cached)", 1: "zero3 (trunk dcn_sharded)",
             2: "mixed (trunk fcdp + adapters zero3)"}
    for i, r in enumerate(data["rows"]):
        ls = " ".join(f"{x:.4f}" for x in r["losses"])
        out.append(
            f"| {names.get(i, r['mode'])} | {r['pod_ag_bytes']:,.0f} | "
            f"{r['stage1_dcn_analytic']:,.0f} | "
            f"{r['host_cache_bytes']:,.0f} | {ls} |")
    out.append("")
    out.append(
        f"LoRA rank {data['lora_rank']}, trainable fraction "
        f"**{data['trainable_frac_pct']:.2f}%** of parameters, "
        f"{data['trained_steps']} trained steps. Steady-state DCN "
        f"reduction vs the zero3 baseline: "
        f"**{data['peft_dcn_reduction_pct']:.2f}%** uniform-fcdp, "
        f"**{data['mixed_peft_dcn_reduction_pct']:.2f}%** mixed-composite "
        f"(bound >= {data['reduction_bound_pct']:.0f}% asserted by the "
        f"bench); adapter updates after one step are **bit-identical** "
        f"to the all-trainable reference on the adapter leaves "
        f"(asserted), and the per-step losses match across every arm.")
    return "\n".join(out)


def dryrun_summary():
    cells = _load("dryrun_fcdp.json")
    if cells is None:
        return None
    ok = [c for c in cells if c["status"] == "ok"]
    sk = [c for c in cells if c["status"] == "skipped"]
    comp = [c["compile_s"] for c in ok]
    return {"ok": len(ok), "skipped": len(sk),
            "max_compile_s": max(comp), "sum_compile_s": sum(comp)}


class _NA:
    """Formats as 'n/a' under ANY format spec, so the TEMPLATE's numeric
    placeholders ({maxc:.1f}, {fc_red:.1f}, ...) still render when the
    full-run results files are absent."""

    def __format__(self, spec):
        return "n/a"


def main():
    b = bench_numbers()
    d = dryrun_summary()

    na = _NA()
    kw = dict(ok=na, skipped=na, maxc=na, sumc=na,
              z3_dcn=na, fc_dcn=na, fc_red=na, peft_dcn=na, peft_red=na,
              mics_dcn=na, z3_drop=na, fc_keep=na, speedup01=na,
              host_1pod=na, zpp_1pod=na, fc_1pod=na, z3_1pod=na)
    if d is not None:
        kw.update(ok=d["ok"], skipped=d["skipped"],
                  maxc=d["max_compile_s"], sumc=int(d["sum_compile_s"]))
    if b is not None:
        rows = b["bw_sensitivity"]["rows"]

        def sps(sysname, gbps):
            return next(r["samples_per_s"] for r in rows
                        if r["system"] == sysname and r["dcn_gbps"] == gbps)

        cv = {r["system"]: r for r in b["comm_volume"]["rows"]}
        mem = {(r["mesh"], r["system"]): r for r in b["memory"]["rows"]}
        kw.update(
            z3_dcn=cv["zero3"]["dcn_bytes"] / 1e9,
            fc_dcn=cv["fcdp"]["dcn_bytes"] / 1e9,
            fc_red=100 * (1 - cv["fcdp"]["dcn_vs_zero3"]),
            peft_dcn=cv["fcdp_comm(peft)"]["dcn_bytes"] / 1e9,
            peft_red=100 * (1 - cv["fcdp_comm(peft)"]["dcn_vs_zero3"]),
            mics_dcn=cv["mics"]["dcn_bytes"] / 1e9,
            z3_drop=100 * (1 - sps("zero3", 0.1) / sps("zero3", 100)),
            fc_keep=100 * (sps("fcdp_comm_peft", 0.1)
                           / sps("fcdp_comm_peft", 100)),
            speedup01=sps("fcdp_comm_peft", 0.1) / sps("zero3", 0.1),
            host_1pod=mem[("1pod", "fcdp")]["host_cache_GiB"],
            zpp_1pod=mem[("1pod", "zeropp")]["hbm_peak_GiB"],
            fc_1pod=mem[("1pod", "fcdp")]["hbm_peak_GiB"],
            z3_1pod=mem[("1pod", "zero3")]["hbm_peak_GiB"],
        )
    try:
        table_1pod, table_2pod = render(False), render(True)
    except FileNotFoundError:
        table_1pod = table_2pod = _MISSING.format(
            name="dryrun_fcdp.json",
            cmd="`PYTHONPATH=src python -m repro.launch.dryrun --all`")

    text = TEMPLATE.format(
        perf_table=perf_table(),
        table_1pod=table_1pod,
        table_2pod=table_2pod,
        smoke_appendix=smoke_appendix(),
        timed_table=timed_table(),
        fused_table=fused_table(),
        serve_table=serve_table(),
        peft_table=peft_table(),
        **kw,
    )
    (ROOT / "EXPERIMENTS.md").write_text(text)
    print(f"wrote EXPERIMENTS.md ({len(text)} chars)")


TEMPLATE = """# EXPERIMENTS — FCDP reproduction + roofline + perf log

All numbers are derived from the multi-pod dry-run (lower + compile on
the CPU backend with 512 placeholder devices; TPU v5e is the *target*:
197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI, 25 GB/s/chip DCN
assumed). Regenerate any table with
`PYTHONPATH=src python -m repro.launch.dryrun --all`,
`python -m benchmarks.run`, `python -m benchmarks.make_experiments_md`.

## §Dry-run

Every (architecture x input-shape) cell was lowered AND compiled with
`jax.jit(step).lower(**input_specs).compile()` on BOTH production meshes
(16x16 = 256 chips; 2x16x16 = 512 chips; `make_production_mesh`), with
`memory_analysis()` and `cost_analysis()` captured per cell
(results/dryrun_fcdp.json, printed log in results/dryrun_all.log):

- **{ok} cells compiled, 0 failures**; {skipped} cells are the documented
  `long_500k` skips (8 pure full-attention archs x 2 meshes — the
  assignment's sub-quadratic-only rule; rwkv6-3b and jamba-v0.1-52b DO
  run long_500k with recurrent state / sequence-sharded KV).
- max single-cell compile {maxc:.1f}s, {sumc}s total for all 64.
- train cells lower `train_step` (fwd+bwd+AdamW update on ZeRO shards);
  `decode_*`/`long_*` lower `serve_step` (one token against a
  seq_len-sized KV cache), `prefill_32k` lowers the cache-filling
  forward, per the assignment.
- Memory/cost provenance: `memory_analysis()` gives per-chip
  argument/temp bytes (printed per cell); `cost_analysis()` FLOPs are a
  *1x-loop lower bound* (XLA counts while bodies once), so the roofline
  FLOPs/bytes come from a jaxpr walker that multiplies scan trip counts
  and attributes per-device shapes inside shard_map (see
  launch/roofline.py; both sources recorded per cell).
- HBM notes: cells whose per-chip peak exceeds the 16 GiB v5e budget at
  the assigned global batch are reported as-is (e.g. yi-34b train_4k
  81.5 GiB, kimi-k2 116 GiB — 1T params with fp32 Adam is 27 GiB of
  optimizer state alone at 512 chips); the runnable configuration at
  these shapes uses `--microbatch` gradient accumulation (implemented)
  and/or bf16 optimizer state (`opt_state_dtype=bfloat16`: measured
  kimi-k2 persistent args 26.9 -> 19.2 GiB/chip, -29%), and
  kimi-k2-class models simply need more than 512 chips, which is
  consistent with its provenance. The dry-run's job is to surface
  exactly these numbers.

## §Paper-validation (the reproduction, before any beyond-paper work)

**Table VII (inter-node communication volume), qwen2.5-3b train_4k,
2-pod mesh, per-chip per-iteration DCN bytes** — structural reproduction
of the paper's measurement (their absolute numbers are per-GPU on a flat
4-node all-gather; ours are per-chip on a hierarchical 2-pod gather, so
ratios are the comparable quantity):

| system | DCN GB/chip/step | vs ZeRO-3 | paper's claim |
|---|---|---|---|
| ZeRO-3 | {z3_dcn:.4f} | 1.00 | 3W baseline |
| ZeRO++ | {fc_dcn:.4f} | 0.70 | 2W (-33%) |
| FCDP | {fc_dcn:.4f} | 0.70 (**-{fc_red:.1f}%**) | 2W (-33%), identical GPU mem |
| FCDP-Comm (LoRA r=8 on qkvo) | {peft_dcn:.5f} | **-{peft_red:.1f}%** | -99.9% |
| MiCS | {mics_dcn:.3f} | grad-AR over DCN instead of AG | memory-for-comm trade |

The FCDP rows split exactly as the paper's Fig. 4: the backward pod-stage
all-gather is gone (verified structurally in
tests/test_system.py::test_fcdp_halves_backward_pod_allgather: pod-axis
AG bytes halve, reduce-scatter unchanged); the remaining DCN bytes are
the forward AG + gradient reduce-scatter. MiCS moves the cost into a
full-gradient DCN all-reduce, as §VI predicts.

**Fig. 9 (bandwidth sensitivity)** — step-time model
max(compute, ici+dcn) sweeping DCN bandwidth 100 -> 0.1 Gbps/host:

- ZeRO-3 throughput drops **{z3_drop:.1f}%** from 100 Gbps to 0.1 Gbps
  (paper: 98.4% over their 100 -> 1 Gbps range — our hierarchical
  baseline needs a 10x lower floor to show the same collapse because the
  two-stage gather already shrinks DCN payloads by the intra-pod degree;
  that hierarchical-baseline advantage is itself a TPU-adaptation
  finding, see DESIGN.md §2).
- FCDP-Comm (PEFT) keeps **{fc_keep:.1f}%** of its peak throughput at
  0.1 Gbps (paper: 86-90% at 1 Gbps) — the decoupling claim reproduces.
- At 0.1 Gbps FCDP-Comm is **{speedup01:.1f}x** ZeRO-3. The paper's
  100x/51x headline additionally relies on their flat (non-hierarchical)
  all-gather baseline and 8-GPU nodes; with per-accelerator inter-node
  bytes ~256x smaller on a TPU pod, the same mechanism yields a smaller
  but same-shaped effect.

**Memory (SSIII-B / Tables V-VI)** — granite-3-8b train_4k:

- 2-pod mesh: fcdp HBM == zero3 HBM (the paper's headline equality);
  the FCDP host-cache tier is 0.1 GiB/chip (= W/(data*tp) stage-1 shards,
  the paper's "~2W per node").
- Single-pod mesh (the regime where the cache is the fully gathered
  weight): zeropp pays **{zpp_1pod:.1f} GiB** HBM vs fcdp
  **{fc_1pod:.1f} GiB** (zero3: {z3_1pod:.1f}) — the ZeRO++ cache tax
  appears in HBM while FCDP moves the same {host_1pod:.2f} GiB/chip to
  host DRAM (CPU backend drops `pinned_host`, so the fcdp row subtracts
  the analytically-derived cache size; on TPU the policy emits real
  host offloads).
- max-batch (Tables V/VI analog): at 2-pod scale all three systems
  sustain the same global batch (256 at 4k) because a 256-chip pod
  shards the stage-1 cache 256 ways — the paper's OOM gap re-emerges in
  the single-pod full-weight-cache regime above.

**Numerical equivalence** (the paper's implicit correctness claim):
one training step under zero3 / zeropp / fcdp / mics produces identical
loss, grad-norm, and updated parameters (tests/test_system.py), and the
sharded system matches a single-device unsharded reference gradient
leaf-for-leaf.

## §Roofline

Terms per §ROOFLINE: compute = FLOPs/chip / 197e12; memory = HBM
bytes/chip / 819e9 (upper bound: major-op operand bytes, fusion-credited
for elementwise chains); collective = ICI bytes/chip / 50e9 + DCN
bytes/chip / 25e9 (jaxpr walker, ring cost models, scan trips included;
axis attribution pod->DCN / data,model->ICI). `MODEL_FLOPS/HLO` =
6*N*D (dense) or 6*N_active*D (MoE) over walked HLO FLOPs — the
useful-compute ratio (catches remat + capacity-factor + padding waste:
e.g. 0.61 for qwen = block_io remat ~1/3 + attention quadratic + head).
`roofline frac` = (MODEL_FLOPS/chips/peak) / max(term)s — the score per
cell. Dominant-term mitigation is in §Perf.

{table_1pod}

Supplementary (the technique's own mesh — DCN terms appear here):

{table_2pod}

Reading the table:
- **train cells are collective-dominated** — and the breakdown (coll_by_op
  in results/dryrun_fcdp.json) shows the volume is NOT the ZeRO gathers
  (0.9 GB/chip for qwen) but the Megatron-TP activation all-reduces
  (57 GB/chip): at d_model 2048-8192 with tp=16 and 32k tokens/chip, the
  f/g-pair psums dwarf parameter traffic. FCDP's contribution governs
  the DCN column, which it wins (see §Paper-validation); the ICI column
  is a TP-design property attacked in §Perf.
- **decode cells** score ~0 roofline fraction by construction: one token
  per sequence against 512 chips' peak is inherently latency- not
  throughput-bound; the interesting metric there is the absolute
  collective/memory time per token (attacked for kimi in §Perf).
- **long_500k** runs only on the two sub-quadratic archs; rwkv6's
  recurrent state makes the step collective-bound purely on parameter
  reconstruction for batch=1 — the FCDP-Comm serving layout is what
  makes it DCN-free.

## §Perf — hypothesis -> change -> measure -> validate

Three hillclimb cells: **qwen2.5-3b/train_4k** (most representative of
the paper's regime: dense GPT-style full fine-tune), **llama4/train_4k**
(worst roofline fraction among train cells), **kimi-k2/decode_32k**
(most collective-bound). Paper-faithful fcdp baseline first; beyond-paper
iterations after. Full numbers: results/perf_iterations.json.

{perf_table}

### Iteration log (hypothesis -> outcome)

**qwen/train_4k**
1. *save_collectives* — hypothesis: block_io remat re-runs every TP psum
   in the backward (~1/3 of the 57 GB/chip psum volume); saving only
   collective outputs (+~0.25 GiB/layer) should cut ICI ~30%.
   Measured: ici 1.169s -> 0.988s (**-15%**, roofline 0.181 -> 0.214).
   PARTIALLY CONFIRMED — only the forward-recompute psums were saved;
   the backward f/g-pair ARs (structural Megatron comm) remain. HBM
   14.1 -> 23.4 GiB exceeds v5e: on 16 GiB chips this policy needs
   `--microbatch 2` (implemented) or applies to a layer subset.
2. *int8 pod-gradient compression* — hypothesis: halve the (already
   small) DCN reduce-scatter. Measured dcn 1.2ms -> 0.9ms. CONFIRMED
   but immaterial at pod=2 scale; matters on many-pod meshes where the
   pod stage multiplies.
3. *device_cache_fraction 0.5* (FCDP-Cache tau) — hypothesis: no comm
   change, HBM trade only. Measured: ici unchanged, HBM -1.8 GiB.
   CONFIRMED (it is a placement knob, exactly the paper's C3).
4. *int8 activation all-reduce, forward* (`act_psum=int8`: the f-pair
   psums on sublayer outputs run as int8 RS+AG with per-256 scales) —
   hypothesis: the fwd+recompute half of the 57 GB psum volume halves.
   Measured: ici 1.169 -> 0.901s (**-23%**), roofline 0.235, HBM
   UNCHANGED 14.1 GiB (fits v5e, unlike save_collectives), training
   loss within 0.003 of exact over 4 smoke steps. CONFIRMED — strictly
   dominates iteration 1.
5. *int8 backward all-reduce* (`tp_region_in`: a custom-vjp marker on
   the column-parallel region inputs runs the autodiff-inserted g-bar
   cotangent all-reduce in int8 too) — hypothesis: the remaining
   ~17 GB of backward ARs halve; ici should approach 0.5s. Measured:
   ici 0.901 -> **0.499s**; the dominant term FLIPS to memory and the
   roofline fraction reaches **0.367 = 2.03x the paper-faithful
   baseline**, HBM still 14.1 GiB, loss delta still 0.003. CONFIRMED —
   the headline win of the perf pass on the paper's own regime.
   Lesson: on a 256-chip pod the paper's DCN problem is already solved
   by hierarchy; the analogous *intra-pod* communication-avoiding move
   (compress what you must send, never re-send what you cached) is
   where the next 2x lives.

**llama4/train_4k**
1. *moe_weight_resident (pod-only expert sharding)* — hypothesis:
   per-step expert gather volume (~180 GB/chip) >> resident size
   (1 GiB/chip bf16), so keep experts resident. Measured: AG -90 GB as
   predicted BUT psum +181 GB and HBM 572 GiB. REFUTED twice over:
   (a) optimizer state followed the param sharding (fixed by the ZeRO-2
   split below), and (b) VMA autodiff turns replicated-param gradients
   into full all-reduces (2x the reduce-scatter bytes) — the fwd saving
   is exactly cancelled. A refuted hypothesis that exposed a real
   mechanism: *gradient RS-vs-AR is tied to the storage layout, not the
   schedule*.
2. *zero2_experts (resident weights + fully-sharded optimizer + one
   intra AG/step)* — implemented the full ZeRO-2 split (grads RS'd over
   intra axes, updated shards gathered once per step). Measured: ici
   unchanged, HBM still 319 GiB (bf16 resident grads/params at expert
   scale), dcn +3.6s (pod-axis full-grad reduce). REFUTED at
   400B-expert scale on 16 GiB chips: the paper's own answer — a host
   cache — is the only tier that can hold gathered experts; bounded by
   ~64 GiB host/chip it covers ~60% of llama4's layers (planner knob).
3. *save_collectives* — same mechanism as qwen. ici 8.656 -> 8.004s
   (**-7.5%**, roofline 0.078 -> 0.084). CONFIRMED, adopted.
4. *moe_token_chunk 16k* — hypothesis: fewer, larger a2a launches; bytes
   unchanged. Measured: identical terms. CONFIRMED-NULL (the roofline
   counts bytes, not launches; launch overhead is invisible to this
   profile — flagged for on-hardware validation).

**kimi-k2/decode_32k**
1. *moe_serve_sharded (gather-free expert decode)* — hypothesis: the
   baseline gathers ~2 GB of expert weights per layer to process ~4
   tokens/chip; computing against the sharded weights and moving the
   tokens instead (AG tokens over 'data', partial-contraction psum,
   slice-back) should cut the collective term by >40%. Measured:
   ici 2.524s -> **1.373s (-46%)**, memory term -34%, HBM -3.3 GiB,
   decode logits bit-identical to the gathered path. CONFIRMED — the
   single largest win of the perf pass; per-token latency lower bound
   improves 1.8x.
2. *capacity_factor floor* — hypothesis: decode buffers are
   capacity-padded 1.25x. Measured: identical (capacity already at the
   min-4 floor at these token counts). CONFIRMED-NULL.
   Next lever (napkin): expert-slice the remaining per-layer attention/
   router gathers (additional ~0.7s), or batch multiple decode steps
   per gather.

**Stopping criterion**: per §Perf rules, each cell stopped after
consecutive <5% iterations on its dominant term (qwen: it2/it3 null on
ici; llama4: it4 null + it5/6 refuted; kimi: it2 null).

### Paper-faithful baseline vs beyond-paper optimized (required split)

| cell | paper-faithful fcdp baseline | + beyond-paper | delta |
|---|---|---|---|
| qwen2.5-3b/train_4k | roofline 0.181 (block_io) | **0.367** (int8 fwd+bwd activation AR; fits 16 GiB) | **2.03x** |
| llama4/train_4k | 0.078 | 0.084 (save_collectives + int8 act-AR) | +8% (expert gathers dominate; host-tier planner is the next lever) |
| kimi-k2/decode_32k | collective 2.52s/token-step | 1.37s (moe_serve_sharded) | **1.84x** |

The paper's own mechanism (host-cached backward reconstruction) is
present in ALL rows — it is what keeps the DCN column at the 2W level
and HBM at ZeRO-3 parity; the beyond-paper wins attack the terms the
paper does not address (TP activation volume, MoE weight movement).

## §Large-scale runnability checklist

- fault tolerance: checkpoint/restart driver with failure injection
  (examples/quickstart.py survives an injected crash; tests cover
  double-failure recovery), heartbeat watchdog, straggler z-score
  monitor (launch/train.py prints flagged steps).
- elastic scaling: checkpoints restore across meshes (2-pod -> 1-pod ->
  smoke mesh; examples/elastic_restart.py), `runtime.elastic.remesh()`
  picks the largest valid mesh from survivors.
- parallelism: DP(pod,data) x TP(model) x EP(model) x ZeRO-3, sequence-
  sharded KV for long context, PEFT-aware comm; all composable per
  SystemConfig.
- distributed-optimization tricks: two-stage (DCN/ICI) gathers overlap
  by construction; int8 DCN gradient compression; chunked CE loss;
  gather-free MoE decode; FCDP-Cache compile-time planner.
- 1000+ node path: the `pod` axis generalizes to N pods (mesh (N,16,16));
  per-pod DCN traffic is independent of N for FCDP (2W_t or 2W/pod-stage
  shards), grad reduce is log/ring over pods; checkpoint shards per
  process; data pipeline is seeded per (shard, step) with no central
  coordinator.

## §Gather-fused collective matmul (toy-mesh smoke axis)

The output projections consume stage-2 shards as they arrive: each
device multiplies its resident weight chunk immediately while a
ppermute ring streams the remaining chunks in behind the per-chunk
matmuls (`--fused-matmul ag_matmul`; `both` adds the dual grad rings).
The swap is byte-neutral — the ring moves the same (n-1)/n of the
weight the tiled all-gather did — so the overlap credit (measured from
the kernel's own chunk schedule, launch/roofline.py:
`fused_overlap_credit`) converts one-for-one into less exposed
collective time:

{fused_table}

## §Continuous-batching serve (timed smoke axis)

One engine, two admission policies on the identical mixed-length
workload and the SAME jitted paged-KV steps: continuous (admit/retire
every scheduler tick, chunked prefill riding along with in-flight
decodes) vs static (wait for every slot to drain, then refill). These
are wall-clock measurements -- the first timed numbers in this log; all
tables above are roofline-derived:

{serve_table}

## §Parameter residency: PEFT end-to-end (smoke axis)

The residency layer (core/residency.py, ARCHITECTURE.md "Parameter
residency") gives every leaf one lifecycle value — storage tier,
reconstruction schedule, backward source, update class. The PEFT smoke
axis proves the headline consequence on a *trained* workload: under
fcdp a frozen LoRA trunk is `pod_replicated`/`host`/`frozen_cached`
(empty stage 1 — zero steady-state DCN bytes, no gather-ring slot)
while zero3 keeps the same frozen trunk `dcn_sharded` and re-gathers it
over DCN every step, exactly the DeepSpeed baseline asymmetry the paper
targets:

{peft_table}

## §Timed smoke step times (wall-clock, regression-gated)

`python -m benchmarks.run --smoke --timed` times the toy training arms
each axis declares (e.g. comm's fcdp-vs-zero3, quant's bf16-vs-int8,
fused's unfused-vs-fused) on the 8-device CPU mesh: warmup steps
excluded, every timed step fenced with `jax.block_until_ready`, and the
median gated against `results/baseline/` by `benchmarks/compare.py`
inside a wide noise band (absolute CPU numbers are machine noise — only
a catastrophic slowdown gates; the tight gates are the analytic byte
metrics in the same artifacts):

{timed_table}

## §CI smoke artifacts

{smoke_appendix}
"""

if __name__ == "__main__":
    main()
