"""Serve-bench artifact payload + axis validator (the results half of
the workload/results split -- ``serve_workload.py`` owns the workload).

The schema+validate pattern that started here is now generalized into
``benchmarks/harness/results.py``: the serve axis builds its payload
with ``make_payload``, the harness wraps it into the shared versioned
artifact envelope, and the serve-specific invariants below are
registered as the axis validator for ``serve_smoke`` -- so the one CI
gate step that loops over every bench artifact also enforces them.

The serve numbers are wall-clock measured on the machine that produced
them, not derived from the roofline model.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.harness import results as hresults

LATENCY_KEYS = ("ttft_s", "tpot_s", "itl_s")
PCT_KEYS = ("mean", "p50", "p90", "p99")


def make_payload(workload: dict, kv: dict, arms: dict,
                 extra: dict = None) -> dict:
    """arms: {policy_name: summarize(...) dict} -- at least
    'continuous' and 'static'."""
    doc = {"smoke": True, "timed": True, "workload": workload, "kv": kv,
           "arms": arms}
    c, s = arms["continuous"], arms["static"]
    doc["continuous_vs_static_rps"] = (
        c["throughput_rps"] / s["throughput_rps"]
        if s["throughput_rps"] else float("inf"))
    if extra:
        doc.update(extra)
    return doc


def validate(doc: dict) -> None:
    """Invariants the acceptance gates rely on; raises AssertionError."""
    assert doc.get("timed"), "serve artifact must be wall-clock timed"
    arms = doc["arms"]
    for policy in ("continuous", "static"):
        a = arms[policy]
        assert a["requests"] > 0, policy
        assert a["wall_s"] > 0, policy
        assert a["throughput_rps"] > 0, policy
        assert a["throughput_tok_s"] > 0, policy
        for lk in LATENCY_KEYS:
            for pk in PCT_KEYS:
                v = a[lk][pk]
                assert v >= 0, (policy, lk, pk, v)
        # every request produced at least one token -> TTFT measured
        assert a["ttft_s"]["mean"] > 0, policy
        assert a["itl_s"]["p50"] > 0, policy
    # the headline: continuous batching strictly beats wait-for-full-batch
    assert (arms["continuous"]["throughput_rps"]
            > arms["static"]["throughput_rps"]), (
        arms["continuous"]["throughput_rps"],
        arms["static"]["throughput_rps"])


# the serve invariants ride the shared gate: every artifact whose
# "axis" is serve_smoke gets them on top of the generic schema checks
hresults.register_axis_validator("serve_smoke", validate)


def make_artifact(workload: dict, kv: dict, arms: dict,
                  extra: dict = None) -> dict:
    """Deprecated pre-harness entry point (payload-only artifact);
    kept for one release for external scripts."""
    return make_payload(workload, kv, arms, extra)


def write(path: Path, doc: dict) -> None:
    validate(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=float)
