"""Serve-bench artifact schema + writer (the results half of the
workload/results split -- ``serve_workload.py`` owns the workload).

The artifact (``results/bench_smoke_serve.json``) is the repo's first
TIMED perf artifact: every latency number in it is wall-clock measured
on the machine that produced it, not derived from the roofline model.
``validate()`` is shared by the bench itself and the CI gate so the
schema can't silently rot.
"""
from __future__ import annotations

import json
from pathlib import Path

LATENCY_KEYS = ("ttft_s", "tpot_s", "itl_s")
PCT_KEYS = ("mean", "p50", "p90", "p99")


def make_artifact(workload: dict, kv: dict, arms: dict,
                  extra: dict = None) -> dict:
    """arms: {policy_name: summarize(...) dict} -- at least
    'continuous' and 'static'."""
    doc = {"smoke": True, "timed": True, "workload": workload, "kv": kv,
           "arms": arms}
    c, s = arms["continuous"], arms["static"]
    doc["continuous_vs_static_rps"] = (
        c["throughput_rps"] / s["throughput_rps"]
        if s["throughput_rps"] else float("inf"))
    if extra:
        doc.update(extra)
    return doc


def validate(doc: dict) -> None:
    """Invariants the acceptance gates rely on; raises AssertionError."""
    assert doc.get("timed"), "serve artifact must be wall-clock timed"
    arms = doc["arms"]
    for policy in ("continuous", "static"):
        a = arms[policy]
        assert a["requests"] > 0, policy
        assert a["wall_s"] > 0, policy
        assert a["throughput_rps"] > 0, policy
        assert a["throughput_tok_s"] > 0, policy
        for lk in LATENCY_KEYS:
            for pk in PCT_KEYS:
                v = a[lk][pk]
                assert v >= 0, (policy, lk, pk, v)
        # every request produced at least one token -> TTFT measured
        assert a["ttft_s"]["mean"] > 0, policy
        assert a["itl_s"]["p50"] > 0, policy
    # the headline: continuous batching strictly beats wait-for-full-batch
    assert (arms["continuous"]["throughput_rps"]
            > arms["static"]["throughput_rps"]), (
        arms["continuous"]["throughput_rps"],
        arms["static"]["throughput_rps"])


def write(path: Path, doc: dict) -> None:
    validate(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=float)
