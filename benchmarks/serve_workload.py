"""Synthetic serve workloads (the workload half of the serve bench's
workload/results split -- ``serve_results.py`` owns the artifact).

A workload is a seeded, reproducible list of requests with MIXED prompt
AND generation lengths: continuous batching's advantage over
wait-for-full-batch admission only shows when requests FINISH at
different times -- a static wave idles every slot whose sequence
completed until the slowest one drains, while continuous admission
backfills those slots immediately. Equal lengths would hide that
entirely (every slot finishes together and static never idles), so the
generator spreads prompts bimodally and generation lengths uniformly,
then shuffles. Deterministic per (spec, seed): both scheduler policies
replay the identical request list.
"""
from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import List

import numpy as np


@dataclass(frozen=True)
class WorkloadSpec:
    n_requests: int = 24
    seq_len: int = 128          # hard cap on prompt + generation
    gen_lo: int = 2             # max_new_tokens drawn from [gen_lo, gen_hi]
    gen_hi: int = 16
    min_prompt: int = 4
    vocab_size: int = 256
    seed: int = 0

    def to_json(self) -> dict:
        return asdict(self)


def generate(spec: WorkloadSpec) -> List:
    """List of ``core.serve_schedule.Request`` for the spec."""
    from repro.core.serve_schedule import Request
    rng = np.random.default_rng(spec.seed)
    hi = spec.seq_len - spec.gen_hi
    if hi < spec.min_prompt:
        raise ValueError(f"seq_len {spec.seq_len} too small for gen_hi "
                         f"{spec.gen_hi} + min_prompt {spec.min_prompt}")
    # half short, half long prompts, shuffled
    short = rng.integers(spec.min_prompt, max(spec.min_prompt + 1, hi // 4),
                         size=spec.n_requests // 2)
    long_ = rng.integers(max(1, 3 * hi // 4), hi, endpoint=True,
                         size=spec.n_requests - len(short))
    plens = np.concatenate([short, long_])
    rng.shuffle(plens)
    # heavy-tailed generation lengths (the realistic shape): 3/4 short,
    # 1/4 near gen_hi -- a static wave drains at the pace of its slowest
    # member, which is exactly what the tail stresses
    g_short = rng.integers(spec.gen_lo, max(spec.gen_lo + 1, spec.gen_hi // 6),
                           size=3 * spec.n_requests // 4)
    g_long = rng.integers(max(1, 3 * spec.gen_hi // 4), spec.gen_hi,
                          endpoint=True,
                          size=spec.n_requests - len(g_short))
    gens = np.concatenate([g_short, g_long])
    rng.shuffle(gens)
    return [Request(rid=i,
                    prompt=rng.integers(1, spec.vocab_size,
                                        (int(p),)).astype(np.int32),
                    max_new_tokens=int(g))
            for i, (p, g) in enumerate(zip(plens, gens))]
