"""Workloads layer of the benchmark harness: every bench axis as a
declarative ``Workload`` spec -- name, analytic body, timed arms, and
the flat back-compat artifact it owns.

The axis bodies are the same analytic assertions the old monolithic
``benchmarks/run.py`` carried (byte-identical invariants, reduction
factors, bit-exact kernel oracles); moving here changed their plumbing
(a ``RunContext`` instead of module globals, metrics declared next to
the numbers they gate) but not a single assertion.  Each body returns
``(payload, metrics)`` -- or ``(payload, metrics, timing)`` for the
serve axis, which measures its own wall clock -- and the execution
layer assembles the schema-validated artifact document.

Timed arms are declared HERE, next to the axis they belong to, as
``TimedArm(label, SystemConfig kwargs)``: the execution layer turns
each into a warmed-up steady-state step-time measurement when the
driver runs with ``--timed``.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Callable, Tuple

import numpy as np

from benchmarks.harness.execution import RunContext, TimedArm
from benchmarks.harness.results import Metric, metric


@dataclass(frozen=True)
class Workload:
    """One bench axis. ``fn(ctx) -> (payload, [Metric[, timing]])``;
    ``flat`` is the legacy results/<name>.json this axis keeps writing
    for back-compat (None = aggregate-only)."""
    name: str
    fn: Callable
    flat: str = None
    timed_arms: Tuple[TimedArm, ...] = ()


# mixed-axis per-tensor override rules: dense trunk on fcdp, expert
# weights on mics, embedding on hier
_MIXED_RULES = (("blocks.*.moe.we_*", "mics"), ("embed", "hier"))


def axis_comm_smoke(ctx: RunContext):
    """--smoke fast path: a toy (2,2,2) mesh per system mode, walking the
    same collect_collectives/roofline_report pipeline the full comm bench
    uses -- keeps the BENCH_*.json schema honest without the 512-device
    compile. Sweeps the streaming gather scheduler's prefetch_depth
    (0/1/2) so the depth gating of the overlap credit and the per-depth
    in-flight ring-buffer accounting stay exercised in CI."""
    from repro.configs.base import (ModelConfig, RunConfig, ShapeCell,
                                    SystemConfig)
    from repro.core.cache import cache_bytes_per_chip
    from repro.core.engine import StepBundle
    from repro.core.strategy import strategy_names
    from repro.launch.mesh import make_mesh
    from repro.launch.roofline import (collect_collectives,
                                       flops_bytes_from_jaxpr,
                                       roofline_report)
    rows = ctx.rows
    cfg = ModelConfig(name="smoke-dense", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256)
    cell = ShapeCell("t", "train", 64, 8)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    out = []
    roofline_cells = []
    for mode in strategy_names():
        for depth in (0, 1, 2):
            sysc = SystemConfig(mode=mode, min_shard_size=8,
                                prefetch_depth=depth)
            b = StepBundle(RunConfig(model=cfg, shape=cell, system=sysc),
                           mesh)
            step = b.make_train_step()
            closed = step.trace(*b.train_input_sds()).jaxpr
            sizes = {a: b.mi.size(a) for a in b.mi.axis_names}
            stats = collect_collectives(closed, sizes)
            flops, nbytes = flops_bytes_from_jaxpr(closed, 8)
            acct = cache_bytes_per_chip(b)
            live = acct["prefetch_depth"]
            rep = roofline_report(
                flops, nbytes, stats, cfg, cell, 8, prefetch=live,
                inflight_bytes=acct["prefetch_buffer_bytes_per_chip"])
            if depth == 1:
                # one dryrun-shaped cell per mode so CI can smoke the
                # roofline_table --json renderer against real output
                ma = step.lower(*b.train_input_sds()).compile() \
                    .memory_analysis()
                roofline_cells.append({
                    "arch": cfg.name, "cell": cell.name,
                    "multi_pod": True, "mode": mode, "status": "ok",
                    "mode_overrides": [], "n_chips": 8,
                    "memory": {"peak_est_bytes":
                               ma.argument_size_in_bytes
                               + ma.temp_size_in_bytes
                               + ma.output_size_in_bytes
                               - ma.alias_size_in_bytes},
                    "roofline": rep})
            # schema the full benches / EXPERIMENTS tables consume
            for key in ("compute_s", "memory_s", "collective_s", "ici_s",
                        "dcn_s", "dominant", "prefetch", "coll_by_op",
                        "dcn_bytes_per_chip", "ici_bytes_per_chip"):
                assert key in rep, f"roofline schema missing {key}"
            for key in ("depth", "inflight_stage1_bytes_per_chip",
                        "overlapped_dcn_bytes_per_chip", "overlapped_s",
                        "collective_exposed_s"):
                assert key in rep["prefetch"], \
                    f"prefetch schema missing {key}"
            out.append({"system": mode, "prefetch_depth": depth,
                        "depth_live": live,
                        "dcn_bytes": rep["dcn_bytes_per_chip"],
                        "inflight_stage1_bytes":
                            acct["prefetch_buffer_bytes_per_chip"],
                        "overlapped_dcn_bytes":
                            rep["prefetch"]["overlapped_dcn_bytes_per_chip"],
                        "overlapped_s": rep["prefetch"]["overlapped_s"],
                        "collective_exposed_s":
                            rep["prefetch"]["collective_exposed_s"]})
            rows.append((f"smoke/{mode}_d{depth}_dcn_MB",
                         0, rep["dcn_bytes_per_chip"] / 1e6))
            rows.append((f"smoke/{mode}_d{depth}_overlap_us",
                         0, rep["prefetch"]["overlapped_s"] * 1e6))
    # invariants the acceptance gates rely on
    by = {(o["system"], o["prefetch_depth"]): o for o in out}
    for mode in ("fcdp", "zero3", "zeropp"):
        assert by[(mode, 1)]["overlapped_dcn_bytes"] > 0
        # fcdp/zeropp backwards already re-run stage 2 only, so prefetch
        # moves bytes earlier without adding or removing any; zero3's
        # carried cache additionally retires its backward stage-1
        # re-gather, so its DCN volume may only shrink
        if mode == "zero3":
            assert by[(mode, 1)]["dcn_bytes"] <= by[(mode, 0)]["dcn_bytes"]
        else:
            assert abs(by[(mode, 2)]["dcn_bytes"]
                       - by[(mode, 0)]["dcn_bytes"]) < 1e-6 * max(
                           by[(mode, 0)]["dcn_bytes"], 1.0)
        # deeper ring: weakly more overlap credit, k x buffer bytes
        assert (by[(mode, 2)]["overlapped_s"]
                >= by[(mode, 1)]["overlapped_s"])
        assert (by[(mode, 2)]["inflight_stage1_bytes"]
                == 2 * by[(mode, 1)]["inflight_stage1_bytes"] > 0)
    for mode in ("mics", "hier"):
        assert by[(mode, 1)]["overlapped_dcn_bytes"] == 0
        assert by[(mode, 1)]["depth_live"] == 0
    with open(ctx.results_dir / "roofline_smoke.json", "w") as f:
        json.dump(roofline_cells, f, indent=2, default=float)
    metrics = []
    for mode in ("fcdp", "zero3", "zeropp", "mics", "hier"):
        metrics.append(metric(f"{mode}_d1_dcn_bytes",
                              by[(mode, 1)]["dcn_bytes"],
                              direction="lower", noise_band=1e-3,
                              unit="B"))
    for mode in ("fcdp", "zero3", "zeropp"):
        metrics.append(metric(f"{mode}_d1_overlapped_s",
                              by[(mode, 1)]["overlapped_s"],
                              direction="higher", noise_band=1e-3,
                              unit="s"))
    return {"smoke": True, "rows": out}, metrics


def axis_mixed_smoke(ctx: RunContext):
    """--smoke mixed-mode dry-run: a toy MoE cell with the dense trunk
    on fcdp, expert weights on mics, and the embedding on hier, walked
    through the same StepBundle/cache-accounting/roofline pipeline.
    The assertions pin the composite invariants the acceptance gates
    rely on (group sums == totals, the mics group owns no ring bytes,
    the step trains)."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.configs.base import (ModelConfig, MoEConfig, OptimizerConfig,
                                    RunConfig, ShapeCell, SystemConfig)
    from repro.core.cache import cache_bytes_per_chip
    from repro.core.engine import StepBundle
    from repro.launch.mesh import make_mesh
    from repro.launch.roofline import (collect_collectives,
                                       flops_bytes_from_jaxpr,
                                       roofline_report)
    from repro.optim.adamw import init_opt_state
    rows = ctx.rows
    cfg = ModelConfig(name="smoke-moe", family="moe", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=256,
                      moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64))
    cell = ShapeCell("t", "train", 64, 8)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = _MIXED_RULES
    out = []
    for label, overrides, depth in (("fcdp", (), 1),
                                    ("mixed", rules, 1)):
        sysc = SystemConfig(mode="fcdp", mode_overrides=overrides,
                            min_shard_size=8, prefetch_depth=depth)
        run = RunConfig(model=cfg, shape=cell, system=sysc,
                        optimizer=OptimizerConfig(total_steps=4,
                                                  warmup_steps=1))
        b = StepBundle(run, mesh)
        acct = cache_bytes_per_chip(b)
        closed = b.make_train_step().trace(*b.train_input_sds()).jaxpr
        sizes = {a: b.mi.size(a) for a in b.mi.axis_names}
        stats = collect_collectives(closed, sizes)
        flops, nbytes = flops_bytes_from_jaxpr(closed, 8)
        rep = roofline_report(
            flops, nbytes, stats, cfg, cell, 8,
            prefetch=acct["prefetch_depth"],
            inflight_bytes=acct["prefetch_buffer_bytes_per_chip"],
            group_bytes=acct["by_group"])
        # per-group sums must reproduce the flat totals exactly
        groups = acct["by_group"]
        assert abs(sum(g["cached_bytes_per_chip"] for g in groups.values())
                   - acct["cached_bytes_per_chip"]) < 1e-6
        assert abs(sum(g["prefetch_buffer_bytes_per_chip"]
                       for g in groups.values())
                   - acct["prefetch_buffer_bytes_per_chip"]) < 1e-6
        out.append({"label": label, "mode": "fcdp",
                    "mode_overrides": list(map(list, overrides)),
                    "groups": groups,
                    "prefetch_depth": acct["prefetch_depth"],
                    "host_cache_bytes": acct["host_cache_bytes_per_chip"],
                    "dcn_bytes": rep["dcn_bytes_per_chip"],
                    "pod_ag_bytes": stats.by_op_axis.get(
                        "all_gather/pod", 0.0),
                    "ici_bytes": rep["ici_bytes_per_chip"]})
        rows.append((f"mixed_smoke/{label}_dcn_MB", 0,
                     rep["dcn_bytes_per_chip"] / 1e6))
        rows.append((f"mixed_smoke/{label}_host_cache_MB", 0,
                     acct["host_cache_bytes_per_chip"] / 1e6))
    pure, mixed = out[0], out[1]
    assert set(mixed["groups"]) == {"fcdp", "mics", "hier"}
    # single-stage groups own no ring bytes; only the fcdp trunk streams
    assert mixed["groups"]["mics"]["prefetch_buffer_bytes_per_chip"] == 0
    assert mixed["groups"]["hier"]["prefetch_buffer_bytes_per_chip"] == 0
    assert mixed["groups"]["fcdp"]["prefetch_buffer_bytes_per_chip"] > 0
    # experts-on-mics retires exactly the experts' pod-axis all-gathers
    # (their gradients cross pods as a psum instead, so TOTAL DCN volume
    # is a wash vs fcdp's fwd-AG + reduce-scatter -- the mics trade is
    # the schedule, not the byte count)
    assert mixed["pod_ag_bytes"] < pure["pod_ag_bytes"]
    assert mixed["dcn_bytes"] <= pure["dcn_bytes"] * 1.05
    # the experts left the host-cache tier entirely
    assert mixed["host_cache_bytes"] < pure["host_cache_bytes"]
    # and one mixed train step actually runs
    sysc = SystemConfig(mode="fcdp", mode_overrides=rules, min_shard_size=8)
    run = RunConfig(model=cfg, shape=cell, system=sysc,
                    optimizer=OptimizerConfig(total_steps=4, warmup_steps=1))
    b = StepBundle(run, mesh)
    params = b.init_all_params(seed=0)
    tp, fp = b.split(params)
    opt = jax.jit(functools.partial(init_opt_state, sys=sysc))(tp)
    rng = np.random.default_rng(0)
    batch = {"ids": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
             "mask": jnp.ones((8, 64), bool)}
    _, _, m = b.make_train_step()(tp, fp, opt, batch)
    assert np.isfinite(float(m["loss"]))
    metrics = [
        metric("dcn_ratio_mixed_vs_pure",
               mixed["dcn_bytes"] / pure["dcn_bytes"],
               direction="lower", noise_band=0.05),
        metric("host_cache_ratio_mixed_vs_pure",
               mixed["host_cache_bytes"] / pure["host_cache_bytes"],
               direction="lower", noise_band=0.02),
        metric("pod_ag_ratio_mixed_vs_pure",
               mixed["pod_ag_bytes"] / pure["pod_ag_bytes"],
               direction="lower", noise_band=0.02),
    ]
    return {"smoke": True, "loss": float(m["loss"]), "rows": out}, metrics


def axis_xstep_smoke(ctx: RunContext):
    """--smoke cross-step axis: the same toy dense cell traced with the
    cross-step optimizer pipeline (stream 3) off/on, plus a 2-step
    training run on each schedule. Pins the acceptance invariants: the
    per-step DCN volume of the steady-state piped step is byte-identical
    to the fused step (the epilogue collectives move, they are not
    added), the step-boundary carry is accounted nonzero only when the
    stream is live, and losses are bit-identical across the two
    schedules."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                    ShapeCell, SystemConfig)
    from repro.core.cache import cache_bytes_per_chip
    from repro.core.engine import StepBundle
    from repro.launch.mesh import make_mesh
    from repro.launch.roofline import collect_collectives
    from repro.optim.adamw import init_opt_state
    rows = ctx.rows
    cfg = ModelConfig(name="smoke-dense", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256)
    cell = ShapeCell("t", "train", 64, 8)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    batches = [{"ids": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
                "labels": jnp.asarray(rng.integers(1, 256, (8, 64)),
                                      jnp.int32),
                "mask": jnp.ones((8, 64), bool)} for _ in range(2)]
    out = []
    for xstep in (False, True):
        sysc = SystemConfig(mode="fcdp", min_shard_size=8,
                            async_grad_reduce=True,
                            cross_step_pipeline=xstep)
        run = RunConfig(model=cfg, shape=cell, system=sysc,
                        optimizer=OptimizerConfig(total_steps=4,
                                                  warmup_steps=1),
                        microbatch=2)
        b = StepBundle(run, mesh)
        acct = cache_bytes_per_chip(b)
        closed = b.make_train_step().trace(*b.train_input_sds()).jaxpr
        sizes = {a: b.mi.size(a) for a in b.mi.axis_names}
        stats = collect_collectives(closed, sizes)
        params = b.init_all_params(seed=0)
        tp, fp = b.split(params)
        opt = jax.jit(functools.partial(init_opt_state, sys=sysc))(tp)
        if xstep:
            carry, m0 = b.make_train_prime()(tp, fp, opt, batches[0])
            tp, opt, carry, m1 = b.make_train_step()(tp, fp, opt, carry,
                                                     batches[1])
            tp, opt, _ = b.make_train_flush()(tp, opt, carry)
        else:
            step = b.make_train_step()
            tp, opt, m0 = step(tp, fp, opt, batches[0])
            tp, opt, m1 = step(tp, fp, opt, batches[1])
        out.append({"cross_step": xstep,
                    "cross_step_live": acct["cross_step"],
                    "cross_step_buffer_bytes":
                        acct["cross_step_buffer_bytes_per_chip"],
                    "dcn_bytes": stats.dcn_bytes,
                    "pod_ag_bytes": stats.by_op_axis.get(
                        "all_gather/pod", 0.0),
                    "pod_rs_bytes": stats.by_op_axis.get(
                        "psum_scatter/pod", 0.0),
                    "losses": [float(m0["loss"]), float(m1["loss"])],
                    "params_sum": float(sum(
                        jnp.sum(jnp.asarray(x, jnp.float32))
                        for x in tp))})
        rows.append((f"xstep_smoke/{'on' if xstep else 'off'}_dcn_MB", 0,
                     stats.dcn_bytes / 1e6))
        rows.append((f"xstep_smoke/{'on' if xstep else 'off'}_carry_MB", 0,
                     acct["cross_step_buffer_bytes_per_chip"] / 1e6))
    off, on = out
    # the collective moves, it is not added: steady-state DCN volume is
    # byte-identical per op, and the carry is the only new memory
    assert abs(on["dcn_bytes"] - off["dcn_bytes"]) \
        < 1e-6 * max(off["dcn_bytes"], 1.0)
    assert abs(on["pod_rs_bytes"] - off["pod_rs_bytes"]) \
        < 1e-6 * max(off["pod_rs_bytes"], 1.0)
    assert on["cross_step_live"] and on["cross_step_buffer_bytes"] > 0
    assert not off["cross_step_live"] and \
        off["cross_step_buffer_bytes"] == 0
    # staleness-free pipelining: bit-identical losses and updated params
    assert on["losses"] == off["losses"]
    assert on["params_sum"] == off["params_sum"]
    metrics = [
        metric("dcn_ratio_on_vs_off",
               on["dcn_bytes"] / max(off["dcn_bytes"], 1.0),
               direction="lower", noise_band=1e-6),
        metric("carry_bytes_on", on["cross_step_buffer_bytes"],
               direction="lower", noise_band=1e-3, unit="B"),
        metric("losses_bit_identical",
               1.0 if on["losses"] == off["losses"] else 0.0,
               direction="higher", noise_band=0.0),
    ]
    return {"smoke": True, "rows": out}, metrics


def axis_restart_smoke(ctx: RunContext):
    """--smoke crash-resume axis: drive the REAL launch driver (prime/
    piped/flush + checkpoint/restart) twice on the toy multi-pod mesh --
    once uninterrupted, once with a FailureInjector crash at a piped
    step past the last checkpoint -- and assert the restarted run's
    per-step losses and final params are bit-identical to the
    uninterrupted trace (the carry rides the manifest-v2 checkpoint, so
    nothing is lost or double-applied)."""
    import tempfile
    from repro.launch.train import main as train_main
    rows = ctx.rows

    def drive(ckpt_dir, fail_at):
        argv = ["--arch", "gemma-2b", "--smoke", "--multi-pod",
                "--steps", "6", "--batch", "8", "--seq-len", "64",
                "--lr", "1e-3", "--microbatch", "2",
                "--async-grad-reduce", "--cross-step-pipeline",
                "--ckpt-dir", ckpt_dir, "--ckpt-every", "2"]
        if fail_at:
            argv += ["--fail-at", str(fail_at)]
        st = train_main(argv)
        per_step = {}
        for row in st.metrics_log:      # last occurrence wins (replays)
            if "step" in row:
                per_step[row["step"]] = row["loss"]
        return per_step, float(sum(
            np.asarray(x, np.float64).sum() for x in st.train_p))

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        clean_losses, clean_sum = drive(d1, None)
        crash_losses, crash_sum = drive(d2, 3)   # past the step-2 ckpt
    assert crash_losses == clean_losses, (clean_losses, crash_losses)
    assert crash_sum == clean_sum
    for s in sorted(clean_losses):
        rows.append((f"restart_smoke/step{s}_loss", 0, clean_losses[s]))
    last = clean_losses[max(clean_losses)]
    metrics = [
        metric("bit_identical", 1.0, direction="higher", noise_band=0.0),
        metric("final_loss", last, direction="lower", noise_band=1e-6),
    ]
    payload = {"smoke": True, "fail_at": 3,
               "losses_clean": clean_losses,
               "losses_resumed": crash_losses,
               "params_sum_clean": clean_sum,
               "params_sum_resumed": crash_sum,
               "bit_identical": True}
    return payload, metrics


def axis_quant_smoke(ctx: RunContext):
    """--smoke quantized-collectives (qwZ) axis: the toy dense cell traced
    with the stage-1 weight all-gather exact (bf16) vs int8-transported
    (``param_compress='int8_pod'``), plus the zero3 baseline whose
    backward re-gathers stage 1. Pins the acceptance invariants:

      * same-config reduction: fcdp bf16 / fcdp int8 stage-1 DCN
        all-gather bytes >= 1.9x (int8 + f32-scale wire cost is
        (1 + 4/256) B/elem vs 2 B/elem bf16; sub-block leaves keep the
        exact path, see strategy.QUANT_MIN_SHARD_ELEMS);
      * stacked reduction: zero3 bf16 (fwd+bwd stage-1 gathers) /
        fcdp int8 (single quantized fwd gather, host-cached for the
        backward) >= 3.5x -- FCDP caching and qwZ compose;
      * bounded loss drift: 3 training steps int8 vs exact, max
        relative drift < 1e-2 (measured ~4e-5 on this cell);
      * the Pallas quant kernels (interpret mode) are bit-exact against
        the jnp oracles on random data."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                    ShapeCell, SystemConfig)
    from repro.core.cache import cache_bytes_per_chip
    from repro.core.engine import StepBundle
    from repro.launch.mesh import make_mesh
    from repro.launch.roofline import collect_collectives
    from repro.optim.adamw import init_opt_state
    rows = ctx.rows
    # 4 layers so the per-layer stage-1 gathers (the part zero3 pays
    # twice and qwZ compresses) dominate the once-per-step embed/head
    # gathers in the stacked ratio
    cfg = ModelConfig(name="smoke-dense", family="dense", num_layers=4,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256)
    cell = ShapeCell("t", "train", 64, 8)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    batches = [{"ids": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
                "labels": jnp.asarray(rng.integers(1, 256, (8, 64)),
                                      jnp.int32),
                "mask": jnp.ones((8, 64), bool)} for _ in range(3)]

    def measure(mode, param_compress):
        sysc = SystemConfig(mode=mode, min_shard_size=8,
                            param_compress=param_compress)
        run = RunConfig(model=cfg, shape=cell, system=sysc,
                        optimizer=OptimizerConfig(total_steps=4,
                                                  warmup_steps=1))
        b = StepBundle(run, mesh)
        acct = cache_bytes_per_chip(b)
        step = b.make_train_step()
        closed = step.trace(*b.train_input_sds()).jaxpr
        sizes = {a: b.mi.size(a) for a in b.mi.axis_names}
        stats = collect_collectives(closed, sizes)
        params = b.init_all_params(seed=0)
        tp, fp = b.split(params)
        opt = jax.jit(functools.partial(init_opt_state, sys=sysc))(tp)
        losses = []
        for batch in batches:
            tp, opt, m = step(tp, fp, opt, batch)
            losses.append(float(m["loss"]))
        return {"mode": mode, "param_compress": param_compress,
                "pod_ag_bytes": stats.by_op_axis.get("all_gather/pod", 0.0),
                "dcn_bytes": stats.dcn_bytes,
                "stage1_dcn_analytic": acct[
                    "stage1_dcn_gather_bytes_per_chip"],
                "stage1_dcn_analytic_exact": acct[
                    "stage1_dcn_gather_bytes_exact"],
                "losses": losses}

    fcdp_bf16 = measure("fcdp", "none")
    fcdp_int8 = measure("fcdp", "int8_pod")
    zero3_bf16 = measure("zero3", "none")
    same_config = fcdp_bf16["pod_ag_bytes"] / fcdp_int8["pod_ag_bytes"]
    stacked = zero3_bf16["pod_ag_bytes"] / fcdp_int8["pod_ag_bytes"]
    drift = max(abs(a - b) / abs(b) for a, b in
                zip(fcdp_int8["losses"], fcdp_bf16["losses"]))
    # kernel-vs-oracle bit-exactness (interpret-mode Pallas on CPU CI)
    from repro.kernels import ops as kops, ref as kref
    x = jnp.asarray(rng.standard_normal((7, 256)), jnp.float32)
    qk, sk = kops.int8_quantize_blocks(x, impl="pallas", interpret=True)
    qr, sr = kref.int8_quantize_blocks_ref(x)
    kernels_exact = (bool(jnp.array_equal(qk, qr))
                     and bool(jnp.array_equal(sk, sr))
                     and bool(jnp.array_equal(
                         kops.int8_dequantize_blocks(qk, sk, impl="pallas",
                                                     interpret=True),
                         kref.int8_dequantize_blocks_ref(qr, sr))))
    assert kernels_exact
    assert same_config >= 1.9, same_config
    assert stacked >= 3.5, stacked
    assert drift < 1e-2, drift
    # the plan-tree analytic accounting matches the traced jaxpr bytes
    for m in (fcdp_bf16, fcdp_int8):
        np.testing.assert_allclose(m["stage1_dcn_analytic"],
                                   m["pod_ag_bytes"], rtol=0.05)
    rows.append(("quant_smoke/same_config_reduction_x", 0, same_config))
    rows.append(("quant_smoke/stacked_reduction_x", 0, stacked))
    rows.append(("quant_smoke/loss_drift_rel", 0, drift))
    metrics = [
        metric("same_config_reduction_x", same_config,
               direction="higher", noise_band=1e-3, unit="x"),
        metric("stacked_reduction_x", stacked,
               direction="higher", noise_band=1e-3, unit="x"),
        metric("loss_drift_rel", drift, direction="lower",
               noise_band=1.0),
        metric("kernels_bit_exact", 1.0, direction="higher",
               noise_band=0.0),
    ]
    payload = {"smoke": True, "kernels_bit_exact": kernels_exact,
               "same_config_reduction_x": same_config,
               "stacked_reduction_x": stacked,
               "loss_drift_rel": drift, "drift_bound": 1e-2,
               "rows": [fcdp_bf16, fcdp_int8, zero3_bf16]}
    return payload, metrics


def axis_fused_smoke(ctx: RunContext):
    """--smoke gather-fused collective-matmul axis: the toy dense cell
    traced with the output projections consuming stage-2 shards as they
    arrive (``fused_matmul='ag_matmul'``) vs the unfused
    all-gather-then-matmul baseline. Pins the acceptance invariants:

      * bit-identical losses: the ring computes the same column-concat
        decomposition, so 3 training steps fused vs unfused match
        EXACTLY (not allclose) for fcdp and zero3;
      * strictly lower exposed collective time: the measured per-chunk
        overlap credit (roofline ``fused.credit_applied_s``, derived
        from the kernel's own chunk schedule) pushes
        ``collective_exposed_s`` strictly below the unfused arm at
        prefetch_depth=1;
      * the ``both`` mode (dual grad rings) stays within a loose drift
        bound of the baseline -- its backward re-associates the bf16
        reduction, so it is exact against its own oracle, not the
        unfused jaxpr;
      * the Pallas per-chunk matmul (interpret mode) is bit-exact
        against the jnp oracle, including non-divisible block shapes."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                    ShapeCell, SystemConfig)
    from repro.core.cache import cache_bytes_per_chip
    from repro.core.engine import StepBundle
    from repro.launch.mesh import make_mesh
    from repro.launch.roofline import (collect_collectives,
                                       flops_bytes_from_jaxpr,
                                       fused_overlap_credit,
                                       roofline_report)
    from repro.optim.adamw import init_opt_state
    rows = ctx.rows
    cfg = ModelConfig(name="smoke-dense", family="dense", num_layers=4,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256)
    cell = ShapeCell("t", "train", 64, 8)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    batches = [{"ids": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
                "labels": jnp.asarray(rng.integers(1, 256, (8, 64)),
                                      jnp.int32),
                "mask": jnp.ones((8, 64), bool)} for _ in range(3)]

    def measure(mode, fused):
        sysc = SystemConfig(mode=mode, min_shard_size=8, prefetch_depth=1,
                            fused_matmul=fused)
        run = RunConfig(model=cfg, shape=cell, system=sysc,
                        optimizer=OptimizerConfig(total_steps=4,
                                                  warmup_steps=1))
        b = StepBundle(run, mesh)
        step = b.make_train_step()
        closed = step.trace(*b.train_input_sds()).jaxpr
        sizes = {a: b.mi.size(a) for a in b.mi.axis_names}
        stats = collect_collectives(closed, sizes)
        flops, nbytes = flops_bytes_from_jaxpr(closed, 8)
        acct = cache_bytes_per_chip(b)
        credit = fused_overlap_credit(b.def_leaves, b.plan_leaves, sizes,
                                      cell, tp=b.mi.tp)
        rep = roofline_report(
            flops, nbytes, stats, cfg, cell, 8,
            prefetch=acct["prefetch_depth"],
            inflight_bytes=acct["prefetch_buffer_bytes_per_chip"],
            fused=credit)
        params = b.init_all_params(seed=0)
        tp, fp = b.split(params)
        opt = jax.jit(functools.partial(init_opt_state, sys=sysc))(tp)
        losses = []
        for batch in batches:
            tp, opt, m = step(tp, fp, opt, batch)
            losses.append(float(m["loss"]))
        return {"mode": mode, "fused_matmul": fused,
                "n_fused_leaves": credit["n_fused_leaves"],
                "fused_credit_s": credit["credit_s"],
                "fused_credit_applied_s": rep["fused"]["credit_applied_s"],
                "ici_bytes": rep["ici_bytes_per_chip"],
                "collective_exposed_s":
                    rep["prefetch"]["collective_exposed_s"],
                "losses": losses}

    arms = {(m, f): measure(m, f)
            for m in ("fcdp", "zero3")
            for f in ("none", "ag_matmul")}
    both = measure("fcdp", "both")
    for m in ("fcdp", "zero3"):
        off, on = arms[(m, "none")], arms[(m, "ag_matmul")]
        assert off["n_fused_leaves"] == 0
        assert on["n_fused_leaves"] > 0, m
        # the ring is the same column-concat decomposition, so fusing
        # must not change a single bit of the training trajectory
        assert on["losses"] == off["losses"], (m, on["losses"],
                                               off["losses"])
        # the swap is byte-neutral (ppermute moves the same (n-1)/n of
        # the weight the tiled all-gather did) ...
        np.testing.assert_allclose(on["ici_bytes"], off["ici_bytes"],
                                   rtol=1e-6)
        # ... so a positive measured credit means strictly less exposed
        # collective time on the critical path
        assert on["fused_credit_applied_s"] > 0, m
        assert (on["collective_exposed_s"]
                < off["collective_exposed_s"]), m
    drift = max(abs(a - b) / abs(b) for a, b in
                zip(both["losses"], arms[("fcdp", "none")]["losses"]))
    assert drift < 5e-2, drift
    # per-chunk Pallas matmul (interpret mode) vs jnp oracle, including
    # shapes that do not divide the 128x128 block
    from repro.kernels import collective_matmul as cm, ref as kref
    kernels_exact = True
    for (M, K, N) in ((7, 96, 100), (128, 64, 128), (130, 32, 257)):
        x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        got = cm.matmul_chunk(x, w, interpret=True)
        kernels_exact &= bool(jnp.array_equal(
            got, kref.matmul_chunk_ref(x, w)))
    assert kernels_exact
    delta = (arms[("fcdp", "none")]["collective_exposed_s"]
             - arms[("fcdp", "ag_matmul")]["collective_exposed_s"])
    rows.append(("fused_smoke/fcdp_exposed_delta_us", 0, delta * 1e6))
    rows.append(("fused_smoke/fcdp_n_fused_leaves", 0,
                 arms[("fcdp", "ag_matmul")]["n_fused_leaves"]))
    rows.append(("fused_smoke/both_loss_drift_rel", 0, drift))
    metrics = [
        metric("fcdp_exposed_delta_s", delta, direction="higher",
               noise_band=1e-3, unit="s"),
        metric("fcdp_n_fused_leaves",
               arms[("fcdp", "ag_matmul")]["n_fused_leaves"],
               direction="higher", noise_band=0.0),
        metric("both_loss_drift_rel", drift, direction="lower",
               noise_band=1.0),
        metric("losses_bit_identical", 1.0, direction="higher",
               noise_band=0.0),
        metric("kernels_bit_exact", 1.0, direction="higher",
               noise_band=0.0),
    ]
    payload = {"smoke": True, "kernels_bit_exact": kernels_exact,
               "losses_bit_identical": True,
               "both_loss_drift_rel": drift, "drift_bound": 5e-2,
               "rows": [arms[("fcdp", "none")], arms[("fcdp", "ag_matmul")],
                        arms[("zero3", "none")],
                        arms[("zero3", "ag_matmul")], both]}
    return payload, metrics


def axis_serve_smoke(ctx: RunContext):
    """--smoke continuous-batching serve axis: the toy dense cell served
    twice through the SAME jitted paged-KV steps -- once with continuous
    admission (admit/retire every scheduler tick, chunked prefill), once
    with the wait-for-full-batch static baseline -- on the identical
    mixed-length workload. Request throughput plus TTFT/TPOT/ITL
    percentiles are measured wall clock, not modeled. Pins the
    acceptance invariants:

      * continuous batching achieves STRICTLY higher request throughput
        than static batching on the mixed-length workload;
      * all timed metrics are present and positive (axis-specific
        validator registered by serve_results with the shared results
        layer);
      * the paged KV pools are byte-accounted as a MemoryPlanner tenant
        (kv_page_bytes_per_chip > 0 and == the analytic pool size)."""
    from repro.configs.base import (ModelConfig, RunConfig, ShapeCell,
                                    SystemConfig)
    from repro.core.cache import cache_bytes_per_chip
    from repro.core.engine import StepBundle
    from repro.core.engine.serve import default_paged_kv
    from repro.core.serve_schedule import PagedServeEngine, summarize
    from repro.launch.mesh import make_mesh
    from benchmarks import serve_results, serve_workload
    rows = ctx.rows

    cfg = ModelConfig(name="smoke-dense", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256)
    cell = ShapeCell("serve", "decode", 128, 8)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    run = RunConfig(model=cfg, shape=cell,
                    system=SystemConfig(min_shard_size=8))
    bundle = StepBundle(run, mesh)
    params = bundle.init_all_params(seed=0)
    kv = default_paged_kv(bundle, cell)

    # planner-tenant accounting: pool bytes land in the totals
    acct = cache_bytes_per_chip(bundle, kv=kv)
    from repro.core.kv_cache import kv_page_bytes_per_chip
    analytic = kv_page_bytes_per_chip(cfg, bundle.mi, bundle.model.plan,
                                      bundle.model.n_groups, kv)
    assert acct["kv_page_bytes_per_chip"] == analytic > 0

    spec = serve_workload.WorkloadSpec(n_requests=32, seq_len=128,
                                       gen_lo=2, gen_hi=48,
                                       vocab_size=256, seed=0)
    cont = PagedServeEngine(bundle, kv, chunk=32, policy="continuous")
    stat = PagedServeEngine(bundle, kv, chunk=32, policy="static",
                            share_steps_with=cont)
    # warm the shared compile cache outside the timed region
    warm = serve_workload.generate(serve_workload.WorkloadSpec(
        n_requests=2, seq_len=128, gen_lo=2, gen_hi=2, vocab_size=256,
        seed=7))
    cont.serve(params, warm)

    arms = {}
    for name, eng in (("continuous", cont), ("static", stat)):
        results_, wall = eng.serve(params, serve_workload.generate(spec))
        assert len(results_) == spec.n_requests
        arms[name] = summarize(results_, wall)
        rows.append((f"serve_smoke/{name}_rps", wall * 1e6,
                     arms[name]["throughput_rps"]))
        rows.append((f"serve_smoke/{name}_ttft_p50_ms", 0,
                     arms[name]["ttft_s"]["p50"] * 1e3))
        rows.append((f"serve_smoke/{name}_itl_p50_ms", 0,
                     arms[name]["itl_s"]["p50"] * 1e3))
    ratio = (arms["continuous"]["throughput_rps"]
             / arms["static"]["throughput_rps"])
    rows.append(("serve_smoke/continuous_vs_static_x", 0, ratio))

    payload = serve_results.make_payload(
        spec.to_json(),
        {"page_size": kv.page_size,
         "pages_per_replica": kv.pages_per_replica,
         "max_pages_per_seq": kv.max_pages_per_seq,
         "kv_page_bytes_per_chip": acct["kv_page_bytes_per_chip"]},
        arms)
    metrics = [
        metric("continuous_vs_static_x", ratio, kind="timed",
               direction="higher", noise_band=0.35, unit="x"),
        metric("continuous_rps", arms["continuous"]["throughput_rps"],
               kind="timed", direction="higher", noise_band=0.6,
               unit="req/s"),
        metric("kv_page_bytes_per_chip", acct["kv_page_bytes_per_chip"],
               direction="lower", noise_band=1e-3, unit="B"),
    ]
    # the serve axis measures its own wall clock: the timing block is
    # the per-token inter-token latency distribution of each policy
    timing = {"timed": True, "source": "itl_s",
              "arms": {name: {"median_s": a["itl_s"]["p50"],
                              "p90_s": a["itl_s"]["p90"],
                              "mean_s": a["itl_s"]["mean"],
                              "n": a["requests"]}
                       for name, a in arms.items()}}
    return payload, metrics, timing


def axis_peft_smoke(ctx: RunContext):
    """--smoke PEFT end-to-end axis (the paper's §IV-E/§V-D headline,
    carried by the residency layer): a LoRA fine-tune with TRAINED steps
    -- not just analytic roofline bytes -- where the frozen trunk is
    permanently pod-replicated/host-cached with zero steady-state DCN
    traffic and only the adapters cross DCN. Pins the acceptance
    invariants:

      * >=99% stage-1 (DCN all_gather/pod) byte reduction vs the zero3
        baseline, measured from the TRACED train-step jaxpr of the same
        LoRA workload (zero3 re-gathers the frozen trunk over DCN every
        step -- the DeepSpeed baseline asymmetry the residency layer
        makes structural: its frozen leaves stay 'dcn_sharded', fcdp's
        become 'pod_replicated' with an empty stage 1);
      * the traced adapter-only DCN bytes match cache.py's plan-tree
        analytic accounting (the residency emission and the jaxpr
        agree);
      * adapter-only updates are BIT-IDENTICAL to the all-trainable
        reference on the adapter leaves after one step (freezing the
        trunk changes where bytes live, never a single bit of the
        adapters' trajectory);
      * a mixed composite bundle (frozen trunk fcdp + trainable
        adapters under zero3 via mode_overrides) trains and keeps the
        >=99% reduction;
      * 3 trained steps produce finite losses and actually move the
        adapters (lora_b leaves leave their zero init).

    The toy is sized UP from the other smoke axes (d_model=256,
    d_ff=1024) so the trunk/adapter ratio supports the 99% claim at
    lora_rank=2 -- at d_model=64 the adapters are ~8% of the trunk and
    the bound is unreachable no matter how good the system is."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                    ShapeCell, SystemConfig)
    from repro.core.cache import cache_bytes_per_chip
    from repro.core.engine import StepBundle
    from repro.core.peft import unfreeze_all
    from repro.core.residency import residency_of
    from repro.launch.mesh import make_mesh
    from repro.launch.roofline import collect_collectives
    from repro.optim.adamw import init_opt_state
    rows = ctx.rows
    cfg = ModelConfig(name="smoke-dense-peft", family="dense", num_layers=2,
                      d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
                      vocab_size=256)
    cell = ShapeCell("t", "train", 64, 8)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    batches = [{"ids": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
                "labels": jnp.asarray(rng.integers(1, 256, (8, 64)),
                                      jnp.int32),
                "mask": jnp.ones((8, 64), bool)} for _ in range(3)]

    def measure(mode, overrides=(), defs_fn=None, steps=3):
        sysc = SystemConfig(mode=mode, min_shard_size=8, peft=True,
                            lora_rank=2, mode_overrides=overrides)
        # grad_clip is set far above any toy gnorm so the clip scale is
        # exactly 1.0 in every arm: global-norm clipping couples the
        # adapters' update to the TRUNK grads' norm, which would break
        # the bit-identity claim against the all-trainable reference
        # for a reason that has nothing to do with the residency layer
        run = RunConfig(model=cfg, shape=cell, system=sysc,
                        optimizer=OptimizerConfig(total_steps=8,
                                                  warmup_steps=1,
                                                  grad_clip=1e9))
        b = StepBundle(run, mesh, defs_fn=defs_fn)
        step = b.make_train_step()
        closed = step.trace(*b.train_input_sds()).jaxpr
        sizes = {a: b.mi.size(a) for a in b.mi.axis_names}
        stats = collect_collectives(closed, sizes)
        acct = cache_bytes_per_chip(b)
        params = b.init_all_params(seed=0)
        tp, fp = b.split(params)
        opt = jax.jit(functools.partial(init_opt_state, sys=sysc))(tp)
        losses, adapters_after_1 = [], None
        for k, batch in enumerate(batches[:steps]):
            tp, opt, m = step(tp, fp, opt, batch)
            losses.append(float(m["loss"]))
            if k == 0:
                adapters_after_1 = [np.asarray(x) for x in tp]
        return {"bundle": b, "mode": mode,
                "pod_ag_bytes": stats.by_op_axis.get("all_gather/pod", 0.0),
                "dcn_bytes": stats.dcn_bytes,
                "stage1_dcn_analytic": acct[
                    "stage1_dcn_gather_bytes_per_chip"],
                "host_cache_bytes": acct["host_cache_bytes_per_chip"],
                "groups": acct["by_group"],
                "losses": losses, "tp": tp,
                "adapters_after_1": adapters_after_1}

    fcdp = measure("fcdp")
    zero3 = measure("zero3")
    ref = measure("fcdp", defs_fn=unfreeze_all, steps=1)
    mixed = measure("fcdp", overrides=(("*lora*", "zero3"),))

    bp, bz = fcdp["bundle"], zero3["bundle"]
    # residency asymmetry the byte claim rests on: fcdp's frozen trunk
    # leaves DCN entirely (no ring slot), zero3's stays dcn-sharded
    trunk_res = [residency_of(bp.plan_leaves[i]) for i in bp.frozen_idx]
    assert all(r.tier != "dcn_sharded" and not r.occupies_ring_slot
               and r.update == "frozen_cached" for r in trunk_res)
    z_trunk = [residency_of(bz.plan_leaves[i]) for i in bz.frozen_idx]
    assert any(r.tier == "dcn_sharded" and r.occupies_ring_slot
               for r in z_trunk)
    # trainable fraction: the workload is a real PEFT shape
    n_t = sum(bp.def_leaves[i].size() for i in bp.train_idx)
    n_all = sum(d.size() for d in bp.def_leaves)
    frac_pct = 100.0 * n_t / n_all
    assert frac_pct < 1.0, frac_pct

    # >=99% stage-1 (DCN) reduction, traced bytes, trained workload
    red_pct = 100.0 * (1 - fcdp["pod_ag_bytes"] / zero3["pod_ag_bytes"])
    assert red_pct >= 99.0, red_pct
    red_mixed_pct = 100.0 * (1 - mixed["pod_ag_bytes"]
                             / zero3["pod_ag_bytes"])
    assert red_mixed_pct >= 99.0, red_mixed_pct
    # traced adapter-only bytes == the plan-tree analytic accounting
    np.testing.assert_allclose(fcdp["stage1_dcn_analytic"],
                               fcdp["pod_ag_bytes"], rtol=0.05)
    # the frozen trunk parks in the host cache tier
    assert fcdp["host_cache_bytes"] > 0

    # bit-identity: adapter leaves after 1 step match the all-trainable
    # reference EXACTLY (ref trains every leaf; its flat train list is
    # all leaves, so index it by the peft bundle's trainable positions)
    assert len(ref["bundle"].train_idx) == len(ref["bundle"].def_leaves)
    adapters_ok = all(
        np.array_equal(a, np.asarray(ref["tp"][i]))
        for a, i in zip(fcdp["adapters_after_1"], bp.train_idx))
    assert adapters_ok

    # mixed composite: adapters resolved into their own zero3 group
    assert set(mixed["groups"]) == {"fcdp", "zero3"}
    # trained steps: finite losses, adapters left their zero init
    for m in (fcdp, zero3, mixed):
        assert all(np.isfinite(m["losses"])), m["losses"]
    moved = any(float(jnp.max(jnp.abs(jnp.asarray(x, jnp.float32)))) > 0
                for x, i in zip(fcdp["tp"], bp.train_idx)
                if "_lora_b" in str(bp.def_leaves[i].label or ""))
    if not moved:   # labels may be unset on some trees: fall back to
        moved = any(np.max(np.abs(np.asarray(a)
                                  - np.asarray(x))) > 0
                    for a, x in zip(fcdp["adapters_after_1"], fcdp["tp"]))
    assert moved

    rows.append(("peft_smoke/dcn_reduction_pct", 0, red_pct))
    rows.append(("peft_smoke/mixed_dcn_reduction_pct", 0, red_mixed_pct))
    rows.append(("peft_smoke/trainable_frac_pct", 0, frac_pct))
    rows.append(("peft_smoke/fcdp_host_cache_MB", 0,
                 fcdp["host_cache_bytes"] / 1e6))
    metrics = [
        metric("peft_dcn_reduction_pct", red_pct, direction="higher",
               noise_band=1e-3, unit="%"),
        metric("mixed_peft_dcn_reduction_pct", red_mixed_pct,
               direction="higher", noise_band=1e-3, unit="%"),
        metric("trainable_frac_pct", frac_pct, direction="lower",
               noise_band=1e-6, unit="%"),
        metric("adapters_bit_identical", 1.0, direction="higher",
               noise_band=0.0),
    ]

    def row(m):
        return {"mode": m["mode"], "pod_ag_bytes": m["pod_ag_bytes"],
                "dcn_bytes": m["dcn_bytes"],
                "stage1_dcn_analytic": m["stage1_dcn_analytic"],
                "host_cache_bytes": m["host_cache_bytes"],
                "losses": m["losses"]}
    payload = {"smoke": True, "trained_steps": 3,
               "lora_rank": 2, "trainable_frac_pct": frac_pct,
               "peft_dcn_reduction_pct": red_pct,
               "mixed_peft_dcn_reduction_pct": red_mixed_pct,
               "reduction_bound_pct": 99.0,
               "adapters_bit_identical": True,
               "rows": [row(fcdp), row(zero3), row(mixed)]}
    return payload, metrics


def axis_kernels(ctx: RunContext):
    """Pallas kernels vs jnp oracle: allclose + interpret-mode timing."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rows = ctx.rows
    rng = np.random.default_rng(0)
    out = []
    metrics = []
    B, S, H, hd = 2, 256, 4, 64
    q, k, v = (jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), jnp.float32)
               for _ in range(3))
    t0 = time.time()
    o1 = ops.flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    t1 = (time.time() - t0) * 1e6
    err = float(jnp.max(jnp.abs(o1 - ref.attention_ref(q, k, v))))
    out.append({"kernel": "flash_attention", "max_err": err})
    rows.append(("kernels/flash_attention_err", t1, err))
    metrics.append(metric("flash_attention_max_err", err,
                          direction="lower", noise_band=1.0))

    r = jnp.asarray(rng.normal(0, 1, (B, S, 2, 16)), jnp.float32)
    kk = jnp.asarray(rng.normal(0, 1, (B, S, 2, 16)), jnp.float32)
    vv = jnp.asarray(rng.normal(0, 1, (B, S, 2, 16)), jnp.float32)
    lw = -jnp.exp(jnp.asarray(rng.normal(-0.5, 1, (B, S, 2, 16)),
                              jnp.float32))
    u = jnp.asarray(rng.normal(0, 1, (2, 16)), jnp.float32)
    t0 = time.time()
    ow, _ = ops.wkv6(r, kk, vv, lw, u, chunk=32, interpret=True)
    t1 = (time.time() - t0) * 1e6
    eo, _ = ref.rwkv6_ref(r, kk, vv, lw, u)
    err = float(jnp.max(jnp.abs(ow - eo)))
    out.append({"kernel": "wkv6", "max_err": err})
    rows.append(("kernels/wkv6_err", t1, err))
    metrics.append(metric("wkv6_max_err", err, direction="lower",
                          noise_band=1.0))

    a = jnp.asarray(rng.uniform(0.3, 0.99, (B, S, 64)), jnp.float32)
    bb = jnp.asarray(rng.normal(0, 1, (B, S, 64)), jnp.float32)
    t0 = time.time()
    hs = ops.ssm_scan(a, bb, chunk=64, channel_block=32, interpret=True)
    t1 = (time.time() - t0) * 1e6
    eh, _ = ref.mamba_scan_ref(a[..., None], bb[..., None])
    err = float(jnp.max(jnp.abs(hs - eh[..., 0])))
    out.append({"kernel": "ssm_scan", "max_err": err})
    rows.append(("kernels/ssm_scan_err", t1, err))
    metrics.append(metric("ssm_scan_max_err", err, direction="lower",
                          noise_band=1.0))
    return {"kernels": out}, metrics


# ---------------------------------------------------------------------------
# full (paper-table) axes -- dry-run the production meshes, no wall clock
# ---------------------------------------------------------------------------

def _cell(ctx, arch, cell, mode, multi_pod=True, overrides=None):
    from repro.launch.dryrun import dryrun_cell
    # paper-table benches compare modes on the sequential schedule:
    # prefetch would e.g. remove zero3's backward stage-1 DCN re-gather
    # and shrink the baseline every table normalizes against
    return dryrun_cell(arch, cell, multi_pod, mode,
                       system_overrides=overrides, verbose=False,
                       prefetch_depth=0,
                       mode_overrides=ctx.mode_overrides)


def axis_comm_volume(ctx: RunContext):
    """Table VII analog: per-device DCN/ICI bytes per training iteration
    for each system, plus the PEFT (FCDP-Comm) row."""
    rows = ctx.rows
    arch = "qwen2.5-3b"
    out = []
    for mode in ("zero3", "zeropp", "fcdp", "mics"):
        r = _cell(ctx, arch, "train_4k", mode)
        rl = r["roofline"]
        out.append({"system": mode, "dcn_bytes": rl["dcn_bytes_per_chip"],
                    "ici_bytes": rl["ici_bytes_per_chip"],
                    "by_op": rl["coll_by_op"]})
        rows.append((f"comm_volume/{mode}_dcn_GB", 0,
                     rl["dcn_bytes_per_chip"] / 1e9))
    r = _cell(ctx, arch, "train_4k", "fcdp", overrides={"peft": True})
    rl = r["roofline"]
    out.append({"system": "fcdp_comm(peft)",
                "dcn_bytes": rl["dcn_bytes_per_chip"],
                "ici_bytes": rl["ici_bytes_per_chip"],
                "by_op": rl["coll_by_op"]})
    rows.append(("comm_volume/fcdp_peft_dcn_GB", 0,
                 rl["dcn_bytes_per_chip"] / 1e9))
    base = out[0]["dcn_bytes"]
    for o in out:
        o["dcn_vs_zero3"] = o["dcn_bytes"] / base if base else 0
    fcdp_red = 100 * (1 - out[2]["dcn_vs_zero3"])
    peft_red = 100 * (1 - out[-1]["dcn_vs_zero3"])
    rows.append(("comm_volume/fcdp_dcn_reduction_pct", 0, fcdp_red))
    rows.append(("comm_volume/peft_dcn_reduction_pct", 0, peft_red))
    metrics = [
        metric("fcdp_dcn_reduction_pct", fcdp_red, direction="higher",
               noise_band=1e-3, unit="%"),
        metric("peft_dcn_reduction_pct", peft_red, direction="higher",
               noise_band=1e-3, unit="%"),
    ]
    return {"table": "VII", "arch": arch, "rows": out}, metrics


def axis_memory(ctx: RunContext):
    """SS III-B analog: per-device memory by system.

    Multi-pod: the cached stage-1 shard is tiny (pods are 256-wide), so
    fcdp ~ zeropp ~ zero3 on HBM; the paper's memory dilemma manifests on
    the SINGLE-pod mesh where the cache is the fully-gathered weight:
    zeropp pays it in HBM (the paper's OOM column), fcdp moves it to host
    (reported separately -- the CPU backend drops pinned_host placements,
    so the analytic host-cache size is subtracted for the fcdp row)."""
    from repro.configs.base import RunConfig, SystemConfig, shape_cell
    from repro.configs.registry import get_config
    from repro.core.cache import cache_bytes_per_chip
    from repro.core.engine import StepBundle
    from repro.launch.mesh import make_production_mesh
    rows = ctx.rows
    arch = "granite-3-8b"
    out = []
    fcdp_2pod_peak = None
    for multi_pod in (True, False):
        mesh_name = "2pod" if multi_pod else "1pod"
        for mode in ("zero3", "zeropp", "fcdp", "mics"):
            r = _cell(ctx, arch, "train_4k", mode, multi_pod=multi_pod,
                      overrides={"activation_policy": "block_io"})
            m = r["memory"]
            # analytic host-cache size for the fcdp row
            cfg = get_config(arch)
            run = RunConfig(model=cfg, shape=shape_cell("train_4k"),
                            system=SystemConfig(mode=mode))
            bundle = StepBundle(run, make_production_mesh(
                multi_pod=multi_pod))
            host = cache_bytes_per_chip(bundle)[
                "host_cache_bytes_per_chip"] if mode == "fcdp" else 0.0
            peak = m["peak_est_bytes"] - (host if mode == "fcdp" else 0)
            if mode == "fcdp" and multi_pod:
                fcdp_2pod_peak = peak
            out.append({"mesh": mesh_name, "system": mode,
                        "args_GiB": m["argument_bytes"] / 2**30,
                        "temp_GiB": m["temp_bytes"] / 2**30,
                        "hbm_peak_GiB": peak / 2**30,
                        "host_cache_GiB": host / 2**30})
            rows.append((f"memory/{mesh_name}/{mode}_hbm_peak_GiB", 0,
                         peak / 2**30))
            if mode == "fcdp":
                rows.append((f"memory/{mesh_name}/fcdp_host_cache_GiB", 0,
                             host / 2**30))
    metrics = [metric("fcdp_2pod_hbm_peak_GiB", fcdp_2pod_peak / 2**30,
                      direction="lower", noise_band=0.02, unit="GiB")]
    return {"table": "III-B", "arch": arch, "rows": out}, metrics


def axis_max_batch(ctx: RunContext):
    """Tables V/VI analog: largest power-of-two global batch whose
    compiled train step fits the 16 GiB v5e HBM, per system."""
    from repro.configs.base import RunConfig, SystemConfig, ShapeCell
    from repro.configs.registry import get_config
    from repro.core.engine import StepBundle
    from repro.launch.mesh import make_production_mesh
    rows = ctx.rows

    HBM = 16 * 2**30
    arch = "qwen2.5-3b"
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=True)
    out = {}
    metrics = []
    for mode in ("zero3", "zeropp", "fcdp"):
        best = 0
        for bexp in range(8, 13):           # global batch 256..4096
            B = 2 ** bexp
            cell = ShapeCell("mb", "train", 4096, B)
            sysc = SystemConfig(mode=mode, activation_policy="block_io",
                                loss_chunk=2048)
            run = RunConfig(model=cfg, shape=cell, system=sysc)
            try:
                b = StepBundle(run, mesh)
                c = b.make_train_step().lower(*b.train_input_sds()).compile()
                m = c.memory_analysis()
                peak = (m.argument_size_in_bytes + m.temp_size_in_bytes
                        + m.output_size_in_bytes - m.alias_size_in_bytes)
                if peak <= HBM:
                    best = B
                else:
                    break
            except Exception:
                break
        out[mode] = best
        rows.append((f"max_batch/{mode}", 0, best))
        metrics.append(metric(f"{mode}_max_batch", best,
                              direction="higher", noise_band=0.0))
    return ({"table": "V/VI", "arch": arch, "hbm_GiB": 16, "rows": out},
            metrics)


def axis_throughput_model(ctx: RunContext):
    """Fig. 5/6 analog: roofline-model step time -> samples/s per system,
    plus the paper's strong-scaling axis (1 pod = 256 chips vs 2 pods =
    512 chips, the 2-node vs 4-node analog). CPU container => derived
    from the dry-run terms, not wall clock."""
    rows = ctx.rows
    out = []
    for arch in ("qwen2.5-3b", "yi-34b"):
        for mode in ("zero3", "zeropp", "fcdp"):
            r = _cell(ctx, arch, "train_4k", mode,
                      overrides={"activation_policy": "block_io"})
            rl = r["roofline"]
            # overlap model: compute overlaps comm; step >= max(terms)
            step_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
            sps = 256 / step_s
            out.append({"arch": arch, "system": mode,
                        "step_s": step_s, "samples_per_s": sps,
                        "dominant": rl["dominant"]})
            rows.append((f"throughput/{arch}/{mode}_samples_per_s",
                         step_s * 1e6, sps))
    # strong scaling: same global batch on half the chips (Fig. 5 analog)
    scaling = []
    for mode in ("zero3", "fcdp"):
        for mp, chips in ((False, 256), (True, 512)):
            r = _cell(ctx, "qwen2.5-3b", "train_4k", mode, multi_pod=mp,
                      overrides={"activation_policy": "block_io"})
            rl = r["roofline"]
            step_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
            scaling.append({"system": mode, "chips": chips,
                            "samples_per_s": 256 / step_s})
            rows.append((f"strong_scaling/{mode}_{chips}chips",
                         step_s * 1e6, 256 / step_s))
    metrics = []
    for mode in ("zero3", "fcdp"):
        pair = [s for s in scaling if s["system"] == mode]
        eff = (pair[1]["samples_per_s"] / pair[0]["samples_per_s"]) / 2
        rows.append((f"strong_scaling/{mode}_efficiency_256to512", 0, eff))
        metrics.append(metric(f"{mode}_scaling_efficiency_256to512", eff,
                              direction="higher", noise_band=1e-3))
    return ({"figure": "5/6", "rows": out, "strong_scaling": scaling},
            metrics)


def axis_bw_sensitivity(ctx: RunContext):
    """Fig. 9 analog: step time vs DCN bandwidth for full FT and PEFT.
    Reproduces the paper's headline: FCDP-Comm throughput is ~flat in
    network bandwidth while ZeRO-3 collapses.

    Step time here is max(compute, ici+dcn) -- the paper's GPUs overlap
    HBM traffic with compute, and our memory term is a documented upper
    bound (see EXPERIMENTS.md), so including it would mask the comm
    effect this figure isolates."""
    rows = ctx.rows
    arch = "qwen2.5-3b"
    bws_gbps = [100, 25, 10, 1, 0.5, 0.1]   # per-host (4 chips/host)
    cells = {}
    for label, mode, ov in (
            ("zero3", "zero3", None),
            ("fcdp", "fcdp", None),
            ("zero3_peft", "zero3", {"peft": True}),
            ("fcdp_comm_peft", "fcdp", {"peft": True})):
        r = _cell(ctx, arch, "train_4k", mode, overrides=ov)
        rl = r["roofline"]
        cells[label] = rl
    out = []
    for label, rl in cells.items():
        for bw in bws_gbps:
            dcn_s = rl["dcn_bytes_per_chip"] / (bw * 1e9 / 8 / 4)
            # bw quoted per host (4 chips/host assumed), bits->bytes
            step_s = max(rl["compute_s"], rl["ici_s"] + dcn_s)
            out.append({"system": label, "dcn_gbps": bw,
                        "samples_per_s": 256 / step_s})
    # headline ratios at 1 Gbps
    def sps(label, bw):
        return next(o["samples_per_s"] for o in out
                    if o["system"] == label and o["dcn_gbps"] == bw)
    ratio_vs_zero3 = sps("fcdp_comm_peft", 1) / sps("zero3_peft", 1)
    retention = sps("fcdp_comm_peft", 1) / sps("fcdp_comm_peft", 100)
    rows.append(("bw_sensitivity/peft_speedup_vs_zero3_at_1gbps", 0,
                 ratio_vs_zero3))
    rows.append(("bw_sensitivity/fcdp_comm_retention_at_1gbps", 0,
                 retention))
    metrics = [
        metric("peft_speedup_vs_zero3_at_1gbps", ratio_vs_zero3,
               direction="higher", noise_band=1e-3, unit="x"),
        metric("fcdp_comm_retention_at_1gbps", retention,
               direction="higher", noise_band=1e-3),
    ]
    payload = {"figure": "9", "rows": out,
               "peft_speedup_at_1gbps": ratio_vs_zero3,
               "fcdp_comm_throughput_retention": retention}
    return payload, metrics


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

SMOKE_WORKLOADS = (
    Workload("comm_smoke", axis_comm_smoke, flat="bench_smoke_comm.json",
             timed_arms=(
                 TimedArm("fcdp_d1", {"mode": "fcdp", "prefetch_depth": 1}),
                 TimedArm("zero3_d1", {"mode": "zero3",
                                       "prefetch_depth": 1}))),
    Workload("mixed_smoke", axis_mixed_smoke,
             flat="bench_smoke_mixed.json",
             timed_arms=(
                 TimedArm("fcdp_pure", {"mode": "fcdp",
                                        "prefetch_depth": 1},
                          model="moe"),
                 TimedArm("fcdp_mixed", {"mode": "fcdp",
                                         "prefetch_depth": 1,
                                         "mode_overrides": _MIXED_RULES},
                          model="moe"))),
    Workload("xstep_smoke", axis_xstep_smoke,
             flat="bench_smoke_xstep.json",
             timed_arms=(
                 TimedArm("xstep_off", {"mode": "fcdp",
                                        "async_grad_reduce": True},
                          microbatch=2),
                 TimedArm("xstep_on", {"mode": "fcdp",
                                       "async_grad_reduce": True,
                                       "cross_step_pipeline": True},
                          microbatch=2))),
    Workload("restart_smoke", axis_restart_smoke,
             flat="bench_smoke_restart.json"),
    Workload("quant_smoke", axis_quant_smoke,
             flat="bench_smoke_quant.json",
             timed_arms=(
                 TimedArm("fcdp_bf16", {"mode": "fcdp"}, model="dense4"),
                 TimedArm("fcdp_int8", {"mode": "fcdp",
                                        "param_compress": "int8_pod"},
                          model="dense4"))),
    Workload("fused_smoke", axis_fused_smoke,
             flat="bench_smoke_fused.json",
             timed_arms=(
                 TimedArm("fcdp_unfused", {"mode": "fcdp",
                                           "prefetch_depth": 1},
                          model="dense4"),
                 TimedArm("fcdp_fused", {"mode": "fcdp",
                                         "prefetch_depth": 1,
                                         "fused_matmul": "ag_matmul"},
                          model="dense4"))),
    Workload("serve_smoke", axis_serve_smoke,
             flat="bench_smoke_serve.json"),
    Workload("peft_smoke", axis_peft_smoke,
             flat="bench_smoke_peft.json",
             timed_arms=(
                 TimedArm("zero3_full", {"mode": "zero3"}),
                 TimedArm("fcdp_lora", {"mode": "fcdp", "peft": True,
                                        "lora_rank": 2}))),
    Workload("kernels", axis_kernels, flat="bench_smoke_kernels.json"),
)

FULL_WORKLOADS = (
    Workload("comm_volume", axis_comm_volume),
    Workload("memory", axis_memory),
    Workload("throughput_model", axis_throughput_model),
    Workload("bw_sensitivity", axis_bw_sensitivity),
    Workload("max_batch", axis_max_batch),
    Workload("kernels", axis_kernels),
)
