"""Benchmark harness: workloads (declarative axis specs), execution
(timed steady-state measurement + the analytic bodies), results
(versioned artifact schemas, shared validate(), timestamped run dirs).

``benchmarks/run.py`` is the thin driver; ``benchmarks/compare.py`` is
the regression gate over two runs' artifacts.
"""
from benchmarks.harness.execution import (RunContext, TimedArm, TimingSpec,
                                          measure_timed_arms, run_workload)
from benchmarks.harness.results import (BASELINE, RESULTS, RUNS,
                                        SCHEMA_VERSION, Metric, RunDir,
                                        SchemaError, load_run,
                                        make_artifact, metric, metrics_of,
                                        register_axis_validator, validate,
                                        validate_file)
from benchmarks.harness.workloads import (FULL_WORKLOADS, SMOKE_WORKLOADS,
                                          Workload)

__all__ = [
    "RunContext", "TimedArm", "TimingSpec", "measure_timed_arms",
    "run_workload", "BASELINE", "RESULTS", "RUNS", "SCHEMA_VERSION",
    "Metric", "RunDir", "SchemaError", "load_run", "make_artifact",
    "metric", "metrics_of", "register_axis_validator", "validate",
    "validate_file", "FULL_WORKLOADS", "SMOKE_WORKLOADS", "Workload",
]
