"""Execution layer of the benchmark harness: timed steady-state step
measurement alongside the analytic byte assertions the axis bodies
carry.

``measure_timed_arms`` is the one place wall-clock training numbers are
produced: per declared arm it builds the toy StepBundle, runs
``warmup_steps`` steps OUTSIDE the timed region (compile + allocator
warmup; for the cross-step pipeline the prime step is part of warmup so
only steady-state piped steps are timed), then times ``timed_steps``
steps individually, fencing each with ``jax.block_until_ready`` on the
full step output (params, opt state, metrics) so async dispatch cannot
leak work across the stopwatch.  Reported per arm: median/p90/mean/
min/max seconds over the timed steps -- median+p90 because a handful of
CPU-CI steps has outliers and a mean would smear them.

``run_workload`` drives one axis end to end: the analytic body runs
first (its assertions are the same ones the old monolithic run.py
carried), then the timed arms when ``--timed`` is on, and the pieces
are assembled into one schema-validated artifact document
(``results.make_artifact``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.harness import results
from benchmarks.harness.results import Metric, metric

# wall-clock medians on whatever machine CI landed on: only a
# catastrophic (>2.5x) slowdown should gate
TIMED_STEP_BAND = 1.5


@dataclass(frozen=True)
class TimingSpec:
    warmup_steps: int = 2
    timed_steps: int = 5


@dataclass(frozen=True)
class TimedArm:
    """One timed configuration of an axis: a toy model + SystemConfig
    kwargs.  The arm label becomes the key in the artifact's
    ``timing.arms`` block and the ``step_s_<label>`` timed metric."""
    label: str
    system: dict                     # SystemConfig kwargs (incl. mode)
    model: str = "dense2"            # toy arch: dense2 | dense4 | moe
    microbatch: int = 0


@dataclass
class RunContext:
    """Ambient state one benchmark invocation threads through every
    axis body (replaces the old module-global _MODE_OVERRIDES)."""
    rows: list = field(default_factory=list)
    mode_overrides: tuple = ()
    timed: bool = False
    timing: TimingSpec = field(default_factory=TimingSpec)
    results_dir: "Path" = None

    def __post_init__(self):
        if self.results_dir is None:
            self.results_dir = results.RESULTS


def _toy_model(kind: str):
    from repro.configs.base import ModelConfig, MoEConfig
    if kind == "dense2":
        return ModelConfig(name="smoke-dense", family="dense",
                           num_layers=2, d_model=64, num_heads=4,
                           num_kv_heads=2, d_ff=128, vocab_size=256)
    if kind == "dense4":
        return ModelConfig(name="smoke-dense", family="dense",
                           num_layers=4, d_model=64, num_heads=4,
                           num_kv_heads=2, d_ff=128, vocab_size=256)
    if kind == "moe":
        return ModelConfig(name="smoke-moe", family="moe", num_layers=2,
                           d_model=64, num_heads=4, num_kv_heads=2,
                           d_ff=64, vocab_size=256,
                           moe=MoEConfig(num_experts=4, top_k=2,
                                         d_ff_expert=64))
    raise ValueError(f"unknown toy model {kind!r}")


def _summarize_times(times: List[float], warmup_steps: int) -> dict:
    arr = np.asarray(times, dtype=np.float64)
    return {"median_s": float(np.median(arr)),
            "p90_s": float(np.percentile(arr, 90)),
            "mean_s": float(arr.mean()),
            "min_s": float(arr.min()),
            "max_s": float(arr.max()),
            "n": int(arr.size),
            "warmup_steps": int(warmup_steps)}


def time_train_arm(arm: TimedArm, spec: TimingSpec) -> dict:
    """Steady-state wall-clock step time of one toy training arm."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.configs.base import (OptimizerConfig, RunConfig, ShapeCell,
                                    SystemConfig)
    from repro.core.engine import StepBundle
    from repro.launch.mesh import make_mesh
    from repro.optim.adamw import init_opt_state

    cfg = _toy_model(arm.model)
    cell = ShapeCell("t", "train", 64, 8)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    sysc = SystemConfig(min_shard_size=8, **arm.system)
    total = spec.warmup_steps + spec.timed_steps + 2
    run = RunConfig(model=cfg, shape=cell, system=sysc,
                    optimizer=OptimizerConfig(total_steps=total,
                                              warmup_steps=1),
                    microbatch=arm.microbatch)
    b = StepBundle(run, mesh)
    params = b.init_all_params(seed=0)
    tp, fp = b.split(params)
    opt = jax.jit(functools.partial(init_opt_state, sys=sysc))(tp)
    rng = np.random.default_rng(0)
    batches = [
        {"ids": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
         "labels": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
         "mask": jnp.ones((8, 64), bool)} for _ in range(2)]
    step = b.make_train_step()
    carry = None
    if b.cross_step:
        # the prime step fills the pipeline; it belongs to warmup, the
        # timed region sees only steady-state piped steps
        carry, _ = b.make_train_prime()(tp, fp, opt, batches[0])

    def one_step(i):
        nonlocal tp, opt, carry
        batch = batches[i % len(batches)]
        if b.cross_step:
            tp, opt, carry, m = step(tp, fp, opt, carry, batch)
        else:
            tp, opt, m = step(tp, fp, opt, batch)
        return m

    for i in range(spec.warmup_steps):
        # run first: the step donates the previous tp/opt buffers, so
        # the fence must see the freshly returned ones
        m = one_step(i)
        jax.block_until_ready((tp, opt, m))
    times = []
    for i in range(spec.timed_steps):
        t0 = time.perf_counter()
        m = one_step(spec.warmup_steps + i)
        jax.block_until_ready((tp, opt, m))
        times.append(time.perf_counter() - t0)
    return _summarize_times(times, spec.warmup_steps)


def measure_timed_arms(axis: str, arms: Tuple[TimedArm, ...],
                       ctx: RunContext) -> Tuple[dict, List[Metric]]:
    """Time every declared arm; returns (timing block, timed metrics)."""
    out_arms: Dict[str, dict] = {}
    metrics: List[Metric] = []
    for arm in arms:
        t = time_train_arm(arm, ctx.timing)
        out_arms[arm.label] = t
        metrics.append(metric(f"step_s_{arm.label}", t["median_s"],
                              kind="timed", direction="lower",
                              noise_band=TIMED_STEP_BAND, unit="s"))
        ctx.rows.append((f"{axis}/step_us_{arm.label}",
                         t["median_s"] * 1e6, t["p90_s"] * 1e6))
    timing = {"timed": True,
              "warmup_steps": ctx.timing.warmup_steps,
              "timed_steps": ctx.timing.timed_steps,
              "arms": out_arms}
    return timing, metrics


def run_workload(workload, ctx: RunContext) -> dict:
    """Run one axis: analytic body, then timed arms (when requested),
    assembled into a schema-validated artifact document."""
    ret = workload.fn(ctx)
    payload, metrics = ret[0], list(ret[1])
    timing = ret[2] if len(ret) > 2 else None
    if ctx.timed and workload.timed_arms and timing is None:
        timing, timed_metrics = measure_timed_arms(
            workload.name, workload.timed_arms, ctx)
        metrics.extend(timed_metrics)
    return results.make_artifact(workload.name, payload, metrics, timing)
