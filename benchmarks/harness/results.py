"""Results layer of the benchmark harness: versioned artifact schemas,
one shared ``validate()``, and timestamped run directories.

Every bench axis produces ONE artifact document::

    {
      "schema_version": 1,
      "axis": "quant",
      ... the axis payload (legacy keys: "rows", "smoke", ...) ...,
      "metrics": [{"name", "value", "kind", "direction",
                   "noise_band", "unit"}, ...],
      "timing": null | {"timed": true, "warmup_steps", "timed_steps",
                        "arms": {label: {"median_s", "p90_s", ...}}}
    }

The payload keys stay at top level so every pre-existing consumer of
the flat ``results/bench_smoke_*.json`` files (``make_experiments_md``,
the CI artifact glob, ad-hoc jq) keeps working; the schema fields ride
along.  The same document is ALSO written into the timestamped run dir
``results/runs/<stamp>/<axis>.json`` next to a ``manifest.json``, which
is what ``benchmarks/compare.py`` diffs against ``results/baseline/``.

``metrics`` is the machine-readable gate surface: each metric carries
its own direction (which way is better) and noise band (relative
regression tolerance; ``None`` = the default band for its kind).  Analytic
metrics (byte counts, ratios from the roofline model) are deterministic
and get tight bands; wall-clock (``kind="timed"``) metrics get wide
bands because CI machines differ -- see ARCHITECTURE.md "Benchmark
harness" for the baseline refresh procedure.

Axis-specific invariants beyond the generic schema (e.g. the serve
artifact's "continuous strictly beats static") plug in through
``register_axis_validator`` -- ``serve_results.py`` registers the serve
one, so the one CI gate step validates every artifact with one loop.
"""
from __future__ import annotations

import dataclasses
import json
import platform
import sys
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional

SCHEMA_VERSION = 1

RESULTS = Path(__file__).resolve().parents[2] / "results"
RUNS = RESULTS / "runs"
BASELINE = RESULTS / "baseline"

METRIC_KINDS = ("analytic", "timed")
DIRECTIONS = ("lower", "higher")

# default relative noise bands by kind: analytic numbers are
# deterministic re-derivations (byte accounting, roofline terms) --
# any drift is a real change; timed numbers are wall clock on whatever
# machine CI landed on, so only a catastrophic slowdown should gate.
DEFAULT_NOISE_BAND = {"analytic": 1e-3, "timed": 1.5}


class SchemaError(ValueError):
    """An artifact failed schema validation (readable message)."""


@dataclass(frozen=True)
class Metric:
    """One gate-able number of a bench axis.

    direction: which way is BETTER ("lower" for times/bytes/drift,
    "higher" for throughput/reduction factors).
    noise_band: relative tolerance for the regression gate -- new runs
    may regress up to ``baseline * noise_band`` before compare.py
    fails; 0.0 demands bit-stable equality, None picks the
    DEFAULT_NOISE_BAND for the metric's kind.
    """
    name: str
    value: float
    kind: str = "analytic"            # analytic | timed
    direction: str = "lower"          # lower | higher
    noise_band: Optional[float] = None
    unit: str = ""

    def __post_init__(self):
        if self.kind not in METRIC_KINDS:
            raise SchemaError(f"metric {self.name!r}: unknown kind "
                              f"{self.kind!r}; known {METRIC_KINDS}")
        if self.direction not in DIRECTIONS:
            raise SchemaError(f"metric {self.name!r}: unknown direction "
                              f"{self.direction!r}; known {DIRECTIONS}")
        if self.noise_band is not None and self.noise_band < 0:
            raise SchemaError(f"metric {self.name!r}: noise_band must be "
                              f">= 0 or None, got {self.noise_band!r}")

    def resolved_band(self) -> Optional[float]:
        return (DEFAULT_NOISE_BAND[self.kind]
                if self.noise_band is None else self.noise_band)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def metric(name, value, kind="analytic", direction="lower",
           noise_band=None, unit="") -> Metric:
    """Shorthand constructor the axis bodies use."""
    return Metric(name=name, value=float(value), kind=kind,
                  direction=direction, noise_band=noise_band, unit=unit)


# ---------------------------------------------------------------------------
# artifact documents
# ---------------------------------------------------------------------------

def make_artifact(axis: str, payload: dict,
                  metrics: List[Metric] = (),
                  timing: Optional[dict] = None) -> dict:
    """Assemble the versioned artifact document for one axis."""
    doc = dict(payload)
    for k in ("axis", "schema_version", "metrics", "timing"):
        if k in payload:
            raise SchemaError(f"axis {axis!r}: payload key {k!r} collides "
                              "with the artifact envelope")
    doc["axis"] = axis
    doc["schema_version"] = SCHEMA_VERSION
    doc["metrics"] = [m.to_json() for m in metrics]
    doc["timing"] = timing
    return doc


def metrics_of(doc: dict) -> Dict[str, Metric]:
    """Parse (and re-validate) a document's metrics by name."""
    out = {}
    for m in doc.get("metrics", []):
        mm = Metric(**m)
        if mm.name in out:
            raise SchemaError(f"axis {doc.get('axis')!r}: duplicate "
                              f"metric name {mm.name!r}")
        out[mm.name] = mm
    return out


# axis name -> callable(doc) raising on violated axis-specific invariants
_AXIS_VALIDATORS: Dict[str, Callable[[dict], None]] = {}


def register_axis_validator(axis: str, fn: Callable[[dict], None]) -> None:
    _AXIS_VALIDATORS[axis] = fn


_TIMING_ARM_KEYS = ("median_s", "p90_s", "mean_s", "n")


def validate(doc: dict, axis: str = None) -> None:
    """Shared schema gate for every bench artifact; raises SchemaError
    with a message that names the offending field."""
    if not isinstance(doc, dict):
        raise SchemaError(f"artifact must be a JSON object, got "
                          f"{type(doc).__name__}")
    got_axis = doc.get("axis")
    if not got_axis:
        raise SchemaError("artifact missing 'axis'")
    if axis is not None and got_axis != axis:
        raise SchemaError(f"artifact axis {got_axis!r} != expected {axis!r}")
    v = doc.get("schema_version")
    if v != SCHEMA_VERSION:
        raise SchemaError(
            f"axis {got_axis!r}: schema_version {v!r} != supported "
            f"{SCHEMA_VERSION} -- regenerate the artifact (or refresh "
            "results/baseline/) with this tree's harness")
    if not isinstance(doc.get("metrics"), list):
        raise SchemaError(f"axis {got_axis!r}: 'metrics' must be a list")
    for m in metrics_of(doc).values():
        val = m.value
        if not isinstance(val, (int, float)) or val != val:  # NaN check
            raise SchemaError(f"axis {got_axis!r}: metric {m.name!r} "
                              f"value {val!r} is not a finite number")
    timing = doc.get("timing", None)
    if timing is not None:
        if not timing.get("timed"):
            raise SchemaError(f"axis {got_axis!r}: timing block present "
                              "but not marked timed")
        arms = timing.get("arms")
        if not isinstance(arms, dict) or not arms:
            raise SchemaError(f"axis {got_axis!r}: timing block has no "
                              "arms")
        for label, arm in arms.items():
            for k in _TIMING_ARM_KEYS:
                if k not in arm:
                    raise SchemaError(
                        f"axis {got_axis!r}: timing arm {label!r} "
                        f"missing {k!r}")
                if arm[k] < 0:
                    raise SchemaError(
                        f"axis {got_axis!r}: timing arm {label!r} "
                        f"{k}={arm[k]!r} < 0")
    extra = _AXIS_VALIDATORS.get(got_axis)
    if extra is not None:
        extra(doc)


def validate_file(path) -> dict:
    path = Path(path)
    try:
        doc = json.load(open(path))
    except Exception as e:
        raise SchemaError(f"{path}: unreadable JSON ({e})")
    try:
        validate(doc)
    except SchemaError as e:
        raise SchemaError(f"{path}: {e}")
    return doc


# ---------------------------------------------------------------------------
# run directories
# ---------------------------------------------------------------------------

def _env_info() -> dict:
    info = {"python": sys.version.split()[0],
            "platform": platform.platform()}
    try:
        import jax
        info["jax"] = jax.__version__
        info["backend"] = jax.default_backend()
        info["device_count"] = jax.device_count()
    except Exception:
        pass
    return info


@dataclass
class RunDir:
    """One timestamped benchmark run: results/runs/<stamp>/ holding a
    manifest.json plus one validated artifact per axis.  The flat
    ``results/bench_smoke_*.json`` files are written from the same
    documents for back-compat with make_experiments_md and the CI
    artifact glob."""
    path: Path
    stamp: str
    smoke: bool = True
    timed: bool = False
    axes: List[str] = field(default_factory=list)
    artifacts: Dict[str, str] = field(default_factory=dict)
    failures: Dict[str, str] = field(default_factory=dict)

    @classmethod
    def create(cls, *, smoke: bool, timed: bool, root: Path = None,
               stamp: str = None) -> "RunDir":
        stamp = stamp or time.strftime("%Y%m%d-%H%M%S")
        path = (root or RUNS) / stamp
        # a second run inside the same second must not overwrite
        n = 0
        while path.exists():
            n += 1
            path = (root or RUNS) / f"{stamp}-{n}"
        path.mkdir(parents=True)
        return cls(path=path, stamp=path.name, smoke=smoke, timed=timed)

    def write_axis(self, doc: dict, flat_path: Path = None) -> Path:
        """Validate and persist one axis artifact (run dir + optional
        flat back-compat copy)."""
        validate(doc)
        axis = doc["axis"]
        name = f"{axis}.json"
        with open(self.path / name, "w") as f:
            json.dump(doc, f, indent=2, default=float)
        if flat_path is not None:
            with open(flat_path, "w") as f:
                json.dump(doc, f, indent=2, default=float)
        self.axes.append(axis)
        self.artifacts[axis] = name
        return self.path / name

    def record_failure(self, axis: str, err: str) -> None:
        self.failures[axis] = err

    def finalize(self, extra: dict = None) -> Path:
        manifest = {"schema_version": SCHEMA_VERSION,
                    "stamp": self.stamp,
                    "smoke": self.smoke,
                    "timed": self.timed,
                    "axes": self.axes,
                    "artifacts": self.artifacts,
                    "failures": self.failures,
                    "env": _env_info()}
        if extra:
            manifest.update(extra)
        out = self.path / "manifest.json"
        with open(out, "w") as f:
            json.dump(manifest, f, indent=2, default=float)
        return out


def load_run(path) -> (dict, Dict[str, dict]):
    """Load a run dir (or results/baseline): (manifest, {axis: doc})."""
    path = Path(path)
    mpath = path / "manifest.json"
    if not mpath.exists():
        raise SchemaError(f"{path}: no manifest.json -- not a benchmark "
                          "run directory")
    manifest = json.load(open(mpath))
    v = manifest.get("schema_version")
    if v != SCHEMA_VERSION:
        raise SchemaError(f"{mpath}: manifest schema_version {v!r} != "
                          f"supported {SCHEMA_VERSION}")
    docs = {}
    for axis, name in manifest.get("artifacts", {}).items():
        docs[axis] = validate_file(path / name)
    return manifest, docs
