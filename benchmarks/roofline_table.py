"""Render the §Roofline markdown table from a dry-run JSON.

  PYTHONPATH=src python -m benchmarks.roofline_table [--multi-pod]
  PYTHONPATH=src python -m benchmarks.roofline_table \
      --json results/dryrun_fcdp_mixed.json     # mixed-layout dry-run

The mode column renders per-tensor overrides as
``fcdp+blocks.*.moe.we_*=mics`` so mixed layouts can sit in the same
experiments table as the pure modes they are compared against.
"""
import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def mode_label(cell) -> str:
    """Mode axis value incl. per-tensor overrides: 'fcdp+glob=mics;...'"""
    ov = cell.get("mode_overrides") or []
    if not ov:
        return cell.get("mode", "?")
    return cell["mode"] + "+" + ";".join(f"{p}={m}" for p, m in ov)


def render(multi_pod: bool, path=None):
    with open(path or RESULTS / "dryrun_fcdp.json") as f:
        cells = json.load(f)
    rows = []
    for c in cells:
        if c.get("multi_pod") != multi_pod:
            continue
        if c["status"] == "skipped":
            rows.append((c["arch"], c["cell"], None, c["reason"]))
            continue
        rows.append((c["arch"], c["cell"], c, ""))
    mesh = "2x16x16 (512 chips)" if multi_pod else "16x16 (256 chips)"
    modes = sorted({mode_label(c) for _, _, c, _ in rows if c})
    out = [f"### Roofline — {mesh}, mode={'/'.join(modes) or 'fcdp'}, "
           "block_io activation policy",
           "",
           "| arch | cell | mode | compute | memory | "
           "collective (ici+dcn) | dominant | MODEL_FLOPS/HLO | "
           "roofline frac | HBM peak GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for arch, cell, c, reason in rows:
        if c is None:
            out.append(f"| {arch} | {cell} | — | — | — | — | {reason} "
                       "| — | — | — |")
            continue
        r = c["roofline"]
        peak = c["memory"]["peak_est_bytes"] / 2**30
        out.append(
            f"| {arch} | {cell} | {mode_label(c)} | "
            f"{fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['ici_s'])}+{fmt_s(r['dcn_s'])} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {peak:.1f} |")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None,
                    help="dry-run JSON to render (default "
                         "results/dryrun_fcdp.json); point at a "
                         "--mode-override dry-run for mixed layouts")
    a = ap.parse_args()
    print(render(a.multi_pod, path=a.json))
