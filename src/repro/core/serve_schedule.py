"""Continuous-batching request scheduler over the paged-KV serve engine.

One :class:`PagedServeEngine` owns a fixed grid of B batch slots (the
decode cell's global batch), the paged KV pools (``core/kv_cache.py``)
and three jitted step functions built ONCE per engine:

  - a chunked-prefill step ([B, chunk] tokens; long prompts advance one
    chunk per scheduler iteration so they never stall in-flight decodes)
  - a paged decode step ([B, 1] tokens)
  - the greedy pick (per-rank argmax candidates, engine/serve.py)

Every scheduler iteration:

  admit   -> pop FIFO requests into FREE slots while their full page
             reservation (ceil((prompt+max_new)/page_size)) fits the
             slot replica's free list -- conservative, so an admitted
             sequence can never be starved mid-decode (no preemption)
  prefill -> one chunk for every PREFILL slot (rows not prefilling ride
             along against the scratch page); a slot whose prompt
             completes emits its first token (TTFT) and turns DECODE
  decode  -> one token for every DECODE slot; finished slots retire,
             their pages return to the free list and their table row
             resets to scratch

``policy="static"`` keeps the identical jitted steps but admits only
whole waves (wait for every slot to drain, then refill) -- the
wait-for-full-batch baseline the serve benchmark compares against.

All timing is wall-clock: token picks are materialized to host
(blocking) before timestamps, so TTFT/ITL include device time.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.kv_cache import PagedKVConfig, PageAllocator

FREE, PREFILL, DECODE = 0, 1, 2


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [plen] int32 token ids
    max_new_tokens: int


@dataclass
class RequestResult:
    rid: int
    prompt_len: int
    tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0                # first generated token (TTFT end)
    t_done: float = 0.0
    itl: List[float] = field(default_factory=list)   # inter-token gaps (s)

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit

    @property
    def tpot(self) -> float:
        """Time per output token after the first."""
        n = len(self.tokens)
        return (self.t_done - self.t_first) / max(n - 1, 1)


def _pcts(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
    a = np.asarray(xs, np.float64)
    return {"mean": float(a.mean()),
            "p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)),
            "p99": float(np.percentile(a, 99))}


def summarize(results: List[RequestResult], wall_s: float) -> Dict:
    """Request throughput + TTFT/TPOT/ITL percentiles (seconds)."""
    n_tok = sum(len(r.tokens) for r in results)
    return {
        "requests": len(results),
        "generated_tokens": n_tok,
        "wall_s": wall_s,
        "throughput_rps": len(results) / wall_s if wall_s > 0 else 0.0,
        "throughput_tok_s": n_tok / wall_s if wall_s > 0 else 0.0,
        "ttft_s": _pcts([r.ttft for r in results]),
        "tpot_s": _pcts([r.tpot for r in results]),
        "itl_s": _pcts([g for r in results for g in r.itl]),
    }


class PagedServeEngine:
    """Multi-request serving over one StepBundle (decode cell)."""

    def __init__(self, bundle, kv: PagedKVConfig, chunk: int = 32,
                 policy: str = "continuous", capture_logits: bool = False,
                 share_steps_with: "PagedServeEngine" = None):
        from repro.core.engine.serve import paged_replicas
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {policy!r}")
        cell = bundle.run.shape
        self.bundle = bundle
        self.kv = kv
        self.chunk = min(chunk, kv.max_seq_len)
        self.policy = policy
        self.capture_logits = capture_logits
        self.B = cell.global_batch
        self.n_replicas = paged_replicas(bundle, cell)
        self.slots_per_rep = self.B // self.n_replicas
        self.allocs = [PageAllocator(kv) for _ in range(self.n_replicas)]
        if share_steps_with is not None:
            # reuse another engine's jitted steps (same bundle + kv):
            # policy A/B comparisons then share one compile cache
            self._prefill = share_steps_with._prefill
            self._decode = share_steps_with._decode
            self._pick = share_steps_with._pick
        else:
            self._prefill = bundle.make_prefill_chunk_step(kv)
            self._decode = bundle.make_paged_decode_step(kv)
            self._pick = bundle.make_greedy_pick()
        self.state = bundle.init_paged_state(kv)
        # host-side slot metadata
        self.table = np.zeros((self.B, kv.max_pages_per_seq), np.int32)
        self.lengths = np.zeros((self.B,), np.int32)
        self.status = np.full((self.B,), FREE, np.int32)
        self.prefilled = np.zeros((self.B,), np.int32)
        self.last_tok = np.zeros((self.B,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * self.B
        self.slot_res: List[Optional[RequestResult]] = [None] * self.B
        self.slot_pages: List[List[int]] = [[] for _ in range(self.B)]
        self.slot_tlast = np.zeros((self.B,), np.float64)
        self.captured: Dict[int, List[np.ndarray]] = {}
        self.steps = 0

    # -- admission -----------------------------------------------------------
    def _replica_of(self, slot: int) -> int:
        # serve_batch_dims splits the batch dim into contiguous blocks
        return slot // self.slots_per_rep

    def _admit(self, queue: deque) -> None:
        if self.policy == "static":
            # wait-for-full-batch: refill only once every slot drained,
            # and only as a full wave (or the final partial one)
            if (self.status != FREE).any():
                return
            if len(queue) < self.B and len(queue) == 0:
                return
        while queue:
            req = queue[0]
            need = self.kv.pages_needed(len(req.prompt)
                                        + req.max_new_tokens)
            placed = False
            for s in range(self.B):
                if self.status[s] != FREE:
                    continue
                pages = self.allocs[self._replica_of(s)].alloc(need)
                if pages is None:
                    continue
                queue.popleft()
                self.slot_pages[s] = pages
                self.table[s, :] = 0
                self.table[s, :len(pages)] = pages
                self.lengths[s] = 0
                self.prefilled[s] = 0
                self.status[s] = PREFILL
                self.slot_req[s] = req
                self.slot_res[s] = RequestResult(
                    rid=req.rid, prompt_len=len(req.prompt),
                    t_submit=self._t_submit[req.rid])
                placed = True
                break
            if not placed:
                break               # FIFO: head of line blocks admission

    def _retire(self, s: int, tnow: float) -> None:
        res = self.slot_res[s]
        res.t_done = tnow
        self.results.append(res)
        self.allocs[self._replica_of(s)].free(self.slot_pages[s])
        self.slot_pages[s] = []
        self.table[s, :] = 0        # back to scratch
        self.lengths[s] = 0
        self.status[s] = FREE
        self.slot_req[s] = None
        self.slot_res[s] = None

    # -- one scheduler iteration --------------------------------------------
    def _prefill_step(self, params_leaves) -> None:
        import jax.numpy as jnp
        pf = np.nonzero(self.status == PREFILL)[0]
        if len(pf) == 0:
            return
        C = self.chunk
        ids = np.zeros((self.B, C), np.int32)
        ptab = np.zeros_like(self.table)     # scratch for non-participants
        pos0 = np.zeros((self.B,), np.int32)
        last = np.zeros((self.B,), np.int32)
        took = {}
        for s in pf:
            req = self.slot_req[s]
            start = int(self.prefilled[s])
            n = min(C, len(req.prompt) - start)
            ids[s, :n] = req.prompt[start:start + n]
            ptab[s] = self.table[s]
            pos0[s] = start
            last[s] = n - 1
            took[s] = n
        logits, self.state = self._prefill(
            params_leaves, jnp.asarray(ids), jnp.asarray(ptab),
            jnp.asarray(pos0), jnp.asarray(last), self.state)
        completing = [s for s in pf
                      if self.prefilled[s] + took[s]
                      >= len(self.slot_req[s].prompt)]
        if not completing:
            # mid-prompt chunk: no slot emits a token, so skip the pick
            # and the host sync -- the next call consumes state lazily
            for s in pf:
                self.prefilled[s] += took[s]
            return
        toks = np.asarray(self._pick(logits))          # blocks
        tnow = time.perf_counter()
        full_logits = (np.asarray(logits) if self.capture_logits else None)
        for s in pf:
            req = self.slot_req[s]
            self.prefilled[s] += took[s]
            if self.prefilled[s] < len(req.prompt):
                continue
            # prompt complete: first generated token comes from the
            # last prompt token's logits in this chunk
            self.lengths[s] = len(req.prompt)
            self.status[s] = DECODE
            res = self.slot_res[s]
            res.t_first = tnow
            res.tokens.append(int(toks[s]))
            self.last_tok[s] = toks[s]
            self.slot_tlast[s] = tnow
            if full_logits is not None:
                self.captured.setdefault(req.rid, []).append(
                    full_logits[s].copy())
            if req.max_new_tokens == 1:
                self._retire(s, tnow)

    def _decode_step(self, params_leaves) -> None:
        import jax.numpy as jnp
        dc = np.nonzero(self.status == DECODE)[0]
        if len(dc) == 0:
            return
        toks_in = np.zeros((self.B, 1), np.int32)
        dtab = np.zeros_like(self.table)     # scratch for non-decoding rows
        for s in dc:
            toks_in[s, 0] = self.last_tok[s]
            dtab[s] = self.table[s]
        logits, self.state = self._decode(
            params_leaves, jnp.asarray(toks_in), jnp.asarray(dtab),
            jnp.asarray(self.lengths), self.state)
        toks = np.asarray(self._pick(logits))          # blocks
        tnow = time.perf_counter()
        full_logits = (np.asarray(logits) if self.capture_logits else None)
        for s in dc:
            req = self.slot_req[s]
            res = self.slot_res[s]
            if full_logits is not None:
                self.captured.setdefault(req.rid, []).append(
                    full_logits[s].copy())
            self.lengths[s] += 1             # the incoming token's kv landed
            res.tokens.append(int(toks[s]))
            res.itl.append(tnow - self.slot_tlast[s])
            self.slot_tlast[s] = tnow
            self.last_tok[s] = toks[s]
            if len(res.tokens) >= req.max_new_tokens:
                self._retire(s, tnow)

    # -- driver --------------------------------------------------------------
    def serve(self, params_leaves, requests: List[Request]):
        """Run all requests to completion. Returns (results, wall_s);
        results are ordered by completion time."""
        for r in requests:
            total = len(r.prompt) + r.max_new_tokens
            if total > self.kv.max_seq_len:
                raise ValueError(
                    f"request {r.rid}: prompt+max_new {total} exceeds "
                    f"max_seq_len {self.kv.max_seq_len}")
            if self.kv.pages_needed(total) > self.kv.pages_per_replica - 1:
                raise ValueError(
                    f"request {r.rid} can never fit the per-replica pool")
        queue = deque(requests)
        self.results: List[RequestResult] = []
        t0 = time.perf_counter()
        self._t_submit = {r.rid: t0 for r in requests}
        while queue or (self.status != FREE).any():
            self._admit(queue)
            self._prefill_step(params_leaves)
            self._decode_step(params_leaves)
            self.steps += 1
        return self.results, time.perf_counter() - t0
