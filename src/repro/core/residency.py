"""Parameter residency: one explicit lifecycle object per parameter.

A parameter's life between two optimizer steps used to be smeared across
``GatherPlan``'s accreted flags (``frozen``, ``placement``, the
compress/fused booleans) plus frozen/placement special-cases re-derived
locally in ``core/fcdp.py``, ``core/cache.py``, ``core/schedule.py`` and
the engine's ``train_idx``/``frozen_idx`` split.  ``ParamResidency``
makes the whole lifecycle one first-class value:

  storage tier     where the authoritative bytes live between steps:
                     'dcn_sharded'     fsdp over ('data','pod') -- the
                                       leaf must cross DCN to be rebuilt
                     'pod_replicated'  fsdp over intra axes only (MiCS /
                                       hier storage, or FCDP-Comm's
                                       frozen cached layout) -- stage 1
                                       is structurally empty
                     'replicated'      not fsdp-sharded at all (too
                                       small, indivisible, or no fsdp
                                       dim; may still be TP-sharded)
  reconstruction   the two-stage gather schedule: ``stage1_axes`` (DCN),
                   ``stage2_axes`` (ICI), the ``cache_after`` boundary,
                   int8 stage-1 transport (qwZ) and collective-matmul
                   fusion of the stage-2 gather
  cache+backward   where the cached gather product parks between forward
                   and backward ('regather' | 'device' | 'host') and
                   hence what the backward reads (``backward_source``)
  update class     'trainable' (gradient + optimizer state),
                   'frozen' (no update, baseline layout: re-gathered
                   over DCN every step exactly like DeepSpeed treats a
                   frozen trunk), or
                   'frozen_cached' (frozen under a strategy with
                   ``frozen_cached_layout``: FCDP-Comm's permanently
                   pod-replicated trunk -- zero steady-state DCN bytes)

``core/strategy.py`` EMITS residencies (``ShardingStrategy.residency``);
the legacy ``GatherPlan`` is derived from one and carries it as
``plan.residency``.  Consumers -- ``cache.py`` accounting,
``schedule.py``'s gather ring, ``engine/bundle.py``'s split/merge,
``engine/{train,serve}.py`` -- read this surface instead of branching on
``ParamDef.frozen`` or ``GatherPlan.placement``.

Lifecycle invariants are enforced at construction: a non-trainable leaf
never quantizes its stage-1 transport (its stage 1 runs once into the
cached layout, not per step -- nothing to compress), never carries a
gradient-reduce compression, and never fuses its stage-2 gather; the
storage tier and the stage axes must agree.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Tuple

STORAGE_TIERS = ("dcn_sharded", "pod_replicated", "replicated")
CACHE_TIERS = ("regather", "device", "host")
UPDATE_CLASSES = ("trainable", "frozen", "frozen_cached")


@dataclass(frozen=True)
class ParamResidency:
    """The lifecycle of one parameter leaf, as resolved by its strategy."""
    # -- storage tier
    tier: str                          # STORAGE_TIERS
    # -- cache placement: where the cached gather product parks between
    # forward and backward ('regather' recomputes instead of caching)
    cache: str                         # CACHE_TIERS
    # -- update class
    update: str                        # UPDATE_CLASSES
    # -- reconstruction schedule
    fsdp_dim: Optional[int] = None     # dim index in the scan-body view
    stage1_axes: Tuple[str, ...] = ()  # DCN (inter-pod) gather axes
    stage2_axes: Tuple[str, ...] = ()  # ICI (intra-pod) gather axes
    cache_after: int = 2               # 1 | 2: which stage's product caches
    quantized_gather: bool = False     # qwZ int8 stage-1 transport
    quantized_reduce: bool = False     # qgZ int8 stage-1 grad reduce
    quant_impl: str = "jnp"
    fused: str = "none"                # 'none' | 'ag_matmul' | 'both'
    fused_impl: str = "jnp"

    def __post_init__(self):
        if self.tier not in STORAGE_TIERS:
            raise ValueError(
                f"unknown storage tier {self.tier!r}; one of {STORAGE_TIERS}")
        if self.cache not in CACHE_TIERS:
            raise ValueError(
                f"unknown cache tier {self.cache!r}; one of {CACHE_TIERS}")
        if self.update not in UPDATE_CLASSES:
            raise ValueError(
                f"unknown update class {self.update!r}; one of "
                f"{UPDATE_CLASSES}")
        if self.cache_after not in (1, 2):
            raise ValueError(
                f"cache_after must be 1 or 2, got {self.cache_after!r}")
        # tier <-> schedule consistency
        if self.stage1_axes and self.tier != "dcn_sharded":
            raise ValueError(
                f"tier {self.tier!r} cannot carry stage-1 (DCN) axes "
                f"{self.stage1_axes!r}")
        if self.tier == "dcn_sharded" and not self.stage1_axes:
            raise ValueError(
                "tier 'dcn_sharded' requires non-empty stage1_axes")
        if self.tier == "pod_replicated" and not self.stage2_axes:
            raise ValueError(
                "tier 'pod_replicated' requires non-empty stage2_axes")
        # (cache_after == 1 with an empty stage 1 is legal: it is the
        # stage-1-resident view the async grad-reduce stream consumes,
        # where the stage-1 product IS the step input -- see
        # as_stage1_resident)
        # frozen leaves decline every per-step transport optimization:
        # their stage-1 (if any) is invariant and their reconstruction
        # must stay exact -- the gating matrix the tests pin down
        if self.update != "trainable":
            if self.quantized_gather:
                raise ValueError(
                    f"{self.update!r} leaf cannot quantize its stage-1 "
                    "gather (compress_fwd): nothing re-ships per step")
            if self.quantized_reduce:
                raise ValueError(
                    f"{self.update!r} leaf cannot compress a gradient "
                    "reduce (compress_bwd): it receives no gradient")
            if self.fused != "none":
                raise ValueError(
                    f"{self.update!r} leaf cannot fuse its stage-2 gather "
                    "into a collective matmul: frozen storage is "
                    "pre-gathered / exact by contract")

    # -- update class --------------------------------------------------------
    @property
    def trainable(self) -> bool:
        return self.update == "trainable"

    @property
    def frozen(self) -> bool:
        """Any non-trainable class (frozen or frozen_cached)."""
        return self.update != "trainable"

    @property
    def invariant_gather(self) -> bool:
        """Frozen leaves gather with the invariant collective (their
        value never varies across devices or steps)."""
        return self.frozen

    # -- reconstruction ------------------------------------------------------
    @property
    def is_gathered(self) -> bool:
        return self.fsdp_dim is not None and (bool(self.stage1_axes)
                                              or bool(self.stage2_axes))

    @property
    def crosses_dcn(self) -> bool:
        """True when rebuilding this leaf moves bytes over the slow
        (inter-pod) tier."""
        return bool(self.stage1_axes)

    @property
    def occupies_ring_slot(self) -> bool:
        """Whether the streaming gather scheduler may issue this leaf's
        stage 1 a layer ahead.  Leaves with no DCN residency (frozen
        cached trunk, MiCS/hier storage, replicated leaves) must NOT
        occupy ring slots: there is no stage-1 gather to overlap."""
        return self.is_gathered and bool(self.stage1_axes)

    @property
    def backward_source(self) -> str:
        """What the backward pass reads to rebuild the weight:
        'resident' (never gathered), 'regather' (recompute both stages),
        'device_cache' / 'host_cache' (re-run stage 2 from the cached
        stage-1 shard, or read the fully-cached weight when
        cache_after == 2)."""
        if not self.is_gathered:
            return "resident"
        if self.cache == "regather":
            return "regather"
        return f"{self.cache}_cache"

    # -- what the engine owes this leaf --------------------------------------
    @property
    def receives_gradient(self) -> bool:
        return self.trainable

    @property
    def has_optimizer_state(self) -> bool:
        return self.trainable


# ---------------------------------------------------------------------------
# Classification helpers (the one place the ParamDef.frozen flag is read)
# ---------------------------------------------------------------------------

def update_class(pdef, frozen_cached_layout: bool = False) -> str:
    """Resolve a ParamDef's update class.  ``frozen_cached_layout`` is
    the emitting strategy's attribute (FCDP-Comm stores frozen leaves
    pre-gathered to the pod)."""
    if not getattr(pdef, "frozen", False):
        return "trainable"
    return "frozen_cached" if frozen_cached_layout else "frozen"


def split_frozen_indices(defs) -> Tuple[List[int], List[int]]:
    """Flat-leaf indices of (trainable, frozen) ParamDefs.

    This is the classification read every engine split goes through --
    ``core/peft.py`` re-exports it for back-compat, and
    ``engine/bundle.py`` uses the residency-carrying variant below once
    plans exist.
    """
    import jax

    from repro.core.partition import is_def
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    train = [i for i, d in enumerate(leaves)
             if update_class(d) == "trainable"]
    frozen = [i for i, d in enumerate(leaves)
              if update_class(d) != "trainable"]
    return train, frozen


def split_train_indices(residencies) -> Tuple[List[int], List[int]]:
    """Flat indices of (trainable, frozen) leaves from a residency (or
    residency-carrying plan) leaf sequence."""
    train, frozen = [], []
    for i, r in enumerate(residencies):
        res = residency_of(r)
        (train if res.trainable else frozen).append(i)
    return train, frozen


def as_stage1_resident(res: ParamResidency) -> ParamResidency:
    """The lifecycle of a leaf whose stage-1 (DCN) gather already ran
    OUTSIDE the step body (the async grad-reduce stream differentiates
    w.r.t. the stage-1-gathered view): no DCN axes remain, the tier is
    what the stage-1 product is -- pod-replicated (or fully replicated
    when there was no stage 2 to begin with) -- and there is no stage-1
    transport left to quantize."""
    if not res.stage1_axes:
        return res
    return dataclasses.replace(
        res, stage1_axes=(),
        tier="pod_replicated" if res.stage2_axes else "replicated",
        quantized_gather=False)


def residency_of(obj) -> ParamResidency:
    """Accept a ParamResidency or anything carrying one (a GatherPlan)."""
    if isinstance(obj, ParamResidency):
        return obj
    res = getattr(obj, "residency", None)
    if res is None:
        raise TypeError(
            f"{type(obj).__name__} carries no ParamResidency; residency "
            "consumers need plans emitted by ShardingStrategy.residency/"
            "gather_plan")
    return res
