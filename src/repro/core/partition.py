"""Parameter partitioning: ParamDef trees and their storage layouts.

Every parameter is described by a ParamDef whose `dims` tag each array
dimension with a logical role:

  'stack' - scan-group dimension (never sharded)
  'fsdp'  - ZeRO-3 sharding dimension (gathered per layer inside the step)
  'tp'    - tensor/expert-parallel dimension (owned shard, never gathered)
  None    - unsharded

WHICH mesh axes the fsdp dim shards over is a per-tensor decision owned
by ``repro.core.strategy`` (full ('data','pod') sharding for the
zero3-family strategies -- intra-major, so the stage-1-then-stage-2
gather reconstructs true global order -- pod-replicated ('data',) for
MiCS and for frozen FCDP-Comm params), resolved per ParamDef via
``strategy.resolve_strategies`` (explicit ``ParamDef.strategy`` tag >
``SystemConfig.mode_overrides`` rule > ``mode``). The module-level
helpers here accept a mode name or a resolved ``ShardingStrategy`` and
delegate; on the single-pod mesh ('data','model') there is no pod axis
and the fsdp axes collapse to ('data',).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import flatten_with_path
from repro.core.strategy import resolve_strategy


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    dims: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | embed
    init_scale: float = 1.0
    frozen: bool = False          # FCDP-Comm classification (set by peft)
    label: str = ""               # dotted path, filled by label_tree
    # 'inter_only': ZeRO-shard only over the slow (pod) axis, keeping the
    # tensor resident within the pod -- the weight-stationary trade for
    # tensors whose per-step gather volume exceeds their resident size
    # (MoE expert weights; beyond-paper, see EXPERIMENTS.md SSPerf)
    fsdp_scope: str = "full"      # full | inter_only
    # per-tensor sharding strategy. None resolves through
    # SystemConfig.mode_overrides / SystemConfig.mode at
    # StepBundle/model construction (core.strategy.resolve_strategies);
    # an explicit name here wins over both. After resolution every leaf
    # carries its resolved name, which is the dispatch/accounting key
    # for the CompositeStrategy facade and the per-group planner split.
    strategy: Optional[str] = None
    # the leaf is consumed as the RHS of one [..., K] @ [K, N] output
    # projection routed through models/layers.matmul -- the consumption
    # pattern the gather-fused collective matmul requires. Opt-in at the
    # def site because shape alone cannot tell a projection from, e.g.,
    # an embedding table with the same ("tp","fsdp") dims; the plan-level
    # rule in core/strategy.gather_plan gates further.
    fusable: bool = False

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)

    @property
    def fsdp_dim(self) -> Optional[int]:
        return self.dims.index("fsdp") if "fsdp" in self.dims else None

    @property
    def tp_dim(self) -> Optional[int]:
        return self.dims.index("tp") if "tp" in self.dims else None

    def size(self) -> int:
        return math.prod(self.shape)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn: Callable, tree, *rest):
    return jax.tree.map(fn, tree, *rest, is_leaf=is_def)


def label_tree(tree):
    """Attach dotted-path labels to every ParamDef in the tree."""
    paths_vals, treedef = flatten_with_path(tree, is_leaf=is_def)
    out = []
    for path, pdef in paths_vals:
        name = ".".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append(replace(pdef, label=name))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Storage layout
# ---------------------------------------------------------------------------

def storage_fsdp_axes(mesh, mode, frozen: bool) -> Tuple[str, ...]:
    """Which mesh axes the fsdp dim is sharded over in storage.

    ``mode`` is a strategy name or ShardingStrategy; the layout decision
    (and the FCDP-Comm frozen asymmetry) lives on the strategy object.
    """
    return resolve_strategy(mode).storage_fsdp_axes(mesh, frozen)


def effective_fsdp_axes(pdef: "ParamDef", mesh, mode) -> Tuple[str, ...]:
    return resolve_strategy(mode).effective_fsdp_axes(pdef, mesh)


def storage_spec(pdef: ParamDef, mesh, mode, min_shard_size: int = 0) -> P:
    return resolve_strategy(mode).storage_spec(pdef, mesh, min_shard_size)


def spec_tree(defs, mesh, mode: str, min_shard_size: int = 0):
    return tree_map_defs(
        lambda d: storage_spec(d, mesh, mode, min_shard_size), defs)


def sharding_tree(defs, mesh, mode: str, min_shard_size: int = 0):
    return tree_map_defs(
        lambda d: NamedSharding(mesh, storage_spec(d, mesh, mode, min_shard_size)),
        defs)


def shape_dtype_tree(defs, mesh, mode: str, min_shard_size: int = 0):
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, d.dtype,
            sharding=NamedSharding(mesh, storage_spec(d, mesh, mode, min_shard_size))),
        defs)


# ---------------------------------------------------------------------------
# Initialization (smoke tests / examples only; dry-run never allocates)
# ---------------------------------------------------------------------------

def _init_one(key, pdef: ParamDef):
    if pdef.init == "zeros":
        return jnp.zeros(pdef.shape, pdef.dtype)
    if pdef.init == "ones":
        return jnp.ones(pdef.shape, pdef.dtype)
    fan_in = pdef.shape[-2] if len(pdef.shape) >= 2 else pdef.shape[-1]
    scale = pdef.init_scale / math.sqrt(max(fan_in, 1))
    if pdef.init == "embed":
        scale = pdef.init_scale * 0.02
    return (jax.random.normal(key, pdef.shape, jnp.float32) * scale).astype(pdef.dtype)


def init_params(defs, seed: int = 0, mesh=None, mode=None,
                min_shard_size: int = 0):
    """Materialize parameters; with a mesh, place them in storage layout."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(jax.random.key(seed), max(len(leaves), 1))
    vals = []
    for k, d in zip(keys, leaves):
        v = _init_one(k, d)
        if mesh is not None:
            v = jax.device_put(
                v, NamedSharding(mesh, storage_spec(d, mesh, mode, min_shard_size)))
        vals.append(v)
    return jax.tree.unflatten(treedef, vals)


def count_tree_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(d.size() for d in leaves)


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m
