"""The streaming gather scheduler: FCDP's communication schedule as a
first-class subsystem.

FCDP's throughput story is a *schedule* -- which all-gather stage runs
when, and what the backward reads instead of re-communicating. This
module owns that schedule for the layer-group scans (it replaces the
hand-rolled double-buffer that used to live inline in
``models/stack.py``) and provides the leaf-level primitives for the
second stream, the async pod-axis gradient reduce in
``engine/train.py``.

Stream 1 -- depth-k stage-1 gather prefetch (:class:`GatherScheduler`)
----------------------------------------------------------------------
The scheduler runs the layer-group scan with a ring buffer of ``k``
in-flight stage-1 (inter/DCN) gather caches::

    ring = [stage1(params[0]), ..., stage1(params[k-1])]   # prologue
    scan i = 0..n-k-1:
        issue stage1(params[i+k])        # no data dependency on layer
        x = compute(x, stage2(ring[0]))  # i's compute: overlaps under
        ring = ring[1:] + [issued]       # XLA's latency-hiding scheduler
    drain the ring: k more compute steps  # epilogue

``k == 0`` is the sequential schedule (each step runs its own fused
two-stage gather). Because the ring rides the scan carry, the backward
pass reads the carried caches back instead of re-running stage 1:
depth k trades k in-flight stage-1 buffers (plus the saved carries)
for up to k layers' worth of DCN overlap. The same scheduler drives
both the stateless scan (train loss / encoder) and the stateful
prefill/decode scan (engine/serve.py); it is a structural no-op when
no plan has a non-empty stage 1 (MiCS/hier, single-pod meshes,
FCDP-Comm frozen layouts).

Stream 2 -- async pod-axis gradient reduce (leaf-level helpers)
---------------------------------------------------------------
On the gradient-accumulation path, the pod-axis gradient
reduce-scatter of microbatch i can run concurrently with microbatch
i+1's forward instead of serializing inside the backward. The
mechanism mirrors stream 1: the microbatch loss is differentiated with
respect to the *stage-1-gathered* parameter view
(:func:`stage1_resident_plans` strips the inter axes the model would
otherwise re-gather), so each microbatch's backward stops at
stage-1-level gradients; :func:`leaf_stage1_reduce` then applies the
deferred pod-axis psum_scatter one microbatch later, where it has no
data dependency on the in-flight forward. One stage-1-sized gradient
buffer is in flight at all times; total reduce volume is unchanged.

Stream 3 -- cross-step pipelined optimizer epilogue (leaf-level helpers)
------------------------------------------------------------------------
Streams 1 and 2 hide the *in-step* collectives; the once-per-step
optimizer tail -- the LAST microbatch's pod-axis reduce-scatter, the
optimizer apply, and the widened updated-shard all-gather -- still
serializes between steps. With ``SystemConfig.cross_step_pipeline`` the
train engine carries that epilogue across the step boundary: step i
returns (accumulated storage-level grads, the last microbatch's
stage-1-level pending grads) as a step-level carry, and step i+1
finalizes it at its top, where the epilogue collectives have no data
dependency on step i+1's first microbatch forward prologue and overlap
with it under XLA's latency-hiding scheduler. Staleness-free by
construction: the finalized (updated) parameters are what step i+1's
forward consumes -- the swap happens before the first layer that reads
them, so only the collectives' latency moves, never the values.
:func:`cross_step_enabled` is the single source of truth for whether
the stream is live; :func:`cross_step_buffer_bytes` is the analytic
per-chip size of the carried buffers.

Crash safety: the carry is part of the persisted training state, not a
transient. The restart driver checkpoints it as a manifest-v2 ``carry``
section (checkpoint/checkpointer.py) so a checkpoint taken mid-pipeline
round-trips bit-exactly; on a step failure the driver flushes the
in-flight epilogue before restoring (``run_with_restarts(flush_fn=...)``)
so no completed step's update is dropped; and because the carry's
leading partial dims are mesh-shaped, ``runtime/elastic.reshard_state``
invalidates it on any mesh change and the driver re-runs the last step
to re-prime (``engine/train.py:cross_step_carry_signature`` is the
compatibility check).

Memory accounting
-----------------
:func:`prefetch_buffer_bytes` is the analytic per-chip size of the k
in-flight ring slots. FCDP-Cache's planner (core/cache.py) counts it
against the tau/HBM budget and demotes in fixed order -- the cross-step
carry first (it costs only step-boundary overlap), then prefetch depth,
then the device cache; launch/dryrun.py and launch/roofline.py surface
all three per cell.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.fcdp import (_ag_fn, gather_param, gather_stage1,
                             gather_stage2)
from repro.core.residency import as_stage1_resident, residency_of
from repro.core.strategy import GatherPlan, leaf_group

_is_plan = lambda x: isinstance(x, GatherPlan)  # noqa: E731


def _in_ring(p) -> bool:
    """Ring membership is a residency property: only leaves with a DCN
    residency (a non-empty stage 1 to issue ahead) occupy ring slots."""
    return _is_plan(p) and residency_of(p).occupies_ring_slot


class GatherScheduler:
    """Owns the gather/communication schedule of one layer-group scan.

    Resolves the ring depth once (strategy stream capability x config x
    mesh x plan prefetchability) and runs whichever schedule applies:

      depth 0: sequential -- each scan step runs its own fused
               two-stage gather (the paper-faithful baseline).
      depth k: ring buffer of k in-flight stage-1 caches; step i issues
               layer i+k's stage-1 (DCN) gather while computing layer i
               from the oldest slot via stage 2 only.

    The ring is keyed by resolved strategy group: only leaves whose
    plan has a non-empty stage 1 (the streaming groups) ride the k ring
    slots; leaves of single-stage groups (mics/hier/frozen layouts,
    replicated tensors -- under per-tensor mixed sharding these coexist
    with streaming leaves in one scan) are sliced at the current step
    and gathered in place, so the carry holds exactly the buffers
    ``prefetch_buffer_bytes`` accounts for.

    ``enabled=False`` forces the sequential schedule regardless of
    config (used by the gather-free sharded-MoE decode path, whose raw
    expert shards must not be pre-gathered).
    """

    def __init__(self, strategy, sys, mesh_like, plans,
                 enabled: bool = True):
        self.strategy = strategy
        self.plans = plans
        self.plan_leaves = jax.tree.leaves(plans, is_leaf=_is_plan)
        prefetchable = any(_in_ring(p) for p in self.plan_leaves)
        self.depth = (strategy.prefetch_depth(sys, mesh_like)
                      if (enabled and prefetchable) else 0)

    # -- entry point ----------------------------------------------------------
    def run(self, make_body: Callable, wrap: Callable, stacked_params,
            x, aux0, stacked_state=None):
        """Scan the layer group under the resolved schedule.

        make_body(gather_leaf) must return ``body(x, params_slice,
        state_slice) -> (x, new_state, aux)`` where ``gather_leaf``
        reconstructs one param leaf from whatever the schedule feeds it
        (raw shards on the sequential schedule, stage-1 caches on the
        prefetch schedule). ``wrap`` applies the remat policy around the
        body. Returns ``(x, new_stacked_state | None, aux)``.
        """
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        k = min(self.depth, n)
        if k == 0:
            return self._run_sequential(make_body, wrap, stacked_params,
                                        x, aux0, stacked_state)
        return self._run_prefetch(make_body, wrap, stacked_params,
                                  x, aux0, stacked_state, n, k)

    # -- sequential schedule --------------------------------------------------
    def _run_sequential(self, make_body, wrap, stacked_params, x, aux0,
                        stacked_state):
        wrapped = wrap(make_body(gather_param))
        if stacked_state is not None:
            def body(carry, inp):
                x, = carry
                params_slice, state_slice = inp
                x, new_state, a = wrapped(x, params_slice, state_slice)
                return (x,), (new_state, a)
            (x,), (new_states, auxs) = jax.lax.scan(
                body, (x,), (stacked_params, stacked_state))
            return x, new_states, aux0 + jnp.sum(auxs)

        def body(carry, params_slice):
            x, aux = carry
            x, _, a = wrapped(x, params_slice, None)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, aux0), stacked_params)
        return x, None, aux

    # -- depth-k prefetch schedule --------------------------------------------
    def _run_prefetch(self, make_body, wrap, stacked_params, x, aux0,
                      stacked_state, n: int, k: int):
        wrapped = wrap(make_body(gather_stage2))
        # partition the leaves by stream group: only plans with a
        # non-empty stage 1 ride the ring; the rest (single-stage
        # strategy groups under mixed sharding, frozen layouts, small
        # replicated tensors) are sliced at the step that consumes them.
        # gather_stage2 is the correct reconstruction for BOTH: stage 1
        # is the identity on every non-ring plan.
        leaves, treedef = jax.tree.flatten(stacked_params)
        ring_ix = [i for i, p in enumerate(self.plan_leaves)
                   if _in_ring(p)]
        dir_ix = [i for i in range(len(leaves)) if i not in set(ring_ix)]
        ring_plans = [self.plan_leaves[i] for i in ring_ix]

        def stage1_flat(ws):
            return [gather_stage1(w, p) for w, p in zip(ws, ring_plans)]

        def merge(ring_slot, dir_slice):
            out = [None] * len(leaves)
            for j, i in enumerate(ring_ix):
                out[i] = ring_slot[j]
            for j, i in enumerate(dir_ix):
                out[i] = dir_slice[j]
            return jax.tree.unflatten(treedef, out)

        # prologue: fill the ring with layers 0..k-1's stage-1 caches
        ring0 = tuple(stage1_flat([leaves[i][j] for i in ring_ix])
                      for j in range(k))
        # step i consumes ring slot i and issues layer i+k's stage 1:
        # ring leaves scan over slices k..n-1, direct leaves over 0..n-k-1
        ring_rest = [leaves[i][k:] for i in ring_ix]
        dir_lead = [leaves[i][:n - k] for i in dir_ix]

        def dir_tail(j):
            return [leaves[i][n - k + j] for i in dir_ix]

        if stacked_state is not None:
            lead_state = jax.tree.map(lambda a: a[:n - k], stacked_state)

            def body(carry, inp):
                x, aux, ring = carry
                ahead, cur_dir, state_slice = inp
                # issue layer i+k's stage-1 (DCN) gather: independent of
                # layer i's compute below, so the scheduler overlaps them
                cache_next = stage1_flat(ahead)
                x, new_state, a = wrapped(x, merge(ring[0], cur_dir),
                                          state_slice)
                return (x, aux + a, ring[1:] + (cache_next,)), new_state
            (x, aux, ring), new_lead = jax.lax.scan(
                body, (x, aux0, ring0), (ring_rest, dir_lead, lead_state))
            # epilogue: drain the ring against the last k state slices
            tails = []
            for j in range(k):
                st = jax.tree.map(lambda a, i=n - k + j: a[i], stacked_state)
                x, st_new, a = wrapped(x, merge(ring[j], dir_tail(j)), st)
                aux = aux + a
                tails.append(st_new)
            tail = jax.tree.map(lambda *xs: jnp.stack(xs), *tails)
            new_state = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), new_lead, tail)
            return x, new_state, aux

        def body(carry, inp):
            x, aux, ring = carry
            ahead, cur_dir = inp
            cache_next = stage1_flat(ahead)
            x, _, a = wrapped(x, merge(ring[0], cur_dir), None)
            return (x, aux + a, ring[1:] + (cache_next,)), None
        (x, aux, ring), _ = jax.lax.scan(body, (x, aux0, ring0),
                                         (ring_rest, dir_lead))
        for j in range(k):                    # epilogue: drain the ring
            x, _, a = wrapped(x, merge(ring[j], dir_tail(j)), None)
            aux = aux + a
        return x, None, aux


# ---------------------------------------------------------------------------
# Stream 2: leaf-level stage-1 primitives for the async gradient reduce
# (storage-level views: the fsdp dim index comes from the ParamDef, NOT
# from the plan, whose dim is shifted to the scan-body view)
# ---------------------------------------------------------------------------

def stage1_resident_plans(plans):
    """Plan tree for a model consuming stage-1-gathered parameters:
    the inter (DCN) axes are stripped, so every in-model gather runs
    stage 2 only and every gather transpose reduces intra-pod only."""
    def strip(p):
        if not (_is_plan(p) and p.inter_axes):
            return p
        return dataclasses.replace(
            p, inter_axes=(),
            residency=as_stage1_resident(residency_of(p)))
    return jax.tree.map(strip, plans, is_leaf=_is_plan)


def leaf_stage1(w: jax.Array, pdef, plan: GatherPlan) -> jax.Array:
    """Stage-1 (inter/DCN) gather of a whole (possibly stacked) storage
    leaf. Identity when the plan has no inter axes. Under
    param_compress='int8_pod' the leaf transports int8 blocks + fp32
    scales (quantized at leaf level, so block boundaries differ from the
    sequential schedule's per-layer-slice blocks -- see
    ARCHITECTURE.md §Quantized collectives)."""
    if not (plan.is_gathered and plan.inter_axes):
        return w
    # residency guarantees quantized_gather is never set on a frozen
    # leaf, so no local frozen re-derivation is needed here
    if (residency_of(plan).quantized_gather
            and len(plan.inter_axes) == 1):
        from repro.core.grad_compress import quantized_stage1_gather
        # not differentiated here (the async schedule differentiates
        # w.r.t. the gathered view); the exact-bwd variant is fine
        return quantized_stage1_gather(w, plan.inter_axes[0], pdef.fsdp_dim,
                                       False, plan.quant_impl)
    return _ag_fn(plan)(w, plan.inter_axes, pdef.fsdp_dim)


def leaf_stage1_reduce(gbar: jax.Array, pdef, plan: GatherPlan) -> jax.Array:
    """Transpose of :func:`leaf_stage1`: pod-axis reduce-scatter of a
    stage-1-level gradient down to the storage shard. This is the
    collective the async stream takes off the critical path. Under
    grad_compress='int8_pod' it transports int8 (same per-microbatch
    quantization the sequential schedule's custom vjp applies)."""
    if not (plan.is_gathered and plan.inter_axes):
        return gbar
    if plan.compress_bwd and len(plan.inter_axes) == 1:
        from repro.core.grad_compress import int8_psum_scatter
        return int8_psum_scatter(gbar, plan.inter_axes[0], pdef.fsdp_dim,
                                 plan.quant_impl)
    return jax.lax.psum_scatter(gbar, plan.inter_axes,
                                scatter_dimension=pdef.fsdp_dim, tiled=True)


# ---------------------------------------------------------------------------
# Analytic memory accounting (consumed by core/cache.py and launch/)
# ---------------------------------------------------------------------------

def async_reduce_enabled(run, strategy, mi) -> bool:
    """Whether engine/train.py actually runs the async grad-reduce
    stream for this run: the flag must be on, the strategy willing, a
    pod axis present, and gradient accumulation active.

    int8 gradient compression COMPOSES with the stream: the deferred
    pod reduce (leaf_stage1_reduce) runs the same per-microbatch int8
    reduce-scatter the sequential schedule's custom stage-1 vjp applies
    -- it used to silently disable stream 2."""
    sys = run.system
    return (bool(run.microbatch and run.microbatch > 1)
            and strategy.async_grad_reduce_active(sys, mi))


def async_buffer_bytes_by_group(strategy, def_leaves, plan_leaves,
                                mi) -> dict:
    """Per-strategy-group split of :func:`async_buffer_bytes`."""
    out: dict = {}
    for d, p in zip(def_leaves, plan_leaves):
        if not (_is_plan(p) and p.is_gathered and p.inter_axes):
            continue
        view = strategy.cached_bytes_for(d, p, mi)
        total = view                         # gathered param view
        if residency_of(p).receives_gradient:
            total += view                    # in-flight grad buffer
        g = leaf_group(strategy, d)
        out[g] = out.get(g, 0.0) + total
    return out


def async_buffer_bytes(strategy, def_leaves, plan_leaves, mi) -> float:
    """Per-chip HBM bytes the async grad-reduce stream keeps resident:
    the stage-1-gathered view of EVERY leaf with a non-empty stage 1
    (the microbatch loss consumes pre-gathered params at leaf level
    rather than gathering per layer inside the scan) plus the carried
    stage-1-level gradient buffer for the trainable leaves. Only the
    streaming strategy groups contribute (single-stage groups under
    mixed sharding defer nothing)."""
    return sum(async_buffer_bytes_by_group(
        strategy, def_leaves, plan_leaves, mi).values())


def cross_step_enabled(run, strategy, mi) -> bool:
    """Whether engine/train.py actually pipelines the optimizer epilogue
    across the step boundary for this run: the stream rides the async
    grad-reduce stream (the carried pending gradient IS stream 2's
    deferred pod reduce), so all of stream 2's conditions apply, plus
    the cross_step_pipeline flag and the strategy's willingness."""
    return (async_reduce_enabled(run, strategy, mi)
            and strategy.cross_step_active(run.system, mi))


def _leaf_shard_bytes(d, p: GatherPlan, mi) -> float:
    """Per-chip bytes of one leaf's STORAGE shard, derived from its own
    gather plan (not the whole-mesh fsdp axes: a pod-replicated mics/hier
    leaf shards over the intra axes only)."""
    import jax
    nbytes = d.size() * jax.dtypes.canonicalize_dtype(d.dtype).itemsize
    deg = mi.tp if d.tp_dim is not None else 1
    if p.is_gathered:
        import math
        deg *= math.prod(mi.size(a) for a in p.inter_axes + p.intra_axes)
    return nbytes / max(deg, 1)


def cross_step_buffer_bytes_by_group(strategy, def_leaves, plan_leaves,
                                     mi) -> dict:
    """Per-strategy-group split of :func:`cross_step_buffer_bytes`."""
    import math
    out: dict = {}
    for d, p in zip(def_leaves, plan_leaves):
        if not _is_plan(p) or not residency_of(p).trainable:
            continue
        shard = _leaf_shard_bytes(d, p, mi)
        inter_deg = 1
        if p.is_gathered and p.inter_axes:
            inter_deg = math.prod(mi.size(a) for a in p.inter_axes) or 1
        # storage-level accumulated grads + stage-1-level pending grads
        # (pending collapses to the storage shard for single-stage leaves)
        g = leaf_group(strategy, d)
        out[g] = out.get(g, 0.0) + shard * (1.0 + inter_deg)
    return out


def cross_step_buffer_bytes(strategy, def_leaves, plan_leaves, mi) -> float:
    """Per-chip HBM bytes the cross-step carry keeps resident across the
    step boundary: for every trainable leaf, one storage-shard-sized
    accumulated-gradient buffer plus one stage-1-shard-sized pending
    gradient buffer (the last microbatch's deferred pod reduce operand).
    Frozen leaves carry nothing. The pre-update parameter view the next
    step finalizes against is the step input itself, already counted in
    the argument bytes."""
    return sum(cross_step_buffer_bytes_by_group(
        strategy, def_leaves, plan_leaves, mi).values())


def prefetch_buffer_bytes_by_group(strategy, def_leaves, plan_leaves, mi,
                                   depth: int) -> dict:
    """Per-strategy-group split of :func:`prefetch_buffer_bytes`."""
    out: dict = {}
    if depth <= 0:
        return out
    for d, p in zip(def_leaves, plan_leaves):
        if not _in_ring(p):
            continue
        if "stack" not in d.dims:
            continue
        n = d.shape[d.dims.index("stack")]
        g = leaf_group(strategy, d)
        out[g] = (out.get(g, 0.0)
                  + float(depth) * strategy.cached_bytes_for(d, p, mi)
                  / max(n, 1))
    return out


def prefetch_buffer_bytes(strategy, def_leaves, plan_leaves, mi,
                          depth: int) -> float:
    """Per-chip HBM bytes of the ``depth`` in-flight stage-1 ring slots.

    One ring slot holds one layer group's stage-1 caches: the per-leaf
    stage-1 shard size (strategy.cached_bytes_for, cache_after == 1)
    divided by that leaf's stack length. Leaves without a stage 1
    (single-stage strategy groups, frozen layouts, replicated tensors)
    or outside the scan contribute nothing -- since the scheduler keys
    its ring by stream group, this is exactly what the scan carries.
    """
    return sum(prefetch_buffer_bytes_by_group(
        strategy, def_leaves, plan_leaves, mi, depth).values())
