"""FCDP-Sched: the two-stage parameter gather and its caching schedule.

The paper's per-layer schedule (Fig. 4) maps onto JAX as:

  stage 1 (inter / DCN):  w_cache = all_gather(w_shard, 'pod')
  stage 2 (intra / ICI):  w_full  = all_gather(w_cache, 'data')

The layer consuming ``w_full`` is wrapped in ``jax.checkpoint`` whose
policy assigns the named value ``fcdp_cache`` per the strategy's
``cache_placement`` (see repro.core.strategy):

  zero3   -> Recompute   : backward re-runs stage 1 + stage 2 (2x inter AG)
  zeropp  -> Saveable    : cached shard lives in HBM, backward re-runs stage 2
  fcdp    -> Offloadable : cached shard lives in pinned host memory,
                           backward re-runs stage 2 only  (the paper)
  mics    -> storage is already pod-replicated; stage 1 is empty and the
             single intra stage recomputes (fwd+bwd intra AG, no DCN AG)

On a mesh without a 'pod' axis (single pod) there is no slow tier; the
cache boundary moves to after stage 2 (cache the fully gathered weight)
so zeropp/fcdp still eliminate the backward all-gather, reproducing the
paper's N=1 limit.

Frozen parameters (FCDP-Comm) are *stored* in the cached layout
(pod-replicated, intra-sharded, host-resident): their reconstruction
never touches DCN and they receive no gradient. See core/comm.py.

The gather is exposed both fused (``gather_param``) and split into its
two stages (``gather_stage1`` / ``gather_stage2``) so the streaming
gather scheduler (core/schedule.py) can issue layer i+k's stage-1 DCN
gather concurrently with layer i's compute, and ``_ag_fn`` (the
frozen/trainable gather-primitive selector) is shared with the
scheduler's leaf-level stage-1 helpers.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.ad_checkpoint import checkpoint_name

from repro.compat import all_gather_invariant
from repro.core.partition import ParamDef
from repro.core.residency import residency_of
from repro.core.strategy import GatherPlan, resolve_strategy

try:  # name-based remat policies need the `name` primitive
    from jax._src.ad_checkpoint import name_p
    import jax._src.interpreters.partial_eval as pe
    _HAVE_POLICY_INTERNALS = True
except Exception:  # pragma: no cover - future jax versions
    name_p, pe = None, None
    _HAVE_POLICY_INTERNALS = False

CACHE_NAME = "fcdp_cache"
FULL_NAME = "fcdp_full"
ACT_NAME = "act_ckpt"


def cache_name(plan: GatherPlan) -> str:
    """Placement-suffixed checkpoint name of one plan's cache boundary.

    The placement travels in the name (``fcdp_cache:host`` etc.) so ONE
    remat policy can serve a layer body whose leaves belong to different
    strategy groups (per-tensor mixed sharding): an fcdp-group weight
    offloads its stage-1 cache to pinned host while a mics-group expert
    in the same body recomputes its gather, without the policy knowing
    which strategy produced which mark."""
    return f"{CACHE_NAME}:{residency_of(plan).cache}"


def make_gather_plan(pdef: ParamDef, mesh, mode,
                     min_shard_size: int = 0,
                     compress_bwd: bool = False,
                     param_compress: bool = False,
                     quant_impl: str = "jnp",
                     fused_matmul: str = "none",
                     fused_impl: str = "jnp") -> GatherPlan:
    """Derive the gather plan matching ``storage_spec`` for this param.
    ``mode`` is a strategy name or ShardingStrategy object."""
    return resolve_strategy(mode).gather_plan(
        pdef, mesh, min_shard_size, compress_bwd, param_compress, quant_impl,
        fused_matmul, fused_impl)


def plan_tree(defs, mesh, mode, min_shard_size: int = 0,
              compress_bwd: bool = False, param_compress: bool = False,
              quant_impl: str = "jnp", fused_matmul: str = "none",
              fused_impl: str = "jnp"):
    return resolve_strategy(mode).plan_tree(
        defs, mesh, min_shard_size, compress_bwd, param_compress, quant_impl,
        fused_matmul, fused_impl)


@jax.tree_util.register_pytree_node_class
class FusedParam:
    """A stage-1 cached shard standing in for the fully gathered weight.

    When a plan is flagged ``fused``, ``gather_stage2`` skips the intra
    all-gather and hands the consumer this wrapper instead: the cache
    (marked for the remat policy exactly like the unfused path) plus the
    plan, which carries the ring axis and mode. ``models/layers.matmul``
    dispatches on it -- the stage-2 gather then happens INSIDE the
    consuming matmul's ring schedule (kernels/collective_matmul.py),
    overlapped chunk by chunk. Registered as a pytree so it rides
    ``jax.tree`` maps, scan carries, and ``jax.checkpoint`` untouched;
    the plan is static aux data."""

    def __init__(self, cache: jax.Array, plan: GatherPlan):
        self.cache = cache
        self.plan = plan

    def tree_flatten(self):
        return (self.cache,), self.plan

    @classmethod
    def tree_unflatten(cls, plan, children):
        return cls(children[0], plan)

    def __repr__(self) -> str:
        return f"FusedParam({getattr(self.cache, 'shape', None)}, " \
               f"fused={self.plan.fused!r})"


def _ag_fn(plan: GatherPlan):
    """Gather primitive for this plan.

    Frozen params (FCDP-Comm / serving) gather with the *invariant*
    all-gather: they receive no gradient, and the invariant type keeps
    downstream values replicated over the gathered axes (required for
    serve-step output typing). Trainable params use the varying
    all-gather, whose transpose is the ZeRO-3 gradient reduce-scatter.
    """
    if residency_of(plan).invariant_gather:
        def ag(x, axes, axis):
            for a in axes:  # invariant AG takes one axis at a time
                x = all_gather_invariant(x, a, axis=axis, tiled=True)
            return x
    else:
        def ag(x, axes, axis):
            return jax.lax.all_gather(x, axes, axis=axis, tiled=True)
    return ag


def gather_stage1(w: jax.Array, plan: GatherPlan) -> jax.Array:
    """Stage 1 (inter / DCN) all-gather only: shard -> cached shard.

    Identity when the plan has no inter axes (single pod, MiCS,
    FCDP-Comm frozen layout). Must run inside shard_map."""
    if not plan.is_gathered or not plan.inter_axes:
        return w
    # the residency layer guarantees a non-trainable leaf never carries a
    # quantized transport (ParamResidency enforces it at construction),
    # so the compression branches need no local frozen re-derivation
    res = residency_of(plan)
    if res.quantized_gather and len(plan.inter_axes) == 1:
        # qwZ: int8 blocks + fp32 scales on the DCN wire, dequantized on
        # arrival -- what lands in the (host) cache is the dequantized
        # bf16 stage-1 view, so backward reuse stays free/full-precision
        from repro.core.grad_compress import quantized_stage1_gather
        return quantized_stage1_gather(w, plan.inter_axes[0], plan.fsdp_dim,
                                       res.quantized_reduce, plan.quant_impl)
    if res.quantized_reduce and len(plan.inter_axes) == 1:
        from repro.core.grad_compress import compressed_stage1_gather
        return compressed_stage1_gather(w, plan.inter_axes[0], plan.fsdp_dim,
                                        plan.quant_impl)
    return _ag_fn(plan)(w, plan.inter_axes, plan.fsdp_dim)


def gather_stage2(w: jax.Array, plan: GatherPlan) -> jax.Array:
    """Stage 2 (intra / ICI) all-gather: cached shard -> full (TP-local)
    parameter, with the cache/full named-checkpoint boundaries marked for
    the remat policy. Must run inside shard_map.

    Fused plans return a :class:`FusedParam` instead of gathering: the
    cache boundary is marked identically (so the remat placement is
    unchanged) but the intra gather -- and with it the FULL_NAME mark,
    since no full weight ever materializes -- is deferred into the
    consuming matmul's ring."""
    if not plan.is_gathered:
        return w
    if plan.cache_after == 1:
        w = checkpoint_name(w, cache_name(plan))
    if plan.is_fused and plan.intra_axes:
        return FusedParam(w, plan)
    if plan.intra_axes:
        w = _ag_fn(plan)(w, plan.intra_axes, plan.fsdp_dim)
    if plan.cache_after == 2:
        w = checkpoint_name(w, cache_name(plan))
    return checkpoint_name(w, FULL_NAME)


def gather_param(w: jax.Array, plan: GatherPlan) -> jax.Array:
    """Reconstruct the full (TP-local) parameter from its ZeRO shard
    (both stages fused -- the sequential, non-prefetched schedule)."""
    if not plan.is_gathered:
        return w
    return gather_stage2(gather_stage1(w, plan), plan)


# ---------------------------------------------------------------------------
# Remat policies (FCDP-Sched placement decisions)
# ---------------------------------------------------------------------------

def make_remat_policy(cache_placement: str, activation_policy: str = "save_all",
                      host_offload: bool = True,
                      promote_to_device: bool = False):
    """Build a jax.checkpoint policy.

    cache_placement: 'device' | 'host' | 'regather' -- the fallback for
        legacy unsuffixed cache marks; plans emitted by the strategies
        carry their own placement in the mark name (``fcdp_cache:host``)
        so a mixed-strategy layer body needs only this one policy.
    activation_policy: 'save_all' (paper-faithful, torch-like) |
                       'block_io' (full activation remat) |
                       'offload_acts' (named activations offloaded)
    promote_to_device: FCDP-Cache's tau split (leading layer segments
        keep the cached shard in HBM): promotes HOST-placed caches to
        device and leaves regather/device groups untouched, so the
        per-segment promotion is safe on mixed-strategy bodies.
    """
    if not _HAVE_POLICY_INTERNALS:  # pragma: no cover
        return jax.checkpoint_policies.nothing_saveable

    # torch-autograd-like 'save_all': keep the outputs of matmuls and of
    # paid-for collectives; recompute cheap elementwise chains (incl. the
    # f32 norm upcasts, which would otherwise dominate activation memory).
    SAVE_PRIMS = {"dot_general", "conv_general_dilated", "psum", "psum2",
                  "psum_invariant", "all_to_all", "psum_scatter"}

    # 'save_collectives' (beyond-paper perf policy, see EXPERIMENTS.md
    # SSPerf): save only paid-for collective outputs so the backward remat
    # recomputes matmuls (cheap, local) but never re-runs a psum /
    # all_to_all (expensive, global). ~-33% on the TP-activation
    # all-reduce volume vs block_io at ~0.25 GiB/layer extra HBM.
    COLLECTIVE_SAVE_PRIMS = {"psum", "psum2", "psum_invariant",
                             "all_to_all", "psum_scatter"}

    def policy(prim, *_, **params):
        s = getattr(prim, "name", str(prim))
        if s == "all_gather" or s == "all_gather_invariant":
            # gathered tensors are never implicitly saved: the whole point
            return pe.Recompute
        if prim is name_p:
            name = params.get("name")
            if name == CACHE_NAME or (name or "").startswith(CACHE_NAME + ":"):
                placement = (name.split(":", 1)[1] if ":" in name
                             else cache_placement)
                if promote_to_device and placement == "host":
                    placement = "device"
                if placement == "device":
                    return pe.Saveable
                if placement == "host":
                    if host_offload:
                        return pe.Offloadable(src="device", dst="pinned_host")
                    return pe.Saveable
                return pe.Recompute
            if name == FULL_NAME:
                return pe.Recompute
            if name == ACT_NAME:
                if activation_policy == "offload_acts":
                    return pe.Offloadable(src="device", dst="pinned_host")
                return pe.Saveable
            return pe.Recompute
        if activation_policy == "save_all" and s in SAVE_PRIMS:
            return pe.Saveable
        if (activation_policy == "save_collectives"
                and s in COLLECTIVE_SAVE_PRIMS):
            return pe.Saveable
        return pe.Recompute

    return policy


def cache_placement_for_mode(mode) -> str:
    return resolve_strategy(mode).cache_placement


def checkpoint_layer(fn, mode, activation_policy: str = "save_all",
                     host_offload: bool = True, placement: Optional[str] = None):
    """Wrap a layer-apply function with the FCDP remat policy.

    ``mode`` is a strategy name or ShardingStrategy object (composites
    welcome: each plan's cache mark carries its own placement).
    ``placement='device'`` is the FCDP-Cache segment promotion -- it
    lifts host-placed caches to HBM and leaves other groups alone."""
    pol = make_remat_policy(
        resolve_strategy(mode).cache_placement,
        activation_policy, host_offload,
        promote_to_device=(placement == "device"))
    return jax.checkpoint(fn, policy=pol)
