"""Beyond-paper optimization: int8 block-quantized TP activation
all-reduce.

The roofline breakdown (EXPERIMENTS.md §Roofline) shows the dominant ICI
term on dense train cells is NOT the ZeRO parameter traffic but the
Megatron-TP f/g-pair activation all-reduces (57 GB/chip on
qwen/train_4k). An all-reduce is reduce-scatter + all-gather; running
both hops in int8 (symmetric per-256-block scales) halves the bytes at
~0.4% relative error per tensor.

Forward-only compression: the backward of this psum is the standard
identity/pvary transpose (exact), so gradients see no additional
quantization beyond what the forward activations already carry.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import all_gather_invariant, axis_size, pvary
from repro.core.grad_compress import BLOCK


def _int8_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantized ring all-reduce: int8 RS (via all_to_all + local sum)
    followed by int8 invariant AG. Returns the (approximately) summed
    tensor, invarying over `axis_name`."""
    n = axis_size(axis_name)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    total = flat.shape[0]
    # pad so each of the n chunks is a whole number of quant blocks
    per = -(-total // (n * BLOCK)) * BLOCK
    pad = per * n - total
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(n, per // BLOCK, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=2, keepdims=True)
                        / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    # reduce-scatter hop (int8): every rank receives all ranks' copy of
    # its own chunk, dequantizes and sums
    q_x = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                             tiled=True).reshape(n, per // BLOCK, BLOCK)
    s_x = jax.lax.all_to_all(scale, axis_name, split_axis=0, concat_axis=0,
                             tiled=True).reshape(n, per // BLOCK, 1)
    own = jnp.sum(q_x.astype(jnp.float32) * s_x, axis=0)   # [nb, BLOCK]
    # all-gather hop (int8) to rebuild the full summed tensor
    s2 = jnp.maximum(jnp.max(jnp.abs(own), axis=1, keepdims=True) / 127.0,
                     1e-12)
    q2 = jnp.clip(jnp.round(own / s2), -127, 127).astype(jnp.int8)
    q_full = all_gather_invariant(q2, axis_name, axis=0, tiled=True)
    s_full = all_gather_invariant(s2.astype(jnp.float32), axis_name,
                                  axis=0, tiled=True)
    out = (q_full.astype(jnp.float32) * s_full).reshape(-1)[:total]
    return out.reshape(shape).astype(dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def int8_psum(x, axis_name: str):
    """Drop-in psum replacement with int8 transport. Exact-gradient:
    the transpose of a psum is the identity broadcast."""
    return _int8_allreduce(x, axis_name)


def _fwd(x, axis_name):
    return int8_psum(x, axis_name), None


def _bwd(axis_name, _, g):
    return (pvary(g, (axis_name,)),)


int8_psum.defvjp(_fwd, _bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def int8_bwd_psum(x, axis_name: str):
    """Identity whose BACKWARD all-reduce runs in int8.

    Column-parallel matmuls consume a TP-replicated input; autodiff's
    transpose inserts a full all-reduce on its cotangent (the Megatron
    g-bar). Wrapping the input here compresses that implicit reduction
    the same way int8_psum compresses the forward one."""
    return pvary(x, (axis_name,))


def _bp_fwd(x, axis_name):
    return int8_bwd_psum(x, axis_name), None


def _bp_bwd(axis_name, _, g):
    return (_int8_allreduce(g, axis_name),)


int8_bwd_psum.defvjp(_bp_fwd, _bp_bwd)
