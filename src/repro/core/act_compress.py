"""Beyond-paper optimization: int8 block-quantized TP activation
all-reduce.

The roofline breakdown (EXPERIMENTS.md §Roofline) shows the dominant ICI
term on dense train cells is NOT the ZeRO parameter traffic but the
Megatron-TP f/g-pair activation all-reduces (57 GB/chip on
qwen/train_4k). An all-reduce is reduce-scatter + all-gather; running
both hops in int8 (symmetric per-256-block scales) halves the bytes at
~0.4% relative error per tensor.

Forward-only compression: the backward of this psum is the standard
identity/pvary transpose (exact), so gradients see no additional
quantization beyond what the forward activations already carry.

The quantize/dequantize/accumulate hot loops are the shared codepath in
kernels/quant.py (jnp oracle or Pallas kernel, selected by `impl` --
see SystemConfig.quant_impl).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.compat import all_gather_invariant, axis_size, pvary
from repro.core.grad_compress import _impl_kw
from repro.kernels import ops as kops
from repro.kernels.quant import BLOCK


def _int8_allreduce(x: jax.Array, axis_name: str,
                    impl: str = "jnp") -> jax.Array:
    """Quantized ring all-reduce: int8 RS (via all_to_all + local
    dequant-accumulate) followed by int8 invariant AG. Returns the
    (approximately) summed tensor, invarying over `axis_name`."""
    n = axis_size(axis_name)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    total = flat.shape[0]
    # pad so each of the n chunks is a whole number of quant blocks
    per = -(-total // (n * BLOCK)) * BLOCK
    pad = per * n - total
    if pad:
        flat = jnp.pad(flat, (0, pad))
    nb = per // BLOCK
    q, scale = kops.int8_quantize_blocks(
        flat.reshape(n * nb, BLOCK), **_impl_kw(impl))
    # reduce-scatter hop (int8): every rank receives all ranks' copy of
    # its own chunk, then runs the dequant-accumulate inner loop
    q_x = jax.lax.all_to_all(q.reshape(n, nb, BLOCK), axis_name,
                             split_axis=0, concat_axis=0,
                             tiled=True).reshape(n, nb, BLOCK)
    s_x = jax.lax.all_to_all(scale.reshape(n, nb, 1), axis_name,
                             split_axis=0, concat_axis=0,
                             tiled=True).reshape(n, nb, 1)
    own = kops.int8_dequant_accumulate(q_x, s_x, **_impl_kw(impl))
    # all-gather hop (int8) to rebuild the full summed tensor
    q2, s2 = kops.int8_quantize_blocks(own, **_impl_kw(impl))
    q_full = all_gather_invariant(q2, axis_name, axis=0, tiled=True)
    s_full = all_gather_invariant(s2, axis_name, axis=0, tiled=True)
    out = kops.int8_dequantize_blocks(
        q_full, s_full, **_impl_kw(impl)).reshape(-1)[:total]
    return out.reshape(shape).astype(dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def int8_psum(x, axis_name: str, impl: str = "jnp"):
    """Drop-in psum replacement with int8 transport. Exact-gradient:
    the transpose of a psum is the identity broadcast."""
    return _int8_allreduce(x, axis_name, impl)


def _fwd(x, axis_name, impl):
    return int8_psum(x, axis_name, impl), None


def _bwd(axis_name, impl, _, g):
    return (pvary(g, (axis_name,)),)


int8_psum.defvjp(_fwd, _bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def int8_bwd_psum(x, axis_name: str, impl: str = "jnp"):
    """Identity whose BACKWARD all-reduce runs in int8.

    Column-parallel matmuls consume a TP-replicated input; autodiff's
    transpose inserts a full all-reduce on its cotangent (the Megatron
    g-bar). Wrapping the input here compresses that implicit reduction
    the same way int8_psum compresses the forward one."""
    return pvary(x, (axis_name,))


def _bp_fwd(x, axis_name, impl):
    return int8_bwd_psum(x, axis_name, impl), None


def _bp_bwd(axis_name, impl, _, g):
    return (_int8_allreduce(g, axis_name, impl),)


int8_bwd_psum.defvjp(_bp_fwd, _bp_bwd)
