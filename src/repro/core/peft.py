"""FCDP-Comm + LoRA: parameter classification into frozen base weights
(W_f) and trainable adapters (W_t).

Classification happens at init (paper §IV-E): frozen ParamDefs get
``frozen=True``, which flips their storage layout to the cached layout
(pod-replicated, intra-sharded -- see partition.storage_fsdp_axes) so
their per-layer reconstruction never crosses DCN, and they receive no
gradient / optimizer state.

LoRA adds rank-r adapters to the attention projections (paper §V-D uses
r=8 on q,k,v,o); the adapters keep the full ZeRO-3 treatment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

from repro.configs.base import ModelConfig, SystemConfig
from repro.core.partition import ParamDef, is_def, tree_map_defs

LORA_TARGETS_IN_ATTN = ("wq", "wk", "wv", "wo")


def freeze_all(defs):
    """Mark every ParamDef frozen (serving layout / FCDP-Comm base)."""
    return tree_map_defs(lambda d: dataclasses.replace(d, frozen=True), defs)


def unfreeze_all(defs):
    """Mark every ParamDef trainable: the all-trainable reference arm
    the PEFT bench compares against (same def tree as apply_lora's --
    adapters included -- but every leaf receives gradient/optimizer
    state and the full ZeRO-3-style per-step communication)."""
    return tree_map_defs(lambda d: dataclasses.replace(d, frozen=False),
                         defs)


def apply_lora(defs, cfg: ModelConfig, sys: SystemConfig):
    """Freeze all base defs and inject trainable LoRA adapter defs into
    every sublayer dict holding a ``sys.lora_targets`` projection (keys:
    <target>_lora_a / _lora_b).

    Injection is keyed purely on target-name membership: a dict node
    containing ANY configured target (rank >= 2 ParamDef) gets adapters
    for every target it holds. Raises a readable error when ``peft=True``
    finds zero injection sites (e.g. a model family whose attention
    dicts use other projection names -- fix ``sys.lora_targets``).
    """
    r = sys.lora_rank
    injected = 0

    def visit(node):
        nonlocal injected
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                out[k] = visit(v)
            # inject adapters next to any configured target projection
            for t in sys.lora_targets:
                base = node.get(t)
                if not (is_def(base) and len(base.shape) >= 2):
                    continue
                d_in, d_out = base.shape[-2], base.shape[-1]
                stack = base.shape[:-2]
                sdims = base.dims[:-2]
                # A: [in, r] follows the input dim's sharding role
                out[f"{t}_lora_a"] = ParamDef(
                    stack + (d_in, r), sdims + (base.dims[-2], None),
                    init="normal", init_scale=1.0)
                # B: [r, out] zero-init, follows the output dim's role
                out[f"{t}_lora_b"] = ParamDef(
                    stack + (r, d_out), sdims + (None, base.dims[-1]),
                    init="zeros")
                injected += 1
            return out
        if is_def(node):
            return dataclasses.replace(node, frozen=True)
        if isinstance(node, (list, tuple)):
            return type(node)(visit(v) for v in node)
        return node

    out = visit(defs)
    if injected == 0:
        raise ValueError(
            f"peft=True but no LoRA injection sites found: none of the "
            f"configured lora_targets {sys.lora_targets!r} name a "
            f"matrix-shaped ParamDef in any sublayer dict of this model "
            f"family -- set SystemConfig.lora_targets to this model's "
            f"projection names")
    return out


def split_frozen_indices(defs) -> Tuple[List[int], List[int]]:
    """Flat-leaf indices of (trainable, frozen) params.

    Classification delegates to the residency layer's update-class
    helper -- the one place ``ParamDef.frozen`` is interpreted."""
    from repro.core import residency
    return residency.split_frozen_indices(defs)


def lora_scale(sys: SystemConfig) -> float:
    """The adapter term's multiplier, alpha/rank.

    ``SystemConfig.lora_alpha`` is the single source of truth (None ->
    alpha = 2*rank, the common default, i.e. scale 2.0); both the
    engine's forward (models/sublayers.py -> attention_block) and any
    analytic accounting read the scale through here."""
    alpha = (sys.lora_alpha if sys.lora_alpha is not None
             else 2.0 * sys.lora_rank)
    return alpha / sys.lora_rank
