"""FCDP-Comm + LoRA: parameter classification into frozen base weights
(W_f) and trainable adapters (W_t).

Classification happens at init (paper §IV-E): frozen ParamDefs get
``frozen=True``, which flips their storage layout to the cached layout
(pod-replicated, intra-sharded -- see partition.storage_fsdp_axes) so
their per-layer reconstruction never crosses DCN, and they receive no
gradient / optimizer state.

LoRA adds rank-r adapters to the attention projections (paper §V-D uses
r=8 on q,k,v,o); the adapters keep the full ZeRO-3 treatment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SystemConfig
from repro.core.partition import ParamDef, is_def, tree_map_defs

LORA_TARGETS_IN_ATTN = ("wq", "wk", "wv", "wo")


def freeze_all(defs):
    """Mark every ParamDef frozen (serving layout / FCDP-Comm base)."""
    return tree_map_defs(lambda d: dataclasses.replace(d, frozen=True), defs)


def apply_lora(defs, cfg: ModelConfig, sys: SystemConfig):
    """Freeze all base defs and inject trainable LoRA adapter defs into
    every attention sublayer dict (keys: <target>_lora_a / _lora_b)."""
    r = sys.lora_rank

    def visit(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                out[k] = visit(v)
            # inject adapters next to attention weights
            if any(t in node for t in sys.lora_targets) and "wq" in node:
                for t in sys.lora_targets:
                    if t not in node:
                        continue
                    base: ParamDef = node[t]
                    d_in, d_out = base.shape[-2], base.shape[-1]
                    stack = base.shape[:-2]
                    sdims = base.dims[:-2]
                    # A: [in, r] follows the input dim's sharding role
                    out[f"{t}_lora_a"] = ParamDef(
                        stack + (d_in, r), sdims + (base.dims[-2], None),
                        init="normal", init_scale=1.0)
                    # B: [r, out] zero-init, follows the output dim's role
                    out[f"{t}_lora_b"] = ParamDef(
                        stack + (r, d_out), sdims + (None, base.dims[-1]),
                        init="zeros")
            return out
        if is_def(node):
            return dataclasses.replace(node, frozen=True)
        if isinstance(node, (list, tuple)):
            return type(node)(visit(v) for v in node)
        return node

    return visit(defs)


def split_frozen_indices(defs) -> Tuple[List[int], List[int]]:
    """Flat-leaf indices of (trainable, frozen) params."""
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    train = [i for i, d in enumerate(leaves) if not d.frozen]
    frozen = [i for i, d in enumerate(leaves) if d.frozen]
    return train, frozen


def lora_scale(sys: SystemConfig) -> float:
    return 2.0  # alpha/r with alpha = 2r (common default)
