"""Pluggable sharding strategies: each system mode as one object.

The paper's contribution is a *schedule* -- where each mode places the
cached parameter shard and which all-gather stage the backward pass
re-runs. A ``ShardingStrategy`` centralizes every decision a mode makes:

  storage layout      which mesh axes the fsdp dim shards over, per
                      (frozen, fsdp_scope) classification
  gather plan         the two-stage reconstruction schedule (inter/DCN
                      stage 1, intra/ICI stage 2) and the cache boundary
  cache placement     where the remat policy parks the stage-1 result
                      ('regather' | 'device' | 'host')
  device-cache split  how FCDP-Cache's tau fraction maps to layer groups
  prefetch gating     whether the layer-ahead stage-1 prefetch applies
  byte accounting     analytic cache/comm sizes for the planner/roofline

``SystemConfig.mode`` is resolved to a strategy object exactly once (at
``StepBundle``/model construction) via :func:`get_strategy`; no other
module compares mode strings.

The four built-ins mirror the paper's comparison set:

  zero3   full ('pod','data') sharding, regather fwd+bwd     (baseline)
  zeropp  full sharding, stage-1 result cached in HBM        (ZeRO++)
  fcdp    full sharding, stage-1 result cached in pinned
          host memory; frozen params stored pre-gathered     (the paper)
  mics    pod-replicated ('data',) sharding; no DCN gathers  (MiCS)

New modes register with :func:`register_strategy` (e.g. a hierarchical-
partitioning strategy that shards optimizer state wider than params).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Type, Union

from jax.sharding import PartitionSpec as P

from repro.launch.mesh import fsdp_axes, intra_fsdp_axes

INTER_AXIS = "pod"     # the slow (DCN) mesh axis name


def spec_axes(spec: P) -> set:
    """Set of mesh axis names a PartitionSpec shards over."""
    used: set = set()
    for e in spec:
        if e is None:
            continue
        if isinstance(e, (tuple, list)):
            used.update(e)
        else:
            used.add(e)
    return used


@dataclass(frozen=True)
class GatherPlan:
    """How one parameter is reconstructed inside the step function."""
    fsdp_dim: Optional[int]          # dim index *inside the scan body*
    inter_axes: Tuple[str, ...]      # stage-1 axes (DCN)
    intra_axes: Tuple[str, ...]      # stage-2 axes (ICI)
    cache_after: int                 # 1 or 2: where the cache boundary sits
    frozen: bool = False
    compress_bwd: bool = False       # int8 DCN gradient reduce (beyond-paper)

    @property
    def is_gathered(self) -> bool:
        return self.fsdp_dim is not None and (bool(self.inter_axes) or bool(self.intra_axes))

    @property
    def prefetchable(self) -> bool:
        """True when a non-empty stage-1 exists to issue a layer ahead."""
        return self.is_gathered and bool(self.inter_axes)


class ShardingStrategy:
    """Base class owning everything a system mode decides.

    Subclasses override the class attributes (and, rarely, the layout
    methods) rather than re-deriving behaviour from the mode name.
    """

    name: str = "base"
    # where the remat policy parks the cached stage-1 shard for backward:
    # 'regather' (recompute both stages), 'device' (HBM), 'host' (pinned)
    cache_placement: str = "regather"
    # frozen (FCDP-Comm) params stored in the pod-replicated cached layout
    frozen_cached_layout: bool = False
    # FCDP-Cache's tau knob (device_cache_fraction) applies
    supports_device_cache: bool = False
    # layer-ahead stage-1 prefetch can apply (False when stage 1 is
    # structurally empty, as for MiCS)
    supports_prefetch: bool = True

    # -- storage layout -----------------------------------------------------
    def storage_fsdp_axes(self, mesh, frozen: bool) -> Tuple[str, ...]:
        """Mesh axes the fsdp dim shards over in storage.

        The pod-replicated cached layout for frozen params is FCDP-Comm's
        mechanism (frozen_cached_layout); baselines treat frozen weights
        like any other, re-gathered over DCN each iteration as DeepSpeed
        does -- that asymmetry IS the paper's PEFT result.
        """
        if frozen and self.frozen_cached_layout:
            return intra_fsdp_axes(mesh)   # pod-replicated cached layout
        return fsdp_axes(mesh)             # full ZeRO-3 sharding

    def effective_fsdp_axes(self, pdef, mesh) -> Tuple[str, ...]:
        axes = self.storage_fsdp_axes(mesh, pdef.frozen)
        if pdef.fsdp_scope == "inter_only":
            axes = tuple(a for a in axes if a == INTER_AXIS)
        return axes

    def storage_spec(self, pdef, mesh, min_shard_size: int = 0) -> P:
        entries: list = [None] * len(pdef.shape)
        small = pdef.size() < min_shard_size
        if pdef.tp_dim is not None:
            entries[pdef.tp_dim] = "model"
        if pdef.fsdp_dim is not None and not small:
            axes = self.effective_fsdp_axes(pdef, mesh)
            if axes:
                # only shard if divisible
                degree = math.prod(mesh.shape[a] for a in axes)
                if pdef.shape[pdef.fsdp_dim] % degree == 0:
                    entries[pdef.fsdp_dim] = axes if len(axes) > 1 else axes[0]
        return P(*entries)

    # -- gather schedule ----------------------------------------------------
    def gather_plan(self, pdef, mesh, min_shard_size: int = 0,
                    compress_bwd: bool = False) -> GatherPlan:
        """Derive the two-stage gather plan matching ``storage_spec``.

        If the def carries a 'stack' (scan) dimension, the returned fsdp
        dim index is shifted to the *scan-body* view (stack dim consumed
        by scan).
        """
        d = pdef.fsdp_dim
        if d is None or pdef.size() < min_shard_size:
            return GatherPlan(None, (), (), 2, pdef.frozen)
        axes = self.effective_fsdp_axes(pdef, mesh)
        degree = math.prod(mesh.shape[a] for a in axes) if axes else 1
        if not axes or pdef.shape[d] % degree != 0:
            return GatherPlan(None, (), (), 2, pdef.frozen)
        inter = tuple(a for a in axes if a == INTER_AXIS)
        intra = tuple(a for a in axes if a != INTER_AXIS)
        # cache boundary: after the inter stage if one exists, else after
        # the full gather (single-pod / pod-replicated storage).
        cache_after = 1 if inter else 2
        body_dim = d - 1 if ("stack" in pdef.dims and
                             pdef.dims.index("stack") < d) else d
        return GatherPlan(body_dim, inter, intra, cache_after, pdef.frozen,
                          compress_bwd=(compress_bwd and bool(inter)
                                        and not pdef.frozen))

    def plan_tree(self, defs, mesh, min_shard_size: int = 0,
                  compress_bwd: bool = False):
        from repro.core.partition import tree_map_defs
        return tree_map_defs(
            lambda p: self.gather_plan(p, mesh, min_shard_size, compress_bwd),
            defs)

    # -- FCDP-Cache ----------------------------------------------------------
    def device_cache_groups(self, n_groups: int, fraction: float) -> int:
        """How many leading layer groups keep their cache on device."""
        if not self.supports_device_cache:
            return 0
        return int(round(fraction * n_groups))

    # -- prefetch -------------------------------------------------------------
    def prefetch_active(self, sys, mesh_like) -> bool:
        """Whether the layer-ahead stage-1 prefetch schedule applies.

        mesh_like: anything with ``axis_names`` (Mesh or MeshInfo).
        A no-op when the mode has no stage-1 (MiCS) or the mesh has no
        slow tier (single pod): there is nothing to overlap.
        """
        return (bool(getattr(sys, "prefetch", False))
                and self.supports_prefetch
                and INTER_AXIS in tuple(mesh_like.axis_names))

    # -- analytic byte accounting --------------------------------------------
    def cached_bytes_for(self, pdef, plan: GatherPlan, mi) -> float:
        """Per-chip size of this param's cached tier (0 when regathered).

        cache_after=1 (multi-pod): the stage-1 shard, i.e. the chip's
        storage shard gathered over the inter axes.
        cache_after=2 (single-pod): the fully gathered TP-local weight.
        """
        if not plan.is_gathered:
            return 0.0
        import jax
        nbytes = pdef.size() * jax.dtypes.canonicalize_dtype(
            pdef.dtype).itemsize
        if plan.cache_after == 1:
            shard = nbytes / self._storage_degree(pdef, mi)
            inter_deg = math.prod(mi.size(a) for a in plan.inter_axes) or 1
            return shard * inter_deg
        return nbytes / (mi.tp if pdef.tp_dim is not None else 1)

    @staticmethod
    def _storage_degree(pdef, mi) -> int:
        deg = 1
        if pdef.fsdp_dim is not None:
            for a in mi.fsdp_axes:
                deg *= mi.size(a)
        if pdef.tp_dim is not None:
            deg *= mi.tp
        return deg

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
# Concrete strategies
# ---------------------------------------------------------------------------

class Zero3(ShardingStrategy):
    """Full sharding, re-gather forward AND backward (paper baseline)."""
    name = "zero3"
    cache_placement = "regather"


class ZeroPP(ShardingStrategy):
    """Full sharding; stage-1 result cached in HBM, backward re-runs
    stage 2 only (ZeRO++ analog)."""
    name = "zeropp"
    cache_placement = "device"


class FCDP(ShardingStrategy):
    """Full sharding; stage-1 result cached in pinned host memory,
    backward re-runs stage 2 only (the paper). Frozen params store in the
    cached layout (FCDP-Comm) and the tau device-cache split applies
    (FCDP-Cache)."""
    name = "fcdp"
    cache_placement = "host"
    frozen_cached_layout = True
    supports_device_cache = True


class MiCS(ShardingStrategy):
    """Pod-local (subgroup) sharding: storage is already pod-replicated,
    stage 1 is structurally empty, and the single intra stage recomputes
    (fwd+bwd intra AG, no DCN AG). Gradients all-reduce across pods."""
    name = "mics"
    cache_placement = "regather"
    supports_prefetch = False

    def storage_fsdp_axes(self, mesh, frozen: bool) -> Tuple[str, ...]:
        return intra_fsdp_axes(mesh)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ShardingStrategy] = {}


def register_strategy(cls: Type[ShardingStrategy]) -> Type[ShardingStrategy]:
    """Register a strategy class under its ``name`` (singleton instance)."""
    if not cls.name or cls.name == "base":
        raise ValueError(f"strategy {cls.__name__} needs a unique name")
    _REGISTRY[cls.name] = cls()
    return cls


for _cls in (Zero3, ZeroPP, FCDP, MiCS):
    register_strategy(_cls)

DEFAULT_STRATEGY = FCDP.name


def strategy_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_strategy(name: str) -> ShardingStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown system mode {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def resolve_strategy(mode: Union[str, ShardingStrategy]) -> ShardingStrategy:
    """Accept a mode name or an already-resolved strategy object."""
    if isinstance(mode, ShardingStrategy):
        return mode
    return get_strategy(mode)
