"""Pluggable sharding strategies: each system mode as one object.

The paper's contribution is a *schedule* -- where each mode places the
cached parameter shard and which all-gather stage the backward pass
re-runs. A ``ShardingStrategy`` centralizes every decision a mode makes:

  storage layout      which mesh axes the fsdp dim shards over, per
                      (frozen, fsdp_scope) classification
  gather plan         the two-stage reconstruction schedule (inter/DCN
                      stage 1, intra/ICI stage 2) and the cache boundary
  cache placement     where the remat policy parks the stage-1 result
                      ('regather' | 'device' | 'host')
  device-cache split  how FCDP-Cache's tau fraction maps to layer groups
  stream capability   how deep the streaming gather scheduler may prefetch
                      (max_prefetch_depth) and whether the async pod-axis
                      gradient-reduce stream applies
  opt layout          optimizer-state sharding (may be wider than params)
  byte accounting     analytic cache/comm sizes for the planner/roofline

``SystemConfig.mode`` is resolved exactly once (at ``StepBundle``/model
construction) via :func:`resolve_strategies`; no other module compares
mode strings. Resolution is PER LEAF: an explicit ``ParamDef.strategy``
tag wins, else the first matching ``SystemConfig.mode_overrides``
``(path-glob, mode)`` rule (fnmatch against the ``label_tree`` dotted
path), else ``mode``. A uniform assignment resolves to the plain
singleton strategy; a mixed one resolves to a :class:`CompositeStrategy`
facade that dispatches every per-parameter decision to the leaf's own
strategy and answers whole-model queries (stream capabilities, byte
accounting) by intersecting/summing over the resolved groups.

The built-ins mirror the paper's comparison set plus one related-work
extension:

  zero3   full ('data','pod') sharding, regather fwd+bwd     (baseline)
  zeropp  full sharding, stage-1 result cached in HBM        (ZeRO++)
  fcdp    full sharding, stage-1 result cached in pinned
          host memory; frozen params stored pre-gathered     (the paper)
  mics    pod-replicated ('data',) sharding; no DCN gathers  (MiCS)
  hier    pod-replicated params, optimizer state sharded
          over ('data','pod')             (hierarchical part., Xu et al.)

New modes register with :func:`register_strategy`.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple, Type, Union

from jax.sharding import PartitionSpec as P

from repro.core.residency import ParamResidency, update_class
from repro.launch.mesh import fsdp_axes, intra_fsdp_axes

INTER_AXIS = "pod"     # the slow (DCN) mesh axis name

# Minimum per-slice shard elements for the int8 DCN transports (qwZ/qgZ).
# Below one quant block the padding + fp32 scales cost MORE wire bytes
# than bf16 (a (32,)-norm shard of 8 elems would ship a padded 256-block
# plus scale: 260 B vs 16 B exact) -- such leaves keep the exact path.
# Mirrors kernels/quant.py BLOCK; kept literal so core/ stays importable
# without the kernels package.
QUANT_MIN_SHARD_ELEMS = 256


def spec_axes(spec: P) -> set:
    """Set of mesh axis names a PartitionSpec shards over."""
    used: set = set()
    for e in spec:
        if e is None:
            continue
        if isinstance(e, (tuple, list)):
            used.update(e)
        else:
            used.add(e)
    return used


@dataclass(frozen=True)
class GatherPlan:
    """Thin derived view of a :class:`ParamResidency` -- the legacy
    surface older call sites (and tests) read.  The lifecycle decisions
    live on ``self.residency``; every field here is derived from it by
    :meth:`from_residency`, and consumers outside strategy/residency
    must branch on the residency, never on ``frozen``/``placement``
    directly."""
    fsdp_dim: Optional[int]          # dim index *inside the scan body*
    inter_axes: Tuple[str, ...]      # stage-1 axes (DCN)
    intra_axes: Tuple[str, ...]      # stage-2 axes (ICI)
    cache_after: int                 # 1 or 2: where the cache boundary sits
    frozen: bool = False
    compress_bwd: bool = False       # int8 DCN gradient reduce (beyond-paper)
    # qwZ: stage-1 all-gather transports int8 blocks + fp32 scales and
    # dequantizes on arrival (beyond-paper, ZeRO++); quant_impl selects
    # the quantize/dequantize codepath (jnp | pallas | pallas_interpret)
    compress_fwd: bool = False
    quant_impl: str = "jnp"
    # gather-fused collective matmul (kernels/collective_matmul.py): the
    # stage-2 intra all-gather is folded into the consuming matmul's
    # ring schedule instead of completing first. 'none' | 'ag_matmul'
    # (fused fwd, bit-parity bwd) | 'both' (bwd ring-fused too);
    # fused_impl selects the per-chunk matmul codepath
    # (jnp | pallas | pallas_interpret)
    fused: str = "none"
    fused_impl: str = "jnp"
    # where the backward reads the cached stage from, carried PER PLAN so
    # leaves of different strategy groups can coexist inside one
    # checkpointed layer body (core/fcdp.py keys the remat policy on a
    # placement-suffixed checkpoint_name): 'regather' | 'device' | 'host'
    placement: str = "regather"
    # the authoritative lifecycle this plan is a view of
    residency: Optional[ParamResidency] = None

    @classmethod
    def from_residency(cls, res: ParamResidency) -> "GatherPlan":
        return cls(res.fsdp_dim, res.stage1_axes, res.stage2_axes,
                   res.cache_after, frozen=res.frozen,
                   compress_bwd=res.quantized_reduce,
                   compress_fwd=res.quantized_gather,
                   quant_impl=res.quant_impl, fused=res.fused,
                   fused_impl=res.fused_impl, placement=res.cache,
                   residency=res)

    @property
    def is_gathered(self) -> bool:
        return self.fsdp_dim is not None and (bool(self.inter_axes) or bool(self.intra_axes))

    @property
    def prefetchable(self) -> bool:
        """True when a non-empty stage-1 exists to issue a layer ahead."""
        return self.is_gathered and bool(self.inter_axes)

    @property
    def is_fused(self) -> bool:
        """True when the stage-2 gather is consumed by the fused ring."""
        return self.fused != "none"


class ShardingStrategy:
    """Base class owning everything a system mode decides.

    Subclasses override the class attributes (and, rarely, the layout
    methods) rather than re-deriving behaviour from the mode name.
    """

    name: str = "base"
    # where the remat policy parks the cached stage-1 shard for backward:
    # 'regather' (recompute both stages), 'device' (HBM), 'host' (pinned)
    cache_placement: str = "regather"
    # frozen (FCDP-Comm) params stored in the pod-replicated cached layout
    frozen_cached_layout: bool = False
    # FCDP-Cache's tau knob (device_cache_fraction) applies
    supports_device_cache: bool = False
    # -- stream capability surface (consumed by core/schedule.py and
    # engine/train.py): how deep the streaming gather scheduler may run
    # its stage-1 ring buffer (0 when stage 1 is structurally empty, as
    # for MiCS/hier), and whether the async pod-axis gradient-reduce
    # stream applies (it needs a per-microbatch stage-1 reduce to move).
    max_prefetch_depth: int = 8
    supports_async_grad_reduce: bool = True
    # whether the cross-step pipelined optimizer stream (stream 3,
    # engine/train.py) applies: the strategy must have a per-microbatch
    # stage-1 reduce whose last instance (plus the optimizer apply and
    # the widened updated-shard gather) can be carried across the step
    # boundary. Structurally stage-1-free modes decline on their own,
    # but their widened epilogue collectives DO ride the carry when they
    # coexist with a streaming group under per-tensor mixed sharding
    # (CompositeStrategy intersects per group: any streaming group
    # enables the carry, and the whole epilogue is deferred).
    supports_cross_step: bool = True
    # whether the stage-1 (pod-axis) parameter all-gather may transport
    # int8 under SystemConfig.param_compress='int8_pod' (qwZ). Strategies
    # with no stage 1 (MiCS/hier) decline structurally; a group can also
    # decline explicitly under per-tensor mixed sharding.
    supports_quantized_gather: bool = True
    # whether the stage-2 (intra / ICI) all-gather may be replaced by the
    # gather-fused collective matmul (kernels/collective_matmul.py) under
    # SystemConfig.fused_matmul != 'none'. Every built-in opts in -- the
    # ring consumes whatever hands it a stage-2 shard (a stage-1 cache,
    # a regather, or pod-replicated storage) -- but the PLAN-level gate
    # in gather_plan still declines leaves whose storage layout forbids
    # it (see there); a group can also decline explicitly under
    # per-tensor mixed sharding.
    supports_fused_matmul: bool = True

    @property
    def supports_prefetch(self) -> bool:
        """Legacy boolean view of ``max_prefetch_depth``."""
        return self.max_prefetch_depth > 0

    # -- storage layout -----------------------------------------------------
    def storage_fsdp_axes(self, mesh, frozen: bool) -> Tuple[str, ...]:
        """Mesh axes the fsdp dim shards over in storage.

        The pod-replicated cached layout for frozen params is FCDP-Comm's
        mechanism (frozen_cached_layout); baselines treat frozen weights
        like any other, re-gathered over DCN each iteration as DeepSpeed
        does -- that asymmetry IS the paper's PEFT result.
        """
        if frozen and self.frozen_cached_layout:
            return intra_fsdp_axes(mesh)   # pod-replicated cached layout
        return fsdp_axes(mesh)             # full ZeRO-3 sharding

    def effective_fsdp_axes(self, pdef, mesh) -> Tuple[str, ...]:
        axes = self.storage_fsdp_axes(mesh, pdef.frozen)
        if pdef.fsdp_scope == "inter_only":
            axes = tuple(a for a in axes if a == INTER_AXIS)
        return axes

    def _spec_with_axes(self, pdef, mesh, axes: Tuple[str, ...],
                        min_shard_size: int = 0) -> P:
        entries: list = [None] * len(pdef.shape)
        small = pdef.size() < min_shard_size
        if pdef.tp_dim is not None:
            entries[pdef.tp_dim] = "model"
        if pdef.fsdp_dim is not None and not small and axes:
            # only shard if divisible
            degree = math.prod(mesh.shape[a] for a in axes)
            if pdef.shape[pdef.fsdp_dim] % degree == 0:
                entries[pdef.fsdp_dim] = axes if len(axes) > 1 else axes[0]
        return P(*entries)

    def storage_spec(self, pdef, mesh, min_shard_size: int = 0) -> P:
        return self._spec_with_axes(
            pdef, mesh, self.effective_fsdp_axes(pdef, mesh), min_shard_size)

    def opt_spec(self, pdef, mesh, min_shard_size: int = 0) -> P:
        """Storage layout of the optimizer state (and master weights).

        Defaults to the param's own layout with the fsdp scope widened
        to 'full' (the ZeRO-2-for-experts seam); hierarchical
        partitioning overrides this to shard optimizer state wider than
        the params themselves. engine/train.py reduce-scatters grads
        over (opt axes - storage axes) before the update and gathers
        the updated shard back. Storage axes come FIRST in the tiling
        order (hier's convention, now uniform): the widening
        reduce-scatter subdivides each storage block over the widening
        axes, so the storage-major opt spec assigns exactly that slice
        to the device.
        """
        full = dataclasses.replace(pdef, fsdp_scope="full")
        storage = self.effective_fsdp_axes(pdef, mesh)
        target = self.effective_fsdp_axes(full, mesh)
        widened = storage + tuple(a for a in target if a not in storage)
        return self._spec_with_axes(full, mesh, widened, min_shard_size)

    # -- residency / gather schedule ----------------------------------------
    def residency(self, pdef, mesh, min_shard_size: int = 0,
                  compress_bwd: bool = False,
                  param_compress: bool = False,
                  quant_impl: str = "jnp",
                  fused_matmul: str = "none",
                  fused_impl: str = "jnp") -> ParamResidency:
        """Emit the full parameter lifecycle matching ``storage_spec``.

        This is the ONE place a leaf's storage tier, reconstruction
        schedule, backward source, and update class are decided; the
        legacy :class:`GatherPlan` is derived from the result.  If the
        def carries a 'stack' (scan) dimension, the emitted fsdp dim
        index is shifted to the *scan-body* view (stack dim consumed by
        scan).
        """
        upd = update_class(pdef, self.frozen_cached_layout)
        d = pdef.fsdp_dim
        if d is None or pdef.size() < min_shard_size:
            return ParamResidency("replicated", self.cache_placement, upd,
                                  quant_impl=quant_impl,
                                  fused_impl=fused_impl)
        axes = self.effective_fsdp_axes(pdef, mesh)
        degree = math.prod(mesh.shape[a] for a in axes) if axes else 1
        if not axes or pdef.shape[d] % degree != 0:
            return ParamResidency("replicated", self.cache_placement, upd,
                                  quant_impl=quant_impl,
                                  fused_impl=fused_impl)
        inter = tuple(a for a in axes if a == INTER_AXIS)
        intra = tuple(a for a in axes if a != INTER_AXIS)
        tier = "dcn_sharded" if inter else "pod_replicated"
        # cache boundary: after the inter stage if one exists, else after
        # the full gather (single-pod / pod-replicated storage).
        cache_after = 1 if inter else 2
        body_dim = d - 1 if ("stack" in pdef.dims and
                             pdef.dims.index("stack") < d) else d
        # frozen params keep the exact invariant gather (their stage-1
        # runs once into the cached layout, not per step -- nothing to
        # compress) and strategies may decline qwZ entirely; leaves whose
        # per-slice shard is smaller than one quant block also stay exact
        # (the padded block + scale would cost more wire than bf16)
        stack = (pdef.shape[pdef.dims.index("stack")]
                 if "stack" in pdef.dims else 1)
        trainable = upd == "trainable"
        quantizable = (bool(inter) and trainable
                       and pdef.size() // (degree * stack)
                       >= QUANT_MIN_SHARD_ELEMS)
        # gather-fused collective matmul eligibility: the def site must
        # opt in (ParamDef.fusable -- the leaf is an output projection
        # consumed through models/layers.matmul) and the ring consumes
        # a [K, N]-shaped body weight whose OUTPUT dim shards over
        # exactly one intra axis (column-concat decomposition; the
        # contraction is never split, preserving bit-exactness), fed by
        # either a stage-1 cache (cache_after=1) or a regather -- a
        # cache_after=2 device/host placement caches the FULLY gathered
        # weight, so there is no per-use stage-2 gather left to fuse:
        # that storage layout declines. Frozen leaves store pre-gathered
        # under FCDP-Comm (same reason) and stay exact elsewhere.
        body_rank = len(pdef.shape) - (1 if "stack" in pdef.dims else 0)
        intra_deg = math.prod(mesh.shape[a] for a in intra) if intra else 1
        fusable = (fused_matmul != "none"
                   and self.supports_fused_matmul
                   and getattr(pdef, "fusable", False)
                   and body_rank == 2 and body_dim == 1
                   and trainable
                   and len(intra) == 1 and intra_deg > 1
                   and (cache_after == 1 or self.cache_placement == "regather"))
        return ParamResidency(
            tier, self.cache_placement, upd,
            fsdp_dim=body_dim, stage1_axes=inter, stage2_axes=intra,
            cache_after=cache_after,
            quantized_gather=(param_compress and quantizable
                              and self.supports_quantized_gather),
            quantized_reduce=(compress_bwd and quantizable),
            quant_impl=quant_impl,
            fused=(fused_matmul if fusable else "none"),
            fused_impl=fused_impl)

    def gather_plan(self, pdef, mesh, min_shard_size: int = 0,
                    compress_bwd: bool = False,
                    param_compress: bool = False,
                    quant_impl: str = "jnp",
                    fused_matmul: str = "none",
                    fused_impl: str = "jnp") -> GatherPlan:
        """Back-compat view: derive the two-stage gather plan from the
        leaf's emitted :class:`ParamResidency`."""
        return GatherPlan.from_residency(self.residency(
            pdef, mesh, min_shard_size, compress_bwd, param_compress,
            quant_impl, fused_matmul, fused_impl))

    def plan_tree(self, defs, mesh, min_shard_size: int = 0,
                  compress_bwd: bool = False, param_compress: bool = False,
                  quant_impl: str = "jnp", fused_matmul: str = "none",
                  fused_impl: str = "jnp"):
        from repro.core.partition import tree_map_defs
        return tree_map_defs(
            lambda p: self.gather_plan(p, mesh, min_shard_size, compress_bwd,
                                       param_compress, quant_impl,
                                       fused_matmul, fused_impl),
            defs)

    # -- FCDP-Cache ----------------------------------------------------------
    def device_cache_groups(self, n_groups: int, fraction: float) -> int:
        """How many leading layer groups keep their cache on device."""
        if not self.supports_device_cache:
            return 0
        return int(round(fraction * n_groups))

    # -- scheduler streams ----------------------------------------------------
    def prefetch_depth(self, sys, mesh_like) -> int:
        """Resolved ring-buffer depth for the streaming gather scheduler.

        mesh_like: anything with ``axis_names`` (Mesh or MeshInfo).
        0 when the mode has no stage 1 (MiCS/hier), the mesh has no slow
        tier (single pod), or the config asks for the sequential
        schedule; otherwise min(requested depth, max_prefetch_depth).
        """
        depth = getattr(sys, "prefetch_depth", None)
        if depth is None:                    # raw legacy configs
            depth = 1 if getattr(sys, "prefetch", False) else 0
        if INTER_AXIS not in tuple(mesh_like.axis_names):
            return 0
        return max(0, min(int(depth), self.max_prefetch_depth))

    def prefetch_active(self, sys, mesh_like) -> bool:
        """Whether the layer-ahead stage-1 prefetch schedule applies."""
        return self.prefetch_depth(sys, mesh_like) > 0

    def async_grad_reduce_active(self, sys, mesh_like) -> bool:
        """Whether the async pod-axis gradient-reduce stream applies:
        the strategy must have a non-empty stage 1 whose per-microbatch
        reduce can be taken off the critical path, and the mesh must
        have a slow tier to hide."""
        return (bool(getattr(sys, "async_grad_reduce", False))
                and self.supports_async_grad_reduce
                and INTER_AXIS in tuple(mesh_like.axis_names))

    def cross_step_active(self, sys, mesh_like) -> bool:
        """Whether the cross-step pipelined optimizer stream (stream 3)
        applies: it rides the async grad-reduce stream (the carried
        pending gradient IS the stream-2 deferred reduce), so the
        strategy must support both, the flag must be on, and the mesh
        must have a slow tier whose epilogue latency is worth hiding."""
        return (bool(getattr(sys, "cross_step_pipeline", False))
                and self.supports_cross_step
                and self.async_grad_reduce_active(sys, mesh_like))

    # -- analytic byte accounting --------------------------------------------
    def cached_bytes_for(self, pdef, plan: GatherPlan, mi) -> float:
        """Per-chip size of this param's cached tier (0 when regathered).

        cache_after=1 (multi-pod): the stage-1 shard, i.e. the chip's
        storage shard gathered over the inter axes.
        cache_after=2 (single-pod): the fully gathered TP-local weight.
        """
        if not plan.is_gathered:
            return 0.0
        import jax
        nbytes = pdef.size() * jax.dtypes.canonicalize_dtype(
            pdef.dtype).itemsize
        if plan.cache_after == 1:
            shard = nbytes / self._storage_degree(pdef, mi)
            inter_deg = math.prod(mi.size(a) for a in plan.inter_axes) or 1
            return shard * inter_deg
        return nbytes / (mi.tp if pdef.tp_dim is not None else 1)

    @staticmethod
    def _storage_degree(pdef, mi) -> int:
        deg = 1
        if pdef.fsdp_dim is not None:
            for a in mi.fsdp_axes:
                deg *= mi.size(a)
        if pdef.tp_dim is not None:
            deg *= mi.tp
        return deg

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
# Concrete strategies
# ---------------------------------------------------------------------------

class Zero3(ShardingStrategy):
    """Full sharding, re-gather forward AND backward (paper baseline)."""
    name = "zero3"
    cache_placement = "regather"


class ZeroPP(ShardingStrategy):
    """Full sharding; stage-1 result cached in HBM, backward re-runs
    stage 2 only (ZeRO++ analog)."""
    name = "zeropp"
    cache_placement = "device"


class FCDP(ShardingStrategy):
    """Full sharding; stage-1 result cached in pinned host memory,
    backward re-runs stage 2 only (the paper). Frozen params store in the
    cached layout (FCDP-Comm) and the tau device-cache split applies
    (FCDP-Cache)."""
    name = "fcdp"
    cache_placement = "host"
    frozen_cached_layout = True
    supports_device_cache = True


class MiCS(ShardingStrategy):
    """Pod-local (subgroup) sharding: storage is already pod-replicated,
    stage 1 is structurally empty, and the single intra stage recomputes
    (fwd+bwd intra AG, no DCN AG). Gradients all-reduce across pods."""
    name = "mics"
    cache_placement = "regather"
    max_prefetch_depth = 0            # stage 1 structurally empty
    supports_async_grad_reduce = False
    supports_cross_step = False       # no stage-1 reduce to carry
    supports_quantized_gather = False  # no stage-1 gather to quantize

    def storage_fsdp_axes(self, mesh, frozen: bool) -> Tuple[str, ...]:
        return intra_fsdp_axes(mesh)


class Hierarchical(MiCS):
    """Hierarchical partitioning (Xu et al.): params shard intra-pod
    only (MiCS gathers: no DCN AG in the step), but optimizer state and
    master weights shard over the FULL ('data','pod') product -- the
    low-bandwidth trade that keeps MiCS's cheap gathers while paying
    only one pod-axis grad reduce-scatter plus one pod-axis updated-
    shard all-gather per step (amortized over all microbatches) instead
    of MiCS's per-step pod all-reduce of full shard-level grads."""
    name = "hier"

    def opt_spec(self, pdef, mesh, min_shard_size: int = 0) -> P:
        full = dataclasses.replace(pdef, fsdp_scope="full")
        # bypass the pod-replicated param layout: opt state goes
        # full-width. Storage axes come FIRST in the tiling order so the
        # widening reduce-scatter of a storage-sharded gradient (which
        # subdivides each storage block over the widening axes) lands on
        # the same global slice the opt spec assigns to the device.
        storage = self.effective_fsdp_axes(full, mesh)
        widened = storage + tuple(a for a in fsdp_axes(mesh)
                                  if a not in storage)
        spec = self._spec_with_axes(full, mesh, widened, min_shard_size)
        if pdef.fsdp_dim is not None and spec[pdef.fsdp_dim] is None:
            # full-width degree does not divide: keep the param layout
            # (opt state must never shard narrower than storage)
            return super().opt_spec(pdef, mesh, min_shard_size)
        return spec


# ---------------------------------------------------------------------------
# Composite (per-leaf mixed) strategies
# ---------------------------------------------------------------------------

class CompositeStrategy(ShardingStrategy):
    """Per-leaf strategy dispatch behind the whole-model strategy surface.

    Built by :func:`resolve_strategies` when a model mixes strategy
    groups (MoE experts on mics while the dense trunk stays fcdp,
    embeddings on hier, ...). Every per-parameter decision
    (storage/opt specs, gather plans, byte accounting) dispatches to the
    leaf's resolved strategy via its ``ParamDef.strategy`` tag; the
    whole-model queries are derived from the resolved groups:

      stream capabilities   intersection over the PARTICIPATING groups:
                            ``max_prefetch_depth`` is the min over the
                            groups that can stream at all (a group whose
                            stage 1 is structurally empty -- mics/hier --
                            neither benefits from nor vetoes the ring;
                            its leaves ride the scan untouched), and the
                            async grad-reduce stream is available when
                            any group has a stage-1 reduce to move (only
                            those groups' reduces are deferred).
      tau split             the FCDP-Cache device-fraction split applies
                            when any group supports it; the per-segment
                            device promotion only touches host-placed
                            caches (see core/fcdp.py), so foreign groups
                            in a promoted segment are unaffected.
      byte accounting       summed per leaf by the leaf's own strategy
                            (core/cache.py reports the per-group split).
    """

    name = "composite"

    def __init__(self, default: ShardingStrategy,
                 groups: Dict[str, ShardingStrategy]):
        self.default = default
        self.groups = dict(groups)

    def _for(self, pdef) -> ShardingStrategy:
        tag = getattr(pdef, "strategy", None)
        if not tag:
            return self.default
        return self.groups.get(tag) or get_strategy(tag)

    def group_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.groups))

    # -- per-leaf dispatch ---------------------------------------------------
    def storage_fsdp_axes(self, mesh, frozen: bool) -> Tuple[str, ...]:
        # no leaf in sight: answer for the default group (callers that
        # care per leaf go through effective_fsdp_axes/storage_spec)
        return self.default.storage_fsdp_axes(mesh, frozen)

    def effective_fsdp_axes(self, pdef, mesh) -> Tuple[str, ...]:
        return self._for(pdef).effective_fsdp_axes(pdef, mesh)

    def storage_spec(self, pdef, mesh, min_shard_size: int = 0) -> P:
        return self._for(pdef).storage_spec(pdef, mesh, min_shard_size)

    def opt_spec(self, pdef, mesh, min_shard_size: int = 0) -> P:
        return self._for(pdef).opt_spec(pdef, mesh, min_shard_size)

    def residency(self, pdef, mesh, min_shard_size: int = 0,
                  compress_bwd: bool = False,
                  param_compress: bool = False,
                  quant_impl: str = "jnp",
                  fused_matmul: str = "none",
                  fused_impl: str = "jnp") -> ParamResidency:
        # per-leaf dispatch also gates qwZ and the fused collective
        # matmul per group: the leaf strategy's own
        # supports_quantized_gather / supports_fused_matmul decide, so a
        # declining group keeps its exact bf16 stage-1 gather (or its
        # unfused stage-2 gather) inside a mixed bundle
        return self._for(pdef).residency(pdef, mesh, min_shard_size,
                                         compress_bwd, param_compress,
                                         quant_impl, fused_matmul,
                                         fused_impl)

    def gather_plan(self, pdef, mesh, min_shard_size: int = 0,
                    compress_bwd: bool = False,
                    param_compress: bool = False,
                    quant_impl: str = "jnp",
                    fused_matmul: str = "none",
                    fused_impl: str = "jnp") -> GatherPlan:
        return self._for(pdef).gather_plan(pdef, mesh, min_shard_size,
                                           compress_bwd, param_compress,
                                           quant_impl, fused_matmul,
                                           fused_impl)

    def cached_bytes_for(self, pdef, plan: GatherPlan, mi) -> float:
        return self._for(pdef).cached_bytes_for(pdef, plan, mi)

    # -- whole-model queries -------------------------------------------------
    @property
    def cache_placement(self) -> str:
        # legacy whole-model view; the real placement travels per plan
        return self.default.cache_placement

    @property
    def supports_device_cache(self) -> bool:
        return any(s.supports_device_cache for s in self.groups.values())

    @property
    def max_prefetch_depth(self) -> int:
        caps = [s.max_prefetch_depth for s in self.groups.values()
                if s.max_prefetch_depth > 0]
        return min(caps) if caps else 0

    @property
    def supports_async_grad_reduce(self) -> bool:
        return any(s.supports_async_grad_reduce
                   for s in self.groups.values())

    @property
    def supports_cross_step(self) -> bool:
        # any streaming group enables the cross-step carry; the deferred
        # epilogue then covers EVERY group's once-per-step collectives
        # (incl. a hier group's widened reduce-scatter/all-gather pair)
        return any(s.supports_cross_step for s in self.groups.values())

    @property
    def supports_quantized_gather(self) -> bool:
        # whole-model view only; the per-leaf gate is the leaf group's
        # own attribute (see gather_plan above)
        return any(s.supports_quantized_gather for s in self.groups.values())

    @property
    def supports_fused_matmul(self) -> bool:
        # whole-model view only; the per-leaf gate is the leaf group's
        # own attribute (see gather_plan above)
        return any(s.supports_fused_matmul for s in self.groups.values())

    # device_cache_groups: inherited -- the base guard reads the
    # supports_device_cache property overridden above

    def __repr__(self) -> str:
        return (f"<CompositeStrategy default={self.default.name!r} "
                f"groups={self.group_names()}>")


def leaf_group(strategy, pdef) -> str:
    """Accounting key of one leaf: its resolved strategy name (the
    composite's default for untagged leaves, the strategy's own name
    under a uniform assignment)."""
    tag = getattr(pdef, "strategy", None)
    if tag:
        return tag
    if isinstance(strategy, CompositeStrategy):
        return strategy.default.name
    return strategy.name


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ShardingStrategy] = {}


def register_strategy(cls: Type[ShardingStrategy]) -> Type[ShardingStrategy]:
    """Register a strategy class under its ``name`` (singleton instance)."""
    if not cls.name or cls.name == "base":
        raise ValueError(f"strategy {cls.__name__} needs a unique name")
    _REGISTRY[cls.name] = cls()
    return cls


for _cls in (Zero3, ZeroPP, FCDP, MiCS, Hierarchical):
    register_strategy(_cls)

DEFAULT_STRATEGY = FCDP.name


def strategy_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_strategy(name: str) -> ShardingStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown system mode {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def resolve_strategy(mode: Union[str, ShardingStrategy]) -> ShardingStrategy:
    """Accept a mode name or an already-resolved strategy object."""
    if isinstance(mode, ShardingStrategy):
        return mode
    if mode is None:
        raise ValueError(
            "no strategy given; resolve one via resolve_strategies() "
            "(per-leaf) or get_strategy(mode)")
    return get_strategy(mode)


# ---------------------------------------------------------------------------
# Per-leaf resolution (SystemConfig.mode_overrides / ParamDef.strategy)
# ---------------------------------------------------------------------------

def parse_mode_override(spec: str) -> Tuple[str, str]:
    """Parse a CLI override spec ``'<path-glob>=<mode>'`` (e.g.
    ``'blocks.*.moe.we_*=mics'``) into a ``(pattern, mode)`` rule."""
    pattern, sep, mode = str(spec).partition("=")
    pattern, mode = pattern.strip(), mode.strip()
    if not sep or not pattern or not mode:
        raise ValueError(
            f"malformed mode override {spec!r}; expected "
            "'<path-glob>=<mode>' (e.g. 'blocks.*.moe.we_*=mics')")
    return pattern, mode


def normalize_mode_overrides(
        overrides: Sequence[Any]) -> Tuple[Tuple[str, str], ...]:
    """Validate and canonicalize ``SystemConfig.mode_overrides``.

    Accepts an ordered sequence of ``(pattern, mode)`` pairs or
    ``'pattern=mode'`` strings; raises ``ValueError`` naming the
    offending rule for a malformed rule or an unregistered strategy
    name. Patterns are fnmatch globs matched against the ``label_tree``
    dotted path of each ParamDef (``*`` crosses dots).
    """
    rules = []
    for rule in tuple(overrides or ()):
        if isinstance(rule, str):
            pattern, mode = parse_mode_override(rule)
        else:
            try:
                pattern, mode = rule
            except (TypeError, ValueError):
                raise ValueError(
                    f"malformed mode_overrides rule {rule!r}; expected "
                    "(pattern, mode) or 'pattern=mode'") from None
            if not (isinstance(pattern, str) and isinstance(mode, str)
                    and pattern.strip() and mode.strip()):
                raise ValueError(
                    f"malformed mode_overrides rule {rule!r}; pattern and "
                    "mode must be non-empty strings")
            pattern, mode = pattern.strip(), mode.strip()
        if mode not in _REGISTRY:
            raise ValueError(
                f"mode_overrides rule {pattern!r}={mode!r} names an "
                f"unknown strategy; registered: {sorted(_REGISTRY)}")
        rules.append((pattern, mode))
    return tuple(rules)


def resolve_strategies(sys, defs, *, strict: bool = True):
    """Resolve the per-leaf strategy assignment of a labeled ParamDef tree.

    Resolution order per leaf: explicit ``ParamDef.strategy`` tag >
    first matching ``SystemConfig.mode_overrides`` rule (fnmatch against
    the dotted label) > ``SystemConfig.mode``. Returns
    ``(defs, strategy)``: under a uniform default assignment the input
    tree and the plain singleton strategy come back unchanged (the
    zero-cost path every single-mode config takes); otherwise every leaf
    is tagged with its resolved name and a :class:`CompositeStrategy`
    over the present groups is returned.

    With ``strict`` (the default), raises ``ValueError`` naming the
    offending rule when an override rule is the first rule-match for
    zero parameter labels (catches typo'd globs at construction time).
    Model construction under ``peft=True`` passes ``strict=False``: the
    base tree is resolved before LoRA injection, so a rule targeting
    the adapters (e.g. ``'*lora*'``) legitimately matches nothing yet --
    the StepBundle re-resolution after ``apply_lora`` runs strict and
    is where a genuinely dead rule still raises. Hit accounting is
    label-only: explicit tags shadow a rule for assignment without
    invalidating it, so re-resolving an already-tagged tree stays
    stable.
    """
    import jax

    from repro.core.partition import is_def
    rules = normalize_mode_overrides(getattr(sys, "mode_overrides", ()))
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    if not rules and not any(getattr(d, "strategy", None) for d in leaves):
        return defs, get_strategy(sys.mode)
    default = get_strategy(sys.mode)
    hits = [0] * len(rules)
    tagged = []
    for d in leaves:
        rule_name = None
        for ri, (pattern, mode) in enumerate(rules):
            if fnmatch.fnmatchcase(d.label, pattern):
                rule_name = mode
                hits[ri] += 1
                break
        if getattr(d, "strategy", None):
            name = d.strategy
            get_strategy(name)                 # unknown tag raises here
        else:
            name = rule_name or default.name
        tagged.append(dataclasses.replace(d, strategy=name))
    for (pattern, mode), n in zip(rules, hits):
        if n == 0 and strict:
            raise ValueError(
                f"mode_overrides rule {pattern!r}={mode!r} matched zero "
                "parameters (patterns are fnmatch globs against dotted "
                "label_tree paths, e.g. 'blocks.*.moe.we_*')")
    groups = {d.strategy: get_strategy(d.strategy) for d in tagged}
    defs = jax.tree.unflatten(treedef, tagged)
    if len(groups) == 1 and default.name in groups:
        # uniform after all (e.g. every leaf explicitly tagged with the
        # default): keep the tags but serve the plain strategy
        return defs, default
    return defs, CompositeStrategy(default, groups)
