"""Pluggable sharding strategies: each system mode as one object.

The paper's contribution is a *schedule* -- where each mode places the
cached parameter shard and which all-gather stage the backward pass
re-runs. A ``ShardingStrategy`` centralizes every decision a mode makes:

  storage layout      which mesh axes the fsdp dim shards over, per
                      (frozen, fsdp_scope) classification
  gather plan         the two-stage reconstruction schedule (inter/DCN
                      stage 1, intra/ICI stage 2) and the cache boundary
  cache placement     where the remat policy parks the stage-1 result
                      ('regather' | 'device' | 'host')
  device-cache split  how FCDP-Cache's tau fraction maps to layer groups
  stream capability   how deep the streaming gather scheduler may prefetch
                      (max_prefetch_depth) and whether the async pod-axis
                      gradient-reduce stream applies
  opt layout          optimizer-state sharding (may be wider than params)
  byte accounting     analytic cache/comm sizes for the planner/roofline

``SystemConfig.mode`` is resolved to a strategy object exactly once (at
``StepBundle``/model construction) via :func:`get_strategy`; no other
module compares mode strings.

The built-ins mirror the paper's comparison set plus one related-work
extension:

  zero3   full ('pod','data') sharding, regather fwd+bwd     (baseline)
  zeropp  full sharding, stage-1 result cached in HBM        (ZeRO++)
  fcdp    full sharding, stage-1 result cached in pinned
          host memory; frozen params stored pre-gathered     (the paper)
  mics    pod-replicated ('data',) sharding; no DCN gathers  (MiCS)
  hier    pod-replicated params, optimizer state sharded
          over ('pod','data')             (hierarchical part., Xu et al.)

New modes register with :func:`register_strategy`.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Type, Union

from jax.sharding import PartitionSpec as P

from repro.launch.mesh import fsdp_axes, intra_fsdp_axes

INTER_AXIS = "pod"     # the slow (DCN) mesh axis name


def spec_axes(spec: P) -> set:
    """Set of mesh axis names a PartitionSpec shards over."""
    used: set = set()
    for e in spec:
        if e is None:
            continue
        if isinstance(e, (tuple, list)):
            used.update(e)
        else:
            used.add(e)
    return used


@dataclass(frozen=True)
class GatherPlan:
    """How one parameter is reconstructed inside the step function."""
    fsdp_dim: Optional[int]          # dim index *inside the scan body*
    inter_axes: Tuple[str, ...]      # stage-1 axes (DCN)
    intra_axes: Tuple[str, ...]      # stage-2 axes (ICI)
    cache_after: int                 # 1 or 2: where the cache boundary sits
    frozen: bool = False
    compress_bwd: bool = False       # int8 DCN gradient reduce (beyond-paper)

    @property
    def is_gathered(self) -> bool:
        return self.fsdp_dim is not None and (bool(self.inter_axes) or bool(self.intra_axes))

    @property
    def prefetchable(self) -> bool:
        """True when a non-empty stage-1 exists to issue a layer ahead."""
        return self.is_gathered and bool(self.inter_axes)


class ShardingStrategy:
    """Base class owning everything a system mode decides.

    Subclasses override the class attributes (and, rarely, the layout
    methods) rather than re-deriving behaviour from the mode name.
    """

    name: str = "base"
    # where the remat policy parks the cached stage-1 shard for backward:
    # 'regather' (recompute both stages), 'device' (HBM), 'host' (pinned)
    cache_placement: str = "regather"
    # frozen (FCDP-Comm) params stored in the pod-replicated cached layout
    frozen_cached_layout: bool = False
    # FCDP-Cache's tau knob (device_cache_fraction) applies
    supports_device_cache: bool = False
    # -- stream capability surface (consumed by core/schedule.py and
    # engine/train.py): how deep the streaming gather scheduler may run
    # its stage-1 ring buffer (0 when stage 1 is structurally empty, as
    # for MiCS/hier), and whether the async pod-axis gradient-reduce
    # stream applies (it needs a per-microbatch stage-1 reduce to move).
    max_prefetch_depth: int = 8
    supports_async_grad_reduce: bool = True

    @property
    def supports_prefetch(self) -> bool:
        """Legacy boolean view of ``max_prefetch_depth``."""
        return self.max_prefetch_depth > 0

    # -- storage layout -----------------------------------------------------
    def storage_fsdp_axes(self, mesh, frozen: bool) -> Tuple[str, ...]:
        """Mesh axes the fsdp dim shards over in storage.

        The pod-replicated cached layout for frozen params is FCDP-Comm's
        mechanism (frozen_cached_layout); baselines treat frozen weights
        like any other, re-gathered over DCN each iteration as DeepSpeed
        does -- that asymmetry IS the paper's PEFT result.
        """
        if frozen and self.frozen_cached_layout:
            return intra_fsdp_axes(mesh)   # pod-replicated cached layout
        return fsdp_axes(mesh)             # full ZeRO-3 sharding

    def effective_fsdp_axes(self, pdef, mesh) -> Tuple[str, ...]:
        axes = self.storage_fsdp_axes(mesh, pdef.frozen)
        if pdef.fsdp_scope == "inter_only":
            axes = tuple(a for a in axes if a == INTER_AXIS)
        return axes

    def _spec_with_axes(self, pdef, mesh, axes: Tuple[str, ...],
                        min_shard_size: int = 0) -> P:
        entries: list = [None] * len(pdef.shape)
        small = pdef.size() < min_shard_size
        if pdef.tp_dim is not None:
            entries[pdef.tp_dim] = "model"
        if pdef.fsdp_dim is not None and not small and axes:
            # only shard if divisible
            degree = math.prod(mesh.shape[a] for a in axes)
            if pdef.shape[pdef.fsdp_dim] % degree == 0:
                entries[pdef.fsdp_dim] = axes if len(axes) > 1 else axes[0]
        return P(*entries)

    def storage_spec(self, pdef, mesh, min_shard_size: int = 0) -> P:
        return self._spec_with_axes(
            pdef, mesh, self.effective_fsdp_axes(pdef, mesh), min_shard_size)

    def opt_spec(self, pdef, mesh, min_shard_size: int = 0) -> P:
        """Storage layout of the optimizer state (and master weights).

        Defaults to the param's own layout with the fsdp scope widened
        to 'full' (the ZeRO-2-for-experts seam); hierarchical
        partitioning overrides this to shard optimizer state wider than
        the params themselves. engine/train.py reduce-scatters grads
        over (opt axes - storage axes) before the update and gathers
        the updated shard back.
        """
        full = dataclasses.replace(pdef, fsdp_scope="full")
        return self._spec_with_axes(
            full, mesh, self.effective_fsdp_axes(full, mesh), min_shard_size)

    # -- gather schedule ----------------------------------------------------
    def gather_plan(self, pdef, mesh, min_shard_size: int = 0,
                    compress_bwd: bool = False) -> GatherPlan:
        """Derive the two-stage gather plan matching ``storage_spec``.

        If the def carries a 'stack' (scan) dimension, the returned fsdp
        dim index is shifted to the *scan-body* view (stack dim consumed
        by scan).
        """
        d = pdef.fsdp_dim
        if d is None or pdef.size() < min_shard_size:
            return GatherPlan(None, (), (), 2, pdef.frozen)
        axes = self.effective_fsdp_axes(pdef, mesh)
        degree = math.prod(mesh.shape[a] for a in axes) if axes else 1
        if not axes or pdef.shape[d] % degree != 0:
            return GatherPlan(None, (), (), 2, pdef.frozen)
        inter = tuple(a for a in axes if a == INTER_AXIS)
        intra = tuple(a for a in axes if a != INTER_AXIS)
        # cache boundary: after the inter stage if one exists, else after
        # the full gather (single-pod / pod-replicated storage).
        cache_after = 1 if inter else 2
        body_dim = d - 1 if ("stack" in pdef.dims and
                             pdef.dims.index("stack") < d) else d
        return GatherPlan(body_dim, inter, intra, cache_after, pdef.frozen,
                          compress_bwd=(compress_bwd and bool(inter)
                                        and not pdef.frozen))

    def plan_tree(self, defs, mesh, min_shard_size: int = 0,
                  compress_bwd: bool = False):
        from repro.core.partition import tree_map_defs
        return tree_map_defs(
            lambda p: self.gather_plan(p, mesh, min_shard_size, compress_bwd),
            defs)

    # -- FCDP-Cache ----------------------------------------------------------
    def device_cache_groups(self, n_groups: int, fraction: float) -> int:
        """How many leading layer groups keep their cache on device."""
        if not self.supports_device_cache:
            return 0
        return int(round(fraction * n_groups))

    # -- scheduler streams ----------------------------------------------------
    def prefetch_depth(self, sys, mesh_like) -> int:
        """Resolved ring-buffer depth for the streaming gather scheduler.

        mesh_like: anything with ``axis_names`` (Mesh or MeshInfo).
        0 when the mode has no stage 1 (MiCS/hier), the mesh has no slow
        tier (single pod), or the config asks for the sequential
        schedule; otherwise min(requested depth, max_prefetch_depth).
        """
        depth = getattr(sys, "prefetch_depth", None)
        if depth is None:                    # raw legacy configs
            depth = 1 if getattr(sys, "prefetch", False) else 0
        if INTER_AXIS not in tuple(mesh_like.axis_names):
            return 0
        return max(0, min(int(depth), self.max_prefetch_depth))

    def prefetch_active(self, sys, mesh_like) -> bool:
        """Whether the layer-ahead stage-1 prefetch schedule applies."""
        return self.prefetch_depth(sys, mesh_like) > 0

    def async_grad_reduce_active(self, sys, mesh_like) -> bool:
        """Whether the async pod-axis gradient-reduce stream applies:
        the strategy must have a non-empty stage 1 whose per-microbatch
        reduce can be taken off the critical path, and the mesh must
        have a slow tier to hide."""
        return (bool(getattr(sys, "async_grad_reduce", False))
                and self.supports_async_grad_reduce
                and INTER_AXIS in tuple(mesh_like.axis_names))

    # -- analytic byte accounting --------------------------------------------
    def cached_bytes_for(self, pdef, plan: GatherPlan, mi) -> float:
        """Per-chip size of this param's cached tier (0 when regathered).

        cache_after=1 (multi-pod): the stage-1 shard, i.e. the chip's
        storage shard gathered over the inter axes.
        cache_after=2 (single-pod): the fully gathered TP-local weight.
        """
        if not plan.is_gathered:
            return 0.0
        import jax
        nbytes = pdef.size() * jax.dtypes.canonicalize_dtype(
            pdef.dtype).itemsize
        if plan.cache_after == 1:
            shard = nbytes / self._storage_degree(pdef, mi)
            inter_deg = math.prod(mi.size(a) for a in plan.inter_axes) or 1
            return shard * inter_deg
        return nbytes / (mi.tp if pdef.tp_dim is not None else 1)

    @staticmethod
    def _storage_degree(pdef, mi) -> int:
        deg = 1
        if pdef.fsdp_dim is not None:
            for a in mi.fsdp_axes:
                deg *= mi.size(a)
        if pdef.tp_dim is not None:
            deg *= mi.tp
        return deg

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
# Concrete strategies
# ---------------------------------------------------------------------------

class Zero3(ShardingStrategy):
    """Full sharding, re-gather forward AND backward (paper baseline)."""
    name = "zero3"
    cache_placement = "regather"


class ZeroPP(ShardingStrategy):
    """Full sharding; stage-1 result cached in HBM, backward re-runs
    stage 2 only (ZeRO++ analog)."""
    name = "zeropp"
    cache_placement = "device"


class FCDP(ShardingStrategy):
    """Full sharding; stage-1 result cached in pinned host memory,
    backward re-runs stage 2 only (the paper). Frozen params store in the
    cached layout (FCDP-Comm) and the tau device-cache split applies
    (FCDP-Cache)."""
    name = "fcdp"
    cache_placement = "host"
    frozen_cached_layout = True
    supports_device_cache = True


class MiCS(ShardingStrategy):
    """Pod-local (subgroup) sharding: storage is already pod-replicated,
    stage 1 is structurally empty, and the single intra stage recomputes
    (fwd+bwd intra AG, no DCN AG). Gradients all-reduce across pods."""
    name = "mics"
    cache_placement = "regather"
    max_prefetch_depth = 0            # stage 1 structurally empty
    supports_async_grad_reduce = False

    def storage_fsdp_axes(self, mesh, frozen: bool) -> Tuple[str, ...]:
        return intra_fsdp_axes(mesh)


class Hierarchical(MiCS):
    """Hierarchical partitioning (Xu et al.): params shard intra-pod
    only (MiCS gathers: no DCN AG in the step), but optimizer state and
    master weights shard over the FULL ('pod','data') product -- the
    low-bandwidth trade that keeps MiCS's cheap gathers while paying
    only one pod-axis grad reduce-scatter plus one pod-axis updated-
    shard all-gather per step (amortized over all microbatches) instead
    of MiCS's per-step pod all-reduce of full shard-level grads."""
    name = "hier"

    def opt_spec(self, pdef, mesh, min_shard_size: int = 0) -> P:
        full = dataclasses.replace(pdef, fsdp_scope="full")
        # bypass the pod-replicated param layout: opt state goes
        # full-width. Storage axes come FIRST in the tiling order so the
        # widening reduce-scatter of a storage-sharded gradient (which
        # subdivides each storage block over the widening axes) lands on
        # the same global slice the opt spec assigns to the device.
        storage = self.effective_fsdp_axes(full, mesh)
        widened = storage + tuple(a for a in fsdp_axes(mesh)
                                  if a not in storage)
        spec = self._spec_with_axes(full, mesh, widened, min_shard_size)
        if pdef.fsdp_dim is not None and spec[pdef.fsdp_dim] is None:
            # full-width degree does not divide: keep the param layout
            # (opt state must never shard narrower than storage)
            return super().opt_spec(pdef, mesh, min_shard_size)
        return spec


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ShardingStrategy] = {}


def register_strategy(cls: Type[ShardingStrategy]) -> Type[ShardingStrategy]:
    """Register a strategy class under its ``name`` (singleton instance)."""
    if not cls.name or cls.name == "base":
        raise ValueError(f"strategy {cls.__name__} needs a unique name")
    _REGISTRY[cls.name] = cls()
    return cls


for _cls in (Zero3, ZeroPP, FCDP, MiCS, Hierarchical):
    register_strategy(_cls)

DEFAULT_STRATEGY = FCDP.name


def strategy_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_strategy(name: str) -> ShardingStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown system mode {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def resolve_strategy(mode: Union[str, ShardingStrategy]) -> ShardingStrategy:
    """Accept a mode name or an already-resolved strategy object."""
    if isinstance(mode, ShardingStrategy):
        return mode
    return get_strategy(mode)
