"""Beyond-paper optimization: int8 block-quantized gradient reduce over
the DCN ('pod') axis, inspired by ZeRO++'s qgZ but expressed as a
custom-VJP stage-1 gather whose transpose runs the reduce-scatter in
int8 (half the DCN bytes of bf16).

Forward is the ordinary stage-1 all-gather; only the backward collective
is quantized. Quantization is symmetric per block of 256 elements along
the flattened tensor.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

BLOCK = 256


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 blockwise quantization over the flattened tensor."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_psum_scatter(g: jax.Array, axis_name: str, dim: int) -> jax.Array:
    """Reduce-scatter over `axis_name` along `dim`, transported in int8.

    Each rank splits g into n chunks along dim, quantizes, all_to_all's
    the chunks so rank j receives every rank's chunk j, dequantizes and
    sums. Result: the local shard of the reduced tensor.
    """
    n = axis_size(axis_name)
    if n == 1:
        return g
    # move dim to front and split into n chunks
    g_moved = jnp.moveaxis(g, dim, 0)
    lead = g_moved.shape[0]
    assert lead % n == 0
    chunk_elems = (lead // n) * math.prod(g_moved.shape[1:])
    flat = g_moved.reshape(n, chunk_elems).astype(jnp.float32)
    pad = (-chunk_elems) % BLOCK
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    nb = flat.shape[1] // BLOCK                     # blocks per chunk
    blocks = flat.reshape(n, nb, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=2, keepdims=True)
                        / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    q_x = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                             tiled=True).reshape(n, nb, BLOCK)
    s_x = jax.lax.all_to_all(scale.astype(jnp.float32), axis_name,
                             split_axis=0, concat_axis=0,
                             tiled=True).reshape(n, nb, 1)
    vals = q_x.astype(jnp.float32) * s_x            # dequant
    summed = jnp.sum(vals, axis=0).reshape(-1)      # reduce over sources
    chunk_shape = (lead // n,) + g_moved.shape[1:]
    out = summed[:chunk_elems].reshape(chunk_shape)
    return jnp.moveaxis(out, 0, dim).astype(g.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def compressed_stage1_gather(w, axis_name: str, dim: int):
    """all_gather over the pod axis whose *gradient* reduce-scatter is
    int8-compressed."""
    return jax.lax.all_gather(w, axis_name, axis=dim, tiled=True)


def _fwd(w, axis_name, dim):
    return compressed_stage1_gather(w, axis_name, dim), None


def _bwd(axis_name, dim, _, g):
    return (int8_psum_scatter(g, axis_name, dim),)


compressed_stage1_gather.defvjp(_fwd, _bwd)
