"""Beyond-paper optimization: int8 block-quantized collectives over the
DCN ('pod') axis, after ZeRO++ (arXiv:2306.10209).

Two seams live here, both built on the shared per-256-block symmetric
quantization codepath in kernels/quant.py (jnp oracle or Pallas kernel,
selected by `impl`):

  * qgZ -- `compressed_stage1_gather`: the ordinary stage-1 all-gather
    whose *gradient* reduce-scatter transports int8 (half the DCN bytes
    of bf16). Forward stays exact.
  * qwZ -- `quantized_stage1_gather`: the stage-1 weight all-gather
    itself transports int8 blocks + fp32 scales and dequantizes on
    arrival (~2x fewer DCN bytes than bf16). Under FCDP the dequantized
    result is what gets host-cached, so the backward reuse stays free
    and full-precision.

`impl` is the config-level selector ('jnp' | 'pallas' |
'pallas_interpret'); kernels/ops.py owns the dispatch.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size
from repro.kernels import ops as kops
from repro.kernels.quant import BLOCK, SCALE_EPS  # noqa: F401  (re-export)


def _impl_kw(impl: str) -> dict:
    """Map config-level quant_impl to kernels/ops.py dispatch kwargs."""
    if impl == "jnp":
        return {"impl": "jnp"}
    return {"impl": "pallas", "interpret": impl == "pallas_interpret"}


def _quantize(g: jax.Array, impl: str = "jnp") -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 blockwise quantization over the flattened tensor.
    Returns (q int8 [nb, BLOCK], scale f32 [nb, 1])."""
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return kops.int8_quantize_blocks(flat.reshape(-1, BLOCK).astype(
        jnp.float32), **_impl_kw(impl))


def int8_psum_scatter(g: jax.Array, axis_name: str, dim: int,
                      impl: str = "jnp") -> jax.Array:
    """Reduce-scatter over `axis_name` along `dim`, transported in int8.

    Each rank splits g into n chunks along dim, quantizes, all_to_all's
    the chunks so rank j receives every rank's chunk j, then runs the
    dequant-accumulate inner loop. Result: the local shard of the
    reduced tensor.
    """
    n = axis_size(axis_name)
    if n == 1:
        return g
    # move dim to front and split into n chunks
    g_moved = jnp.moveaxis(g, dim, 0)
    lead = g_moved.shape[0]
    assert lead % n == 0
    chunk_elems = (lead // n) * math.prod(g_moved.shape[1:])
    flat = g_moved.reshape(n, chunk_elems).astype(jnp.float32)
    pad = (-chunk_elems) % BLOCK
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    nb = flat.shape[1] // BLOCK                     # blocks per chunk
    q, scale = kops.int8_quantize_blocks(
        flat.reshape(n * nb, BLOCK), **_impl_kw(impl))
    q_x = jax.lax.all_to_all(q.reshape(n, nb, BLOCK), axis_name,
                             split_axis=0, concat_axis=0,
                             tiled=True).reshape(n, nb, BLOCK)
    s_x = jax.lax.all_to_all(scale.reshape(n, nb, 1), axis_name,
                             split_axis=0, concat_axis=0,
                             tiled=True).reshape(n, nb, 1)
    summed = kops.int8_dequant_accumulate(
        q_x, s_x, **_impl_kw(impl)).reshape(-1)     # reduce over sources
    chunk_shape = (lead // n,) + g_moved.shape[1:]
    out = summed[:chunk_elems].reshape(chunk_shape)
    return jnp.moveaxis(out, 0, dim).astype(g.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def compressed_stage1_gather(w, axis_name: str, dim: int,
                             impl: str = "jnp"):
    """all_gather over the pod axis whose *gradient* reduce-scatter is
    int8-compressed (qgZ)."""
    return jax.lax.all_gather(w, axis_name, axis=dim, tiled=True)


def _fwd(w, axis_name, dim, impl):
    return compressed_stage1_gather(w, axis_name, dim, impl), None


def _bwd(axis_name, dim, impl, _, g):
    return (int8_psum_scatter(g, axis_name, dim, impl),)


compressed_stage1_gather.defvjp(_fwd, _bwd)


def _quantized_gather_fwd(w, axis_name: str, dim: int, impl: str):
    """int8-transported stage-1 all-gather: quantize the local shard,
    gather blocks + scales over the pod axis, dequantize on arrival."""
    n = axis_size(axis_name)
    w_moved = jnp.moveaxis(w, dim, 0)
    elems = w_moved.size
    q, s = _quantize(w_moved, impl)
    q_all = jax.lax.all_gather(q, axis_name, axis=0, tiled=True)
    s_all = jax.lax.all_gather(s, axis_name, axis=0, tiled=True)
    vals = kops.int8_dequantize_blocks(q_all, s_all, **_impl_kw(impl))
    # each rank contributed ceil(elems/BLOCK) blocks; drop per-rank pad
    vals = vals.reshape(n, -1)[:, :elems]
    out = vals.reshape((n * w_moved.shape[0],) + w_moved.shape[1:])
    return jnp.moveaxis(out, 0, dim).astype(w.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def quantized_stage1_gather(w, axis_name: str, dim: int,
                            compress_bwd: bool = False, impl: str = "jnp"):
    """qwZ: stage-1 weight all-gather in int8 blocks + fp32 scales.

    The gradient reduce-scatter stays exact unless `compress_bwd`
    additionally routes it through the qgZ int8 path (both halves of
    the ZeRO++ DCN reduction, stacked)."""
    return _quantized_gather_fwd(w, axis_name, dim, impl)


def _qg_fwd(w, axis_name, dim, compress_bwd, impl):
    return quantized_stage1_gather(w, axis_name, dim, compress_bwd,
                                   impl), None


def _qg_bwd(axis_name, dim, compress_bwd, impl, _, g):
    if compress_bwd:
        return (int8_psum_scatter(g, axis_name, dim, impl),)
    return (jax.lax.psum_scatter(g, axis_name, scatter_dimension=dim,
                                 tiled=True),)


quantized_stage1_gather.defvjp(_qg_fwd, _qg_bwd)
