"""Back-compat shim: StepBundle moved to repro.core.engine (bundle.py for
per-cell state, train.py / serve.py for the step builders)."""
from repro.core.engine.bundle import StepBundle

__all__ = ["StepBundle"]
