"""train_step / serve_step builders: shard_map orchestration, gradient
flow (reduce-scatter via gather transposes), optimizer application on
ZeRO shards, and ShapeDtypeStruct input_specs for the dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeCell, SystemConfig
from repro.core import peft as peft_mod
from repro.core.partition import (ParamDef, is_def, spec_tree, storage_spec,
                                  shape_dtype_tree, init_params)
from repro.launch.mesh import fsdp_axes
from repro.models.common import MeshInfo
from repro.models.registry import build_model
from repro.optim.adamw import (adamw_update, clip_by_global_norm,
                               init_opt_state)


# ---------------------------------------------------------------------------
# Bundle
# ---------------------------------------------------------------------------

class StepBundle:
    """Everything needed to lower/run one (arch x shape x system) cell."""

    def __init__(self, run: RunConfig, mesh):
        self.run = run
        self.mesh = mesh
        self.mi = MeshInfo.from_mesh(mesh)
        cfg, sys = run.model, run.system
        self.model = build_model(cfg, sys, mesh)
        defs = self.model.defs
        if sys.peft:
            defs = peft_mod.apply_lora(defs, cfg, sys)
        elif run.shape.kind != "train" and sys.serve_frozen:
            # serving: all weights frozen -> FCDP-Comm cached layout
            defs = peft_mod.freeze_all(defs)
        if defs is not self.model.defs:
            self.model._defs = defs
            from repro.core.fcdp import plan_tree
            self.model._plans = plan_tree(
                defs, mesh, sys.mode, sys.min_shard_size,
                compress_bwd=(sys.grad_compress == "int8_pod"))
        from repro.core.partition import label_tree
        self.model._defs = label_tree(self.model.defs)
        self.defs = self.model.defs
        self.def_leaves, self.treedef = jax.tree.flatten(
            self.defs, is_leaf=is_def)
        self.train_idx = [i for i, d in enumerate(self.def_leaves)
                          if not d.frozen]
        self.frozen_idx = [i for i, d in enumerate(self.def_leaves)
                           if d.frozen]
        self.leaf_specs = [storage_spec(d, mesh, sys.mode, sys.min_shard_size)
                           for d in self.def_leaves]
        # ZeRO-2-for-experts: 'inter_only' (weight-resident) tensors keep
        # their PARAMS pod-sharded but their OPTIMIZER state fully sharded;
        # gradients are reduce-scattered over the intra axes before the
        # update and the updated shard is gathered back once per step.
        self.full_specs = [
            storage_spec(dataclasses.replace(d, fsdp_scope="full"), mesh,
                         sys.mode, sys.min_shard_size)
            for d in self.def_leaves]
        self.rep_factors = [self._replication(s) for s in self.full_specs]

    def _replication(self, spec: P) -> float:
        used = set()
        for e in spec:
            if e is None:
                continue
            if isinstance(e, (tuple, list)):
                used.update(e)
            else:
                used.add(e)
        rep = 1
        for a in self.mi.axis_names:
            if a not in used:
                rep *= self.mi.size(a)
        return float(rep)

    # -- param materialization ------------------------------------------------
    def init_all_params(self, seed: int = 0) -> List[jax.Array]:
        sys = self.run.system
        vals = init_params(self.defs, seed, self.mesh, sys.mode,
                           sys.min_shard_size)
        return jax.tree.leaves(vals)

    def split(self, leaves: List[Any]) -> Tuple[List[Any], List[Any]]:
        return ([leaves[i] for i in self.train_idx],
                [leaves[i] for i in self.frozen_idx])

    def merge(self, train: List[Any], frozen: List[Any]):
        leaves: List[Any] = [None] * len(self.def_leaves)
        for i, v in zip(self.train_idx, train):
            leaves[i] = v
        for i, v in zip(self.frozen_idx, frozen):
            leaves[i] = v
        return jax.tree.unflatten(self.treedef, leaves)

    def _leaf_sds(self, idxs) -> List[jax.ShapeDtypeStruct]:
        out = []
        for i in idxs:
            d = self.def_leaves[i]
            out.append(jax.ShapeDtypeStruct(
                d.shape, d.dtype,
                sharding=NamedSharding(self.mesh, self.leaf_specs[i])))
        return out

    # -- batch specs ------------------------------------------------------
    def batch_spec(self, cell: ShapeCell) -> Dict[str, P]:
        dp = self.mi.dp
        bspec = P(self.mi.fsdp_axes) if cell.global_batch % dp == 0 else P()
        cfg = self.run.model
        out = {"ids": bspec, "labels": bspec, "mask": bspec}
        if cfg.num_encoder_layers > 0:
            out["enc_embeds"] = bspec
        return out

    def batch_sds(self, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.run.model
        B, S = cell.global_batch, cell.seq_len
        specs = self.batch_spec(cell)
        out = {
            "ids": jax.ShapeDtypeStruct(
                (B, S), jnp.int32,
                sharding=NamedSharding(self.mesh, specs["ids"])),
            "labels": jax.ShapeDtypeStruct(
                (B, S), jnp.int32,
                sharding=NamedSharding(self.mesh, specs["labels"])),
            "mask": jax.ShapeDtypeStruct(
                (B, S), jnp.bool_,
                sharding=NamedSharding(self.mesh, specs["mask"])),
        }
        if cfg.num_encoder_layers > 0:
            # audio frontend stub: precomputed frame embeddings, 1/4 length
            out["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, max(S // 4, 8), cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(self.mesh, specs["enc_embeds"]))
        return out

    # ======================================================================
    # train step
    # ======================================================================
    def make_train_step(self):
        run, mesh, mi = self.run, self.mesh, self.mi
        sys, opt_cfg = run.system, run.optimizer
        model = self.model
        train_defs = [self.def_leaves[i] for i in self.train_idx]
        train_reps = [self.rep_factors[i] for i in self.train_idx]
        wd_mask = [len(d.shape) >= 2 and "_lora_" not in d.label
                   for d in train_defs]
        dp_axes = mi.fsdp_axes
        tp_present = mi.tp > 1
        cell = run.shape
        bspecs = self.batch_spec(cell)
        from repro.launch.mesh import intra_fsdp_axes
        intra = intra_fsdp_axes(mesh)
        # ZeRO-2 (weight-resident) leaves: params pod-sharded, opt fully
        # sharded; grads get an extra intra-axis reduce-scatter, updated
        # shards get one intra all-gather per step.
        zero2 = [j for j, i in enumerate(self.train_idx)
                 if (self.leaf_specs[i] != self.full_specs[i]
                     and self.def_leaves[i].fsdp_scope == "inter_only")]
        z2_dims = {j: train_defs[j].fsdp_dim for j in zero2}

        def rs_intra(g, dim):
            return jax.lax.psum_scatter(g, intra, scatter_dimension=dim,
                                        tiled=True)

        def ag_intra(p_, dim):
            from jax._src.lax.parallel import all_gather_invariant
            for a in intra:
                p_ = all_gather_invariant(p_, a, axis=dim, tiled=True)
            return p_

        def step_body(train_params, frozen_params, opt_state, batch):
            def loss_fn(train_params):
                params = self.merge(train_params, frozen_params)
                loss_sum, cnt, aux = model.loss_fn(params, batch)
                loss_sum = jax.lax.psum(loss_sum, dp_axes) if dp_axes else loss_sum
                cnt = jax.lax.psum(cnt, dp_axes) if dp_axes else cnt
                aux = jax.lax.psum(aux, dp_axes) if dp_axes else aux
                ce = loss_sum / jnp.maximum(cnt, 1.0)
                aux_n = aux / jnp.maximum(cnt, 1.0)
                return ce + aux_n, (ce, aux_n, cnt)

            if run.microbatch and run.microbatch > 1:
                # gradient accumulation over microbatches
                nm = run.microbatch
                def mb_slice(x, i):
                    b = x.shape[0] // nm
                    return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)
                def acc_body(carry, i):
                    g_acc, ce_acc = carry
                    mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
                    def mb_loss(tp_):
                        params = self.merge(tp_, frozen_params)
                        ls, c, a = model.loss_fn(params, mb)
                        ls = jax.lax.psum(ls, dp_axes) if dp_axes else ls
                        c = jax.lax.psum(c, dp_axes) if dp_axes else c
                        a = jax.lax.psum(a, dp_axes) if dp_axes else a
                        return ls / jnp.maximum(c, 1.0) + a / jnp.maximum(c, 1.0), ls / jnp.maximum(c, 1.0)
                    (l, ce), g = jax.value_and_grad(mb_loss, has_aux=True)(train_params)
                    g_acc = jax.tree.map(jnp.add, g_acc, g)
                    return (g_acc, ce_acc + ce), None
                from repro.models.common import pvary_like
                g0 = jax.tree.map(
                    lambda p_: pvary_like(jnp.zeros_like(p_), p_),
                    train_params)
                (grads, ce_sum), _ = jax.lax.scan(
                    acc_body, (g0, jnp.float32(0)), jnp.arange(nm))
                grads = jax.tree.map(lambda g: g / nm, grads)
                ce, auxl, cnt = ce_sum / nm, jnp.float32(0), jnp.float32(1)
            else:
                (_, (ce, auxl, cnt)), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(train_params)

            if zero2:
                grads = [rs_intra(g, z2_dims[j]) if j in z2_dims else g
                         for j, g in enumerate(grads)]
            grads, gnorm = clip_by_global_norm(
                grads, train_reps, opt_cfg.grad_clip, dp_axes, tp_present)
            new_params, new_opt = adamw_update(
                grads, opt_state, opt_cfg, sys, wd_mask)
            if zero2:
                new_params = [ag_intra(p_, z2_dims[j]) if j in z2_dims else p_
                              for j, p_ in enumerate(new_params)]
            metrics = {"loss": ce, "aux_loss": auxl, "grad_norm": gnorm,
                       "tokens": cnt}
            return new_params, new_opt, metrics

        train_specs = [self.leaf_specs[i] for i in self.train_idx]
        frozen_specs = [self.leaf_specs[i] for i in self.frozen_idx]
        opt_leaf_specs = [self.full_specs[i] for i in self.train_idx]
        opt_specs = {"m": opt_leaf_specs, "v": opt_leaf_specs,
                     "master": opt_leaf_specs, "step": P()}
        metric_specs = {"loss": P(), "aux_loss": P(), "grad_norm": P(),
                        "tokens": P()}

        fn = shard_map(
            step_body, mesh=mesh,
            in_specs=(train_specs, frozen_specs, opt_specs, bspecs),
            out_specs=(train_specs, opt_specs, metric_specs),
            check_vma=True)
        return jax.jit(fn, donate_argnums=(0, 2))

    def train_input_sds(self):
        """ShapeDtypeStructs for lowering the train step (no allocation)."""
        sys = self.run.system
        train_sds = self._leaf_sds(self.train_idx)
        frozen_sds = self._leaf_sds(self.frozen_idx)
        od, md = jnp.dtype(sys.opt_state_dtype), jnp.dtype(sys.master_dtype)
        opt_sh = [NamedSharding(self.mesh, self.full_specs[i])
                  for i in self.train_idx]
        def with_dtype(dt):
            return [jax.ShapeDtypeStruct(s.shape, dt, sharding=sh)
                    for s, sh in zip(train_sds, opt_sh)]
        opt_sds = {"m": with_dtype(od),
                   "v": with_dtype(od),
                   "master": with_dtype(md),
                   "step": jax.ShapeDtypeStruct(
                       (), jnp.int32,
                       sharding=NamedSharding(self.mesh, P()))}
        return train_sds, frozen_sds, opt_sds, self.batch_sds(self.run.shape)

    # ======================================================================
    # serve steps (prefill / decode)
    # ======================================================================
    def _serve_batch_dims(self, cell: ShapeCell,
                          seq_sharded: bool = False) -> Tuple[int, P]:
        """Batch sharding for serving. When the sequence dimension owns
        'data' (long-context), batch may only use the remaining fsdp axes."""
        mi = self.mi
        axes = tuple(a for a in mi.fsdp_axes
                     if not (seq_sharded and a == mi.seq_axis))
        deg = 1
        for a in axes:
            deg *= mi.size(a)
        if axes and cell.global_batch % deg == 0:
            return cell.global_batch // deg, P(axes)
        return cell.global_batch, P()

    def make_prefill_step(self):
        run, mesh, mi = self.run, self.mesh, self.mi
        model = self.model
        cell = run.shape
        b_local, bspec = self._serve_batch_dims(cell)
        cfg = run.model

        if cfg.num_encoder_layers > 0:
            def body(params_leaves, enc_embeds, ids, state):
                params = jax.tree.unflatten(self.treedef, params_leaves)
                return model.prefill_fn(params, enc_embeds, ids, state)
        else:
            def body(params_leaves, ids, state):
                params = jax.tree.unflatten(self.treedef, params_leaves)
                return model.prefill_fn(params, ids, state)

        state_specs = self._state_specs(cell, seq_sharded=False)
        logits_spec = P(bspec[0] if len(bspec) else None, "model")
        if cfg.num_encoder_layers > 0:
            in_specs = (self.leaf_specs, bspec, bspec, state_specs)
        else:
            in_specs = (self.leaf_specs, bspec, state_specs)
        fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=(logits_spec, state_specs),
                       check_vma=True)
        return jax.jit(fn, donate_argnums=(2,) if cfg.num_encoder_layers == 0
                       else (3,))

    def make_decode_step(self, seq_sharded: bool = False):
        run, mesh, mi = self.run, self.mesh, self.mi
        model = self.model
        cell = run.shape
        b_local, bspec = self._serve_batch_dims(cell, seq_sharded)

        def body(params_leaves, tok, state):
            params = jax.tree.unflatten(self.treedef, params_leaves)
            return model.decode_fn(params, tok, state,
                                   seq_sharded=seq_sharded)

        state_specs = self._state_specs(cell, seq_sharded)
        logits_spec = P(bspec[0] if len(bspec) else None, "model")
        fn = shard_map(body, mesh=mesh,
                       in_specs=(self.leaf_specs, bspec, state_specs),
                       out_specs=(logits_spec, state_specs),
                       check_vma=True)
        return jax.jit(fn, donate_argnums=(2,))

    def _state_specs(self, cell: ShapeCell, seq_sharded: bool):
        """PartitionSpec tree matching init_decode_state's structure.

        States carry GLOBAL logical shapes; these specs slice them:
          - batch dim (1, after the stack dim) over the fsdp axes
          - kv-cache seq dim over 'data' when seq_sharded (long-context)
          - TP-owned dims ('model'): rwkv heads, mamba d_inner channels
        """
        mi = self.mi
        _, bspec = self._serve_batch_dims(cell, seq_sharded)
        batch_axes = bspec[0] if len(bspec) else None
        example = self._abstract_state(cell, seq_sharded)
        paths, treedef = jax.tree.flatten_with_path(example)
        specs = []
        for path, arr in paths:
            keys = [str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path]
            name = keys[-1]
            kind = keys[-2] if len(keys) >= 2 else ""
            nd = arr.ndim
            ent = [None] * nd
            if nd >= 2 and batch_axes is not None:
                ent[1] = batch_axes
            if kind in ("attn", "xattn") and name in ("k", "v"):
                if seq_sharded and kind == "attn":
                    ent[2] = mi.seq_axis   # batch axes already exclude it
                elif kind == "attn" and nd >= 4 and mi.tp > 1:
                    ent[3] = "model"       # TP-sharded kv-head slots
            elif kind == "mamba":
                if name == "conv" and nd >= 4:
                    ent[3] = "model"
                elif name == "h" and nd >= 3:
                    ent[2] = "model"
            elif kind == "rwkv_tm" and name == "s" and nd >= 3:
                ent[2] = "model"
            specs.append(P(*ent))
        return jax.tree.unflatten(treedef, specs)

    def _abstract_state(self, cell: ShapeCell, seq_sharded: bool):
        cfg = self.run.model
        kw = {}
        if cfg.num_encoder_layers > 0:
            kw["enc_len"] = max(cell.seq_len // 4, 8)
        return jax.eval_shape(
            lambda: self.model.init_decode_state(
                cell.global_batch, cell.seq_len, seq_sharded=seq_sharded,
                **kw))

    def init_state(self, cell: ShapeCell, seq_sharded: bool = False):
        """Materialize a decode state placed per _state_specs (smoke/serve)."""
        cfg = self.run.model
        kw = {}
        if cfg.num_encoder_layers > 0:
            kw["enc_len"] = max(cell.seq_len // 4, 8)
        specs = self._state_specs(cell, seq_sharded)
        shardings = jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)
        fn = jax.jit(lambda: self.model.init_decode_state(
            cell.global_batch, cell.seq_len, seq_sharded=seq_sharded, **kw),
            out_shardings=shardings)
        return fn()

    def state_sds(self, cell: ShapeCell, seq_sharded: bool):
        """ShapeDtypeStruct state tree with shardings for dry-run."""
        abstract = self._abstract_state(cell, seq_sharded)
        specs = self._state_specs(cell, seq_sharded)

        def glue(a, s):
            return jax.ShapeDtypeStruct(
                a.shape, a.dtype, sharding=NamedSharding(self.mesh, s))
        return jax.tree.map(glue, abstract, specs)

    def prefill_input_sds(self):
        """Inputs for lowering the prefill step."""
        cell = self.run.shape
        cfg = self.run.model
        params_sds = self._leaf_sds(range(len(self.def_leaves)))
        _, bspec = self._serve_batch_dims(cell)
        B, S = cell.global_batch, cell.seq_len
        ids = jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=NamedSharding(self.mesh, bspec))
        state = self.state_sds(cell, seq_sharded=False)
        if cfg.num_encoder_layers > 0:
            enc = jax.ShapeDtypeStruct(
                (B, max(S // 4, 8), cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(self.mesh, bspec))
            return params_sds, enc, ids, state
        return params_sds, ids, state

    def decode_input_sds(self, seq_sharded: bool = False):
        """Inputs for lowering one decode step."""
        cell = self.run.shape
        params_sds = self._leaf_sds(range(len(self.def_leaves)))
        _, bspec = self._serve_batch_dims(cell, seq_sharded)
        tok = jax.ShapeDtypeStruct(
            (cell.global_batch, 1), jnp.int32,
            sharding=NamedSharding(self.mesh, bspec))
        state = self.state_sds(cell, seq_sharded=seq_sharded)
        return params_sds, tok, state
