"""FCDP-Cache: the ahead-of-time memory planner (the paper's tau knob).

XLA has no runtime allocator to poll, so the paper's "monitor GPU memory
pressure, cache on-device when below tau" becomes a compile-time search:
start from the fastest placement (device cache for every layer group),
compile, read memory_analysis(), and demote groups device -> host ->
regather until the step fits tau * HBM. Worst case (all regather) is
exactly ZeRO-3 -- the paper's safety guarantee as a static property.

Also provides the host-DRAM budget accounting (the paper's "~2W bytes of
host memory per node"): on the CPU backend pinned_host placements are
dropped, so bench/memory reporting uses these analytic numbers to
separate would-be-host bytes from true device temps.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax

from repro.core.fcdp import GatherPlan
from repro.core.partition import is_def

HBM_PER_CHIP = 16 * 2**30          # v5e


def cache_bytes_per_chip(bundle) -> Dict[str, float]:
    """Analytic size of the FCDP cache tier, per chip.

    cache_after=1 (multi-pod): the stage-1 (intra-pod) shard, i.e.
    param_bytes / (data*tp) per chip -- summed = W_bf16/(data*tp)*layers'
    worth = W/(pod-degree) per pod total, the paper's 'W per node'.
    cache_after=2 (single-pod): the fully gathered TP-local weight.
    """
    from repro.core.fcdp import plan_tree
    mi = bundle.mi
    sysc = bundle.run.system
    plans = jax.tree.leaves(
        bundle.model.plans,
        is_leaf=lambda x: isinstance(x, GatherPlan))
    defs = bundle.def_leaves
    host = 0.0
    for d, p in zip(defs, plans):
        if not isinstance(p, GatherPlan) or not p.is_gathered:
            continue
        nbytes = d.size() * jax.dtypes.canonicalize_dtype(d.dtype).itemsize
        if p.cache_after == 1:
            # stage-1 result = the chip's shard gathered over inter axes
            shard = nbytes / _spec_degree(d, mi)
            inter_deg = math.prod(mi.size(a) for a in p.inter_axes) or 1
            host += shard * inter_deg
        else:
            # fully gathered TP-local tensor (single-pod layout)
            host += nbytes / (mi.tp if d.tp_dim is not None else 1)
    return {"host_cache_bytes_per_chip": host}


def _spec_degree(d, mi) -> int:
    deg = 1
    if d.fsdp_dim is not None:
        for a in mi.fsdp_axes:
            deg *= mi.size(a)
    if d.tp_dim is not None:
        deg *= mi.tp
    return deg


@dataclass
class CachePlan:
    """Per-segment placement emitted by the planner (consumed by
    LM._segments via SystemConfig.device_cache_fraction)."""
    device_fraction: float
    fits: bool
    peak_bytes: int
    host_bytes: float
    iterations: List[Dict]


class MemoryPlanner:
    """Iterative tau search over the device-cache fraction."""

    def __init__(self, hbm_budget: int = HBM_PER_CHIP,
                 host_budget: Optional[int] = None):
        self.hbm = hbm_budget
        self.host = host_budget

    def _peak(self, bundle) -> int:
        step = bundle.make_train_step()
        c = step.lower(*bundle.train_input_sds()).compile()
        m = c.memory_analysis()
        return (m.argument_size_in_bytes + m.temp_size_in_bytes
                + m.output_size_in_bytes - m.alias_size_in_bytes)

    def plan(self, run, mesh, fractions=(1.0, 0.5, 0.25, 0.0)) -> CachePlan:
        """Try device-cache fractions high->low; after 0.0, fall back to
        activation remat (block_io), then declare regather-only."""
        from repro.core.stepfn import StepBundle
        iters = []
        for frac in fractions:
            sysc = run.system.replace(device_cache_fraction=frac)
            bundle = StepBundle(run.replace(system=sysc), mesh)
            peak = self._peak(bundle)
            host = cache_bytes_per_chip(bundle)["host_cache_bytes_per_chip"]
            iters.append({"device_fraction": frac, "peak_bytes": peak,
                          "host_bytes": host})
            if peak <= self.hbm and (self.host is None or host <= self.host):
                return CachePlan(frac, True, peak, host, iters)
        return CachePlan(0.0, False, iters[-1]["peak_bytes"],
                         iters[-1]["host_bytes"], iters)
