"""FCDP-Cache: the ahead-of-time memory planner (the paper's tau knob).

XLA has no runtime allocator to poll, so the paper's "monitor GPU memory
pressure, cache on-device when below tau" becomes a compile-time search:
start from the fastest configuration (device cache for every layer
group, the configured prefetch depth), compile, read memory_analysis(),
and demote until the step fits tau * HBM -- prefetch depth FIRST (each
demotion frees one in-flight stage-1 ring buffer and costs only overlap,
not placement), then layer groups device -> host -> regather. If even
(depth=0, device_fraction=0.0) does not fit, the planner tries full
activation remat (block_io) before declaring regather-only; worst case
is exactly ZeRO-3 -- the paper's safety guarantee as a static property.

Also provides the host-DRAM budget accounting (the paper's "~2W bytes of
host memory per node"): on the CPU backend pinned_host placements are
dropped, so bench/memory reporting uses these analytic numbers to
separate would-be-host bytes from true device temps.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax

from repro.core.residency import residency_of
from repro.core.schedule import (GatherScheduler,
                                 async_buffer_bytes_by_group,
                                 async_reduce_enabled,
                                 cross_step_buffer_bytes_by_group,
                                 cross_step_enabled,
                                 prefetch_buffer_bytes_by_group)
from repro.core.strategy import (QUANT_MIN_SHARD_ELEMS, GatherPlan,
                                 leaf_group)

HBM_PER_CHIP = 16 * 2**30          # v5e

QUANT_BLOCK = QUANT_MIN_SHARD_ELEMS   # == kernels/quant.py BLOCK
_BF16_BYTES = 2.0
# int8 wire cost per padded quant block: BLOCK int8 payload + one f32 scale
_INT8_BLOCK_BYTES = float(QUANT_BLOCK + 4)


def _stage1_leaf_wire_bytes(pdef, plan: GatherPlan, mi) -> float:
    """Per-chip DCN wire bytes for one forward stage-1 all-gather of this
    leaf: ring all-gather moves (n-1)/n of the gathered payload per chip.

    compress_fwd leaves ship int8 blocks + f32 scales (qwZ): block count
    follows the per-scan-slice quantization the sequential schedule
    performs (the async leaf-level path quantizes the whole stacked leaf
    at once -- at the >=1-block shard sizes the gate admits, the same
    bytes up to per-slice padding)."""
    n = 1
    for a in plan.inter_axes:
        n *= mi.size(a)
    if n <= 1:
        return 0.0
    degree = n
    for a in plan.intra_axes:
        degree *= mi.size(a)
    if "tp" in pdef.dims:      # leaf is additionally model-sharded
        degree *= mi.tp
    shard_elems = pdef.size() // degree
    if plan.compress_fwd:
        stack = (pdef.shape[pdef.dims.index("stack")]
                 if "stack" in pdef.dims else 1)
        slice_elems = shard_elems // stack
        blocks = stack * (-(-slice_elems // QUANT_BLOCK))
        shard_bytes = blocks * _INT8_BLOCK_BYTES
    else:
        shard_bytes = shard_elems * _BF16_BYTES
    return (n - 1) / n * n * shard_bytes


def stage1_dcn_gather_bytes(bundle) -> Dict[str, float]:
    """Analytic per-chip stage-1 (pod-axis) all-gather wire bytes for ONE
    forward pass, honoring qwZ (``SystemConfig.param_compress``): the
    quantized-vs-exact split the roofline's jaxpr walk measures, derived
    from the plan tree alone so the planner/dryrun can report the DCN
    reduction without tracing. ``exact`` is the bf16 counterfactual."""
    by_group: Dict[str, float] = {}
    exact = 0.0
    for d, p in zip(bundle.def_leaves, bundle.plan_leaves):
        if not isinstance(p, GatherPlan) or not p.inter_axes:
            continue
        g = leaf_group(bundle.strategy, d)
        by_group[g] = by_group.get(g, 0.0) + _stage1_leaf_wire_bytes(
            d, p, bundle.mi)
        exact += _stage1_leaf_wire_bytes(
            d, dataclasses.replace(p, compress_fwd=False), bundle.mi)
    return {"stage1_dcn_gather_bytes_per_chip": sum(by_group.values()),
            "stage1_dcn_gather_bytes_exact": exact,
            "by_group": by_group}


def cache_bytes_per_chip(bundle, kv=None) -> Dict[str, float]:
    """Analytic size of the FCDP cache tier, per chip, split by
    resolved strategy group.

    cache_after=1 (multi-pod): the stage-1 (intra-pod) shard, i.e.
    param_bytes / (data*tp) per chip -- summed = W_bf16/(data*tp)*layers'
    worth = W/(pod-degree) per pod total, the paper's 'W per node'.
    cache_after=2 (single-pod): the fully gathered TP-local weight.

    ``by_group`` maps each resolved strategy group (under per-tensor
    mixed sharding a model holds several) to its analytic cache-tier
    size, cache placement, and its share of the in-flight ring / async
    buffers; the flat totals are the sums over groups. The headline
    ``host_cache_bytes_per_chip`` counts HOST-placed groups only (what
    actually lands in pinned host memory -- regather groups cache
    nothing, device groups pay HBM and show up in the compiled peak).

    Also reports the streaming gather scheduler's in-flight stage-1 ring
    buffers (k x one layer group's stage-1 bytes), the async grad-reduce
    stream's resident stage-1 buffers (the leaf-level gathered param
    view + the carried gradient buffer) when that stream is live, and
    the cross-step pipeline's step-boundary carry (accumulated
    storage-level grads + the last microbatch's pending stage-1 grads)
    when stream 3 is live -- all HBM-resident, so the planner counts
    them against the tau budget.

    kv (a ``core.kv_cache.PagedKVConfig`` or None) adds the paged
    KV-cache pools as a fourth tenant: ``kv_page_bytes_per_chip`` is
    always present (0.0 without a paged serve path) so the dryrun /
    roofline schema is stable across train and serve cells.
    """
    mi = bundle.mi
    strategy = bundle.strategy
    plans = bundle.plan_leaves
    defs = bundle.def_leaves
    by_group: Dict[str, Dict[str, float]] = {}
    for d, p in zip(defs, plans):
        if not isinstance(p, GatherPlan):
            continue
        g = leaf_group(strategy, d)
        gb = by_group.setdefault(
            g, {"cached_bytes_per_chip": 0.0,
                # each group resolves to one strategy, so every leaf in
                # it shares one residency cache tier
                "placement": residency_of(p).cache,
                "n_leaves": 0,
                "prefetch_buffer_bytes_per_chip": 0.0,
                "async_buffer_bytes_per_chip": 0.0,
                "cross_step_buffer_bytes_per_chip": 0.0,
                "stage1_dcn_gather_bytes_per_chip": 0.0})
        gb["cached_bytes_per_chip"] += strategy.cached_bytes_for(d, p, mi)
        gb["n_leaves"] += 1
    # the depth the scheduler actually resolves for this bundle (0 when
    # no plan has a non-empty stage 1, e.g. serve_frozen fcdp layouts)
    depth = GatherScheduler(strategy, bundle.run.system, mi,
                            bundle.model.plans).depth
    for g, b in prefetch_buffer_bytes_by_group(
            strategy, defs, plans, mi, depth).items():
        by_group[g]["prefetch_buffer_bytes_per_chip"] = b
    if async_reduce_enabled(bundle.run, strategy, mi):
        for g, b in async_buffer_bytes_by_group(
                strategy, defs, plans, mi).items():
            by_group[g]["async_buffer_bytes_per_chip"] = b
    xstep = cross_step_enabled(bundle.run, strategy, mi)
    if xstep:
        for g, b in cross_step_buffer_bytes_by_group(
                strategy, defs, plans, mi).items():
            by_group[g]["cross_step_buffer_bytes_per_chip"] = b
    dcn = stage1_dcn_gather_bytes(bundle)
    for g, b in dcn["by_group"].items():
        if g in by_group:
            by_group[g]["stage1_dcn_gather_bytes_per_chip"] = b
    kv_bytes = 0.0
    if kv is not None:
        from repro.core.kv_cache import kv_page_bytes_per_chip
        model = bundle.model
        kv_bytes = kv_page_bytes_per_chip(
            bundle.run.model, mi, getattr(model, "plan", ()),
            getattr(model, "n_groups", 0), kv)
    host = sum(gb["cached_bytes_per_chip"] for gb in by_group.values()
               if gb["placement"] == "host")
    return {"host_cache_bytes_per_chip": host,
            "kv_page_bytes_per_chip": kv_bytes,
            "param_compress": bundle.run.system.param_compress,
            "stage1_dcn_gather_bytes_per_chip": dcn[
                "stage1_dcn_gather_bytes_per_chip"],
            "stage1_dcn_gather_bytes_exact": dcn[
                "stage1_dcn_gather_bytes_exact"],
            "cached_bytes_per_chip": sum(
                gb["cached_bytes_per_chip"] for gb in by_group.values()),
            "prefetch_depth": depth,
            "prefetch_buffer_bytes_per_chip": sum(
                gb["prefetch_buffer_bytes_per_chip"]
                for gb in by_group.values()),
            "async_buffer_bytes_per_chip": sum(
                gb["async_buffer_bytes_per_chip"]
                for gb in by_group.values()),
            "cross_step": xstep,
            "cross_step_buffer_bytes_per_chip": sum(
                gb["cross_step_buffer_bytes_per_chip"]
                for gb in by_group.values()),
            "by_group": by_group}


@dataclass
class CachePlan:
    """Per-segment placement emitted by the planner (consumed by
    LM._segments via SystemConfig.device_cache_fraction)."""
    device_fraction: float
    fits: bool
    peak_bytes: int
    host_bytes: float
    iterations: List[Dict]
    # activation policy the winning configuration ran with -- differs
    # from the run's own policy only when the block_io fallback fired
    activation_policy: str = "save_all"
    # prefetch depth the winning configuration ran with -- may be lower
    # than the run's own depth when ring buffers were demoted to fit
    prefetch_depth: int = 0
    # whether the winning configuration keeps the cross-step optimizer
    # pipeline (stream 3); demoted FIRST -- dropping it frees the
    # step-boundary carry buffers and costs only epilogue overlap
    cross_step: bool = False
    # paged-KV pool capacity (pages per replica) the winning serve
    # configuration keeps -- None for train plans; demoted LAST on the
    # serve path (shrinking it bounds batch concurrency, a throughput
    # property, never correctness)
    kv_pages: Optional[int] = None


class MemoryPlanner:
    """Iterative tau search over (prefetch depth, device-cache fraction)."""

    def __init__(self, hbm_budget: int = HBM_PER_CHIP,
                 host_budget: Optional[int] = None):
        self.hbm = hbm_budget
        self.host = host_budget

    def _peak(self, bundle) -> int:
        step = bundle.make_train_step()
        c = step.lower(*bundle.train_input_sds()).compile()
        m = c.memory_analysis()
        return (m.argument_size_in_bytes + m.temp_size_in_bytes
                + m.output_size_in_bytes - m.alias_size_in_bytes)

    def _attempt(self, run, mesh, sysc, iters) -> Dict:
        from repro.core.engine import StepBundle
        bundle = StepBundle(run.replace(system=sysc), mesh)
        peak = self._peak(bundle)
        acct = cache_bytes_per_chip(bundle)
        it = {"device_fraction": sysc.device_cache_fraction,
              "activation_policy": sysc.activation_policy,
              "prefetch_depth": acct["prefetch_depth"],
              "prefetch_buffer_bytes": acct[
                  "prefetch_buffer_bytes_per_chip"],
              "async_buffer_bytes": acct["async_buffer_bytes_per_chip"],
              "cross_step": acct["cross_step"],
              "cross_step_buffer_bytes": acct[
                  "cross_step_buffer_bytes_per_chip"],
              "peak_bytes": peak, "host_bytes": acct[
                  "host_cache_bytes_per_chip"],
              "param_compress": acct["param_compress"],
              "stage1_dcn_gather_bytes": acct[
                  "stage1_dcn_gather_bytes_per_chip"],
              "by_group": acct["by_group"]}
        iters.append(it)
        return it

    def _fits(self, it: Dict) -> bool:
        return (it["peak_bytes"] <= self.hbm
                and (self.host is None or it["host_bytes"] <= self.host))

    def plan(self, run, mesh, fractions=(1.0, 0.5, 0.25, 0.0)) -> CachePlan:
        """Demote until the step fits, in fixed order: the cross-step
        optimizer pipeline first (dropping it frees the step-boundary
        carry buffers and costs only epilogue overlap), then prefetch
        depth (k -> 0 at the fastest device fraction -- each step frees
        one in-flight stage-1 ring buffer and costs only overlap), then
        device-cache fractions high -> low, then the activation-remat
        (block_io) fallback, then declare regather-only.

        Each demotion acts on the groups it can act on (per-tensor mixed
        sharding): a depth step shrinks only the streaming groups' ring
        slots, a fraction step promotes/demotes only the host-placed
        group's segments; every iteration records the per-group byte
        split so the search is auditable group by group."""
        # the depth the run's own (possibly composite) strategy resolves
        # to -- the per-leaf assignment lives on the bundle's def tree,
        # so probe one bundle rather than re-deriving from the mode name
        from repro.core.engine import StepBundle
        probe = StepBundle(run, mesh)
        k0 = probe.strategy.prefetch_depth(run.system, probe.mi)
        x0 = cross_step_enabled(run, probe.strategy, probe.mi)
        attempts = ([(fractions[0], k0, True)] if x0 else []) \
            + [(fractions[0], d, False) for d in range(k0, 0, -1)] \
            + [(f, 0, False) for f in fractions]
        iters: List[Dict] = []
        for frac, depth, xs in attempts:
            sysc = run.system.replace(device_cache_fraction=frac,
                                      prefetch_depth=depth,
                                      cross_step_pipeline=xs)
            it = self._attempt(run, mesh, sysc, iters)
            if self._fits(it):
                return CachePlan(frac, True, it["peak_bytes"],
                                 it["host_bytes"], iters,
                                 activation_policy=sysc.activation_policy,
                                 prefetch_depth=it["prefetch_depth"],
                                 cross_step=it["cross_step"])
        # device cache fully demoted and still over budget: trade compute
        # for memory with full activation remat before giving up
        if run.system.activation_policy != "block_io":
            sysc = run.system.replace(device_cache_fraction=0.0,
                                      prefetch_depth=0,
                                      cross_step_pipeline=False,
                                      activation_policy="block_io")
            it = self._attempt(run, mesh, sysc, iters)
            if self._fits(it):
                return CachePlan(0.0, True, it["peak_bytes"],
                                 it["host_bytes"], iters,
                                 activation_policy="block_io")
        last = iters[-1]
        return CachePlan(0.0, False, last["peak_bytes"], last["host_bytes"],
                         iters, activation_policy=last["activation_policy"])

    # -- serve planning (paged-KV tenant; core/kv_cache.py) ------------------
    def _peak_serve(self, bundle, kv) -> int:
        step = bundle.make_paged_decode_step(kv)
        c = step.lower(*bundle.paged_decode_input_sds(kv)).compile()
        m = c.memory_analysis()
        return (m.argument_size_in_bytes + m.temp_size_in_bytes
                + m.output_size_in_bytes - m.alias_size_in_bytes)

    def _attempt_serve(self, run, mesh, sysc, kv, iters) -> Dict:
        from repro.core.engine import StepBundle
        bundle = StepBundle(run.replace(system=sysc), mesh)
        peak = self._peak_serve(bundle, kv)
        acct = cache_bytes_per_chip(bundle, kv=kv)
        it = {"device_fraction": sysc.device_cache_fraction,
              "activation_policy": sysc.activation_policy,
              "prefetch_depth": acct["prefetch_depth"],
              "prefetch_buffer_bytes": acct[
                  "prefetch_buffer_bytes_per_chip"],
              "kv_pages": kv.pages_per_replica,
              "kv_page_bytes": acct["kv_page_bytes_per_chip"],
              "peak_bytes": peak,
              "host_bytes": acct["host_cache_bytes_per_chip"],
              "param_compress": acct["param_compress"],
              "by_group": acct["by_group"]}
        iters.append(it)
        return it

    def plan_serve(self, run, mesh, kv,
                   fractions=(1.0, 0.5, 0.25, 0.0)) -> CachePlan:
        """Tau search for the paged serve path (decode cell). Tenants
        demote in fixed order, documented in ARCHITECTURE.md §Serving:

          1. prefetch depth k -> 0 (each step frees one in-flight
             stage-1 ring buffer, costs only overlap; resolves to 0
             already under the serve_frozen fcdp layout),
          2. device-cache fraction high -> low (weights fall back to
             the host cache / regather tier),
          3. paged-KV pool capacity, halved until one max-length
             sequence + the scratch page still fit. Capacity bounds
             how many sequences decode concurrently -- a throughput
             knob -- so it is the last tenant to shrink and never
             affects per-request numerics.

        The cross-step carry and activation-remat stages of the train
        search do not apply (serving runs no optimizer/backward).
        """
        from repro.core.engine import StepBundle
        probe = StepBundle(run, mesh)
        k0 = probe.strategy.prefetch_depth(run.system, probe.mi)
        attempts = [(fractions[0], d) for d in range(k0, 0, -1)] \
            + [(f, 0) for f in fractions]
        iters: List[Dict] = []
        for frac, depth in attempts:
            sysc = run.system.replace(device_cache_fraction=frac,
                                      prefetch_depth=depth)
            it = self._attempt_serve(run, mesh, sysc, kv, iters)
            if self._fits(it):
                return CachePlan(frac, True, it["peak_bytes"],
                                 it["host_bytes"], iters,
                                 prefetch_depth=it["prefetch_depth"],
                                 kv_pages=kv.pages_per_replica)
        floor = 1 + kv.max_pages_per_seq
        cur = kv
        sysc = run.system.replace(device_cache_fraction=fractions[-1],
                                  prefetch_depth=0)
        while cur.pages_per_replica > floor:
            cur = dataclasses.replace(
                cur, pages_per_replica=max(
                    floor, (cur.pages_per_replica + 1) // 2))
            it = self._attempt_serve(run, mesh, sysc, cur, iters)
            if self._fits(it):
                return CachePlan(fractions[-1], True, it["peak_bytes"],
                                 it["host_bytes"], iters,
                                 kv_pages=cur.pages_per_replica)
        last = iters[-1]
        return CachePlan(0.0, False, last["peak_bytes"],
                         last["host_bytes"], iters,
                         kv_pages=cur.pages_per_replica)
