"""FCDP-Cache: the ahead-of-time memory planner (the paper's tau knob).

XLA has no runtime allocator to poll, so the paper's "monitor GPU memory
pressure, cache on-device when below tau" becomes a compile-time search:
start from the fastest placement (device cache for every layer group),
compile, read memory_analysis(), and demote groups device -> host ->
regather until the step fits tau * HBM. If even device_fraction=0.0 does
not fit, the planner tries full activation remat (block_io) before
declaring regather-only; worst case is exactly ZeRO-3 -- the paper's
safety guarantee as a static property.

Also provides the host-DRAM budget accounting (the paper's "~2W bytes of
host memory per node"): on the CPU backend pinned_host placements are
dropped, so bench/memory reporting uses these analytic numbers to
separate would-be-host bytes from true device temps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax

from repro.core.strategy import GatherPlan

HBM_PER_CHIP = 16 * 2**30          # v5e


def cache_bytes_per_chip(bundle) -> Dict[str, float]:
    """Analytic size of the FCDP cache tier, per chip.

    cache_after=1 (multi-pod): the stage-1 (intra-pod) shard, i.e.
    param_bytes / (data*tp) per chip -- summed = W_bf16/(data*tp)*layers'
    worth = W/(pod-degree) per pod total, the paper's 'W per node'.
    cache_after=2 (single-pod): the fully gathered TP-local weight.
    """
    mi = bundle.mi
    strategy = bundle.strategy
    plans = jax.tree.leaves(
        bundle.model.plans,
        is_leaf=lambda x: isinstance(x, GatherPlan))
    defs = bundle.def_leaves
    host = 0.0
    for d, p in zip(defs, plans):
        if not isinstance(p, GatherPlan):
            continue
        host += strategy.cached_bytes_for(d, p, mi)
    return {"host_cache_bytes_per_chip": host}


@dataclass
class CachePlan:
    """Per-segment placement emitted by the planner (consumed by
    LM._segments via SystemConfig.device_cache_fraction)."""
    device_fraction: float
    fits: bool
    peak_bytes: int
    host_bytes: float
    iterations: List[Dict]
    # activation policy the winning configuration ran with -- differs
    # from the run's own policy only when the block_io fallback fired
    activation_policy: str = "save_all"


class MemoryPlanner:
    """Iterative tau search over the device-cache fraction."""

    def __init__(self, hbm_budget: int = HBM_PER_CHIP,
                 host_budget: Optional[int] = None):
        self.hbm = hbm_budget
        self.host = host_budget

    def _peak(self, bundle) -> int:
        step = bundle.make_train_step()
        c = step.lower(*bundle.train_input_sds()).compile()
        m = c.memory_analysis()
        return (m.argument_size_in_bytes + m.temp_size_in_bytes
                + m.output_size_in_bytes - m.alias_size_in_bytes)

    def _attempt(self, run, mesh, sysc, iters) -> Dict:
        from repro.core.engine import StepBundle
        bundle = StepBundle(run.replace(system=sysc), mesh)
        peak = self._peak(bundle)
        host = cache_bytes_per_chip(bundle)["host_cache_bytes_per_chip"]
        it = {"device_fraction": sysc.device_cache_fraction,
              "activation_policy": sysc.activation_policy,
              "peak_bytes": peak, "host_bytes": host}
        iters.append(it)
        return it

    def _fits(self, it: Dict) -> bool:
        return (it["peak_bytes"] <= self.hbm
                and (self.host is None or it["host_bytes"] <= self.host))

    def plan(self, run, mesh, fractions=(1.0, 0.5, 0.25, 0.0)) -> CachePlan:
        """Try device-cache fractions high->low; after 0.0, fall back to
        activation remat (block_io), then declare regather-only."""
        iters: List[Dict] = []
        for frac in fractions:
            sysc = run.system.replace(device_cache_fraction=frac)
            it = self._attempt(run, mesh, sysc, iters)
            if self._fits(it):
                return CachePlan(frac, True, it["peak_bytes"],
                                 it["host_bytes"], iters,
                                 activation_policy=sysc.activation_policy)
        # device cache fully demoted and still over budget: trade compute
        # for memory with full activation remat before giving up
        if run.system.activation_policy != "block_io":
            sysc = run.system.replace(device_cache_fraction=0.0,
                                      activation_policy="block_io")
            it = self._attempt(run, mesh, sysc, iters)
            if self._fits(it):
                return CachePlan(0.0, True, it["peak_bytes"],
                                 it["host_bytes"], iters,
                                 activation_policy="block_io")
        last = iters[-1]
        return CachePlan(0.0, False, last["peak_bytes"], last["host_bytes"],
                         iters, activation_policy=last["activation_policy"])
