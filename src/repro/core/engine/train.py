"""Train-step builder: shard_map orchestration, gradient flow
(reduce-scatter via gather transposes), and optimizer application on
ZeRO shards. Consumes a StepBundle whose strategy already fixed the
storage layout and gather schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import HAS_VMA, all_gather_invariant, shard_map
from repro.core.strategy import spec_axes
from repro.launch.mesh import intra_fsdp_axes
from repro.optim.adamw import adamw_update, clip_by_global_norm


def build_train_step(bundle):
    run, mesh, mi = bundle.run, bundle.mesh, bundle.mi
    sys, opt_cfg = run.system, run.optimizer
    model = bundle.model
    train_defs = [bundle.def_leaves[i] for i in bundle.train_idx]
    train_reps = [bundle.rep_factors[i] for i in bundle.train_idx]
    wd_mask = [len(d.shape) >= 2 and "_lora_" not in d.label
               for d in train_defs]
    dp_axes = mi.fsdp_axes
    tp_present = mi.tp > 1
    cell = run.shape
    bspecs = bundle.batch_spec(cell)
    intra = intra_fsdp_axes(mesh)
    # ZeRO-2 (weight-resident) leaves: params pod-sharded, opt fully
    # sharded; grads get an extra intra-axis reduce-scatter, updated
    # shards get one intra all-gather per step.
    zero2 = [j for j, i in enumerate(bundle.train_idx)
             if (bundle.leaf_specs[i] != bundle.full_specs[i]
                 and bundle.def_leaves[i].fsdp_scope == "inter_only")]
    z2_dims = {j: train_defs[j].fsdp_dim for j in zero2}

    # Pre-VMA JAX: shard_map's AD does not auto-insert the cross-axis
    # reductions for grads of params stored REPLICATED over some mesh
    # axes (pod-replicated MiCS/frozen layouts, model-replicated kv/norm
    # weights, min_shard_size-replicated tensors) -- each device would
    # keep only its local partial. Current JAX's varying-mesh-axis type
    # system inserts these psums automatically (transpose of the
    # implicit pvary), so the explicit sum is gated on HAS_VMA. The
    # gather transposes already reduce over the axes present in the
    # storage spec; zero2 leaves' intra sum is handled by rs_intra.
    grad_sync = {}
    if not HAS_VMA:
        for j, i in enumerate(bundle.train_idx):
            if j in z2_dims:
                continue
            missing = tuple(a for a in mi.axis_names
                            if a not in spec_axes(bundle.leaf_specs[i]))
            if missing:
                grad_sync[j] = missing

    def rs_intra(g, dim):
        return jax.lax.psum_scatter(g, intra, scatter_dimension=dim,
                                    tiled=True)

    def ag_intra(p_, dim):
        for a in intra:
            p_ = all_gather_invariant(p_, a, axis=dim, tiled=True)
        return p_

    def step_body(train_params, frozen_params, opt_state, batch):
        def loss_fn(train_params):
            params = bundle.merge(train_params, frozen_params)
            loss_sum, cnt, aux = model.loss_fn(params, batch)
            loss_sum = jax.lax.psum(loss_sum, dp_axes) if dp_axes else loss_sum
            cnt = jax.lax.psum(cnt, dp_axes) if dp_axes else cnt
            aux = jax.lax.psum(aux, dp_axes) if dp_axes else aux
            ce = loss_sum / jnp.maximum(cnt, 1.0)
            aux_n = aux / jnp.maximum(cnt, 1.0)
            return ce + aux_n, (ce, aux_n, cnt)

        if run.microbatch and run.microbatch > 1:
            # gradient accumulation over microbatches
            nm = run.microbatch
            def mb_slice(x, i):
                b = x.shape[0] // nm
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)
            def acc_body(carry, i):
                g_acc, ce_acc = carry
                mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
                def mb_loss(tp_):
                    params = bundle.merge(tp_, frozen_params)
                    ls, c, a = model.loss_fn(params, mb)
                    ls = jax.lax.psum(ls, dp_axes) if dp_axes else ls
                    c = jax.lax.psum(c, dp_axes) if dp_axes else c
                    a = jax.lax.psum(a, dp_axes) if dp_axes else a
                    return ls / jnp.maximum(c, 1.0) + a / jnp.maximum(c, 1.0), ls / jnp.maximum(c, 1.0)
                (l, ce), g = jax.value_and_grad(mb_loss, has_aux=True)(train_params)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, ce_acc + ce), None
            from repro.models.common import pvary_like
            g0 = jax.tree.map(
                lambda p_: pvary_like(jnp.zeros_like(p_), p_),
                train_params)
            # derive the loss-carry zero from a replicated input rather
            # than a literal: scan requires the carry's replication type
            # to match the body output's (which is replicated over every
            # axis after the loss psums), and a bare constant carries no
            # replication type on pre-VMA JAX
            ce0 = (opt_state["step"] * 0).astype(jnp.float32)
            (grads, ce_sum), _ = jax.lax.scan(
                acc_body, (g0, ce0), jnp.arange(nm))
            grads = jax.tree.map(lambda g: g / nm, grads)
            ce, auxl, cnt = ce_sum / nm, jnp.float32(0), jnp.float32(1)
        else:
            (_, (ce, auxl, cnt)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(train_params)

        if grad_sync:
            grads = [jax.lax.psum(g, grad_sync[j]) if j in grad_sync else g
                     for j, g in enumerate(grads)]
        if zero2:
            grads = [rs_intra(g, z2_dims[j]) if j in z2_dims else g
                     for j, g in enumerate(grads)]
        grads, gnorm = clip_by_global_norm(
            grads, train_reps, opt_cfg.grad_clip, dp_axes, tp_present)
        new_params, new_opt = adamw_update(
            grads, opt_state, opt_cfg, sys, wd_mask)
        if zero2:
            new_params = [ag_intra(p_, z2_dims[j]) if j in z2_dims else p_
                          for j, p_ in enumerate(new_params)]
        metrics = {"loss": ce, "aux_loss": auxl, "grad_norm": gnorm,
                   "tokens": cnt}
        return new_params, new_opt, metrics

    train_specs = [bundle.leaf_specs[i] for i in bundle.train_idx]
    frozen_specs = [bundle.leaf_specs[i] for i in bundle.frozen_idx]
    opt_leaf_specs = [bundle.full_specs[i] for i in bundle.train_idx]
    opt_specs = {"m": opt_leaf_specs, "v": opt_leaf_specs,
                 "master": opt_leaf_specs, "step": P()}
    metric_specs = {"loss": P(), "aux_loss": P(), "grad_norm": P(),
                    "tokens": P()}

    fn = shard_map(
        step_body, mesh=mesh,
        in_specs=(train_specs, frozen_specs, opt_specs, bspecs),
        out_specs=(train_specs, opt_specs, metric_specs),
        check_vma=True)
    return jax.jit(fn, donate_argnums=(0, 2))
