"""Train-step builder: shard_map orchestration, gradient flow
(reduce-scatter via gather transposes), and optimizer application on
ZeRO shards. Consumes a StepBundle whose strategy already fixed the
storage layout and gather schedule.

Everything here is PER LEAF, so per-tensor mixed sharding
(CompositeStrategy) needs no special casing: the opt-widening
reduce-scatter/all-gather pair fires for exactly the leaves whose opt
spec is wider than their storage spec (hier embeddings, ZeRO-2-for-
experts), the pre-VMA gradient psums cover exactly the leaves stored
replicated over some axes (mics/hier groups, frozen layouts), and the
async reduce stream defers exactly the leaves with a non-empty stage 1
(the streaming groups) -- single-stage groups' reduces pass through
untouched.

Three gradient/optimizer schedules exist on the accumulation path:

  sequential (default): each microbatch's backward contains the full
  gather transposes, so the pod-axis reduce-scatter serializes after
  every backward, and the optimizer epilogue serializes at the end of
  the step.

  async (SystemConfig.async_grad_reduce, strategy-gated): the scheduler's
  second stream. Each microbatch is differentiated with respect to the
  STAGE-1-GATHERED parameter view (core/schedule.py:
  stage1_resident_plans), so its backward stops at stage-1-level
  gradients with intra-pod reduces only; the pod-axis reduce-scatter of
  microbatch i then runs at the top of iteration i+1, where it has no
  data dependency on microbatch i+1's forward and overlaps with it.
  Memory trade: the stage-1-gathered param view is materialized at leaf
  level for the whole model (instead of per layer inside the scan) and
  one stage-1-sized gradient buffer rides the scan carry --
  core/schedule.py:async_buffer_bytes is the analytic per-chip cost,
  surfaced through core/cache.py. Per-step DCN volume is unchanged (the
  reduce moves, it is not added).

  cross-step (SystemConfig.cross_step_pipeline, scheduler stream 3,
  rides the async stream): the once-per-step optimizer tail -- the LAST
  microbatch's pod-axis reduce-scatter, the optimizer apply, and the
  widened updated-shard all-gather -- is carried across the step
  boundary instead of serializing at the end of the step. The step
  function splits into three compiled bodies sharing one closure:

    prime(params, frozen, opt, batch)        -> (carry, metrics)
    piped(params, frozen, opt, carry, batch) -> (params', opt', carry',
                                                 metrics)
    flush(params, opt, carry)                -> (params', opt', metrics)

  ``carry`` holds step i's accumulated storage-level grads plus the last
  microbatch's stage-1-level pending grads (the stream-2 fold,
  generalized to the step level). ``piped`` finalizes the carry at its
  TOP -- pod reduce + grad_sync + widen reduce-scatter + clip + AdamW +
  widened all-gather -- and runs its own microbatch loop against the
  UPDATED parameters, so the schedule is staleness-free: the epilogue
  collectives merely sit next to step i+1's first-microbatch forward
  prologue in one program, where XLA's latency-hiding scheduler overlaps
  them (they have no data dependency on the batch). Per-step DCN volume
  is byte-identical to the fused step: prime defers one reduce-scatter +
  one epilogue, every piped step retires exactly one while deferring its
  own, flush retires the last. Carry leaves cross the jit boundary with
  a leading 'partial' dimension sharded over every mesh axis their
  payload spec does not mention, so the pre-reduction partial sums are
  honestly typed (each device row holds its own partial; per-chip bytes
  are one shard -- core/schedule.py:cross_step_buffer_bytes is the
  analytic cost).
"""
from __future__ import annotations

import math
from types import SimpleNamespace

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import (HAS_VMA, all_gather_invariant, pvary, shard_map,
                          typeof)
from repro.core import schedule as sched
from repro.core.strategy import spec_axes
from repro.optim.adamw import adamw_update, clip_by_global_norm


def _entry_axes(spec: P, dim) -> tuple:
    """Mesh axes a PartitionSpec shards dimension ``dim`` over."""
    if dim is None or dim >= len(spec):
        return ()
    e = spec[dim]
    if e is None:
        return ()
    return tuple(e) if isinstance(e, (tuple, list)) else (e,)


# ---------------------------------------------------------------------------
# Cross-step carry layout (consumed by StepBundle for the dry-run sds)
# ---------------------------------------------------------------------------

def _stage1_storage_spec(spec: P, pdef, plan) -> P:
    """Storage-level PartitionSpec of the stage-1-gathered view of one
    leaf: the inter (DCN) axes stripped from the fsdp-dim entry. The
    identity for single-stage leaves."""
    if pdef.fsdp_dim is None or not (plan.is_gathered and plan.inter_axes):
        return spec
    entries = list(spec) + [None] * (len(pdef.shape) - len(spec))
    e = entries[pdef.fsdp_dim]
    axes = tuple(a for a in ((e,) if isinstance(e, str) else tuple(e or ()))
                 if a not in plan.inter_axes)
    entries[pdef.fsdp_dim] = (axes if len(axes) > 1
                              else (axes[0] if axes else None))
    return P(*entries)


def _carried_spec(base: P, pdef, mi):
    """(full_spec, global_shape) of one carry leaf: the payload spec
    plus a leading 'partial' dim sharded over every mesh axis the
    payload does not mention. Pre-reduction gradients genuinely differ
    along those axes (partial sums awaiting their psum), so the leading
    dim makes the global array honest -- each device row holds its own
    partial -- while per-chip storage stays one shard."""
    names = tuple(mi.axis_names)
    lead = tuple(a for a in names if a not in spec_axes(base))
    entries = list(base) + [None] * (len(pdef.shape) - len(base))
    full = P(lead if len(lead) > 1 else (lead[0] if lead else None),
             *entries)
    shape = (max(1, math.prod(mi.size(a) for a in lead)),) + tuple(pdef.shape)
    return full, shape


def cross_step_carry_layout(bundle):
    """Per-train-leaf carry layout for the cross-step pipeline:
    ``{"g_acc": [(spec, global_shape, dtype), ...], "pending": [...]}``.
    ``g_acc`` leaves are storage-level accumulated gradients, ``pending``
    leaves are stage-1-level last-microbatch gradients (the deferred pod
    reduce operand)."""
    out = {"g_acc": [], "pending": []}
    for i in bundle.train_idx:
        d = bundle.def_leaves[i]
        plan = bundle.plan_leaves[i]
        spec = bundle.leaf_specs[i]
        for key, base in (("g_acc", spec),
                          ("pending", _stage1_storage_spec(spec, d, plan))):
            full, shape = _carried_spec(base, d, bundle.mi)
            out[key].append((full, shape, d.dtype))
    return out


def cross_step_carry_signature(bundle):
    """``[(global_shape, dtype_str), ...]`` of the carry leaves in
    checkpoint flatten order (the ``carry`` dict's keys sort g_acc before
    pending) -- what ``runtime/elastic.reshard_state`` compares against a
    saved manifest's carry section to decide mesh-compatibility. The
    leading partial dim is mesh-shaped (the product of the unmentioned
    axes' sizes), so a mesh change shows up here even when the payload
    shapes agree; a carry that fails this check must be invalidated and
    re-primed, never ``device_put`` as stale partials."""
    layout = cross_step_carry_layout(bundle)
    return [(tuple(shape), str(jnp.dtype(dtype)))
            for key in sorted(layout)
            for _, shape, dtype in layout[key]]


def _lift(x, axes):
    """pvary ``x`` over whichever of ``axes`` its vma is missing (no-op
    on pre-VMA JAX): carry outputs must vary over every axis their out
    spec mentions."""
    have = set(getattr(typeof(x), "vma", ()) or ())
    need = tuple(a for a in axes if a not in have)
    return pvary(x, need) if need else x


# ---------------------------------------------------------------------------
# Shared step-body parts
# ---------------------------------------------------------------------------

def _build_parts(bundle):
    run, mesh, mi = bundle.run, bundle.mesh, bundle.mi
    sys, opt_cfg = run.system, run.optimizer
    strategy = bundle.strategy
    model = bundle.model
    train_defs = [bundle.def_leaves[i] for i in bundle.train_idx]
    train_plans = [bundle.plan_leaves[i] for i in bundle.train_idx]
    frozen_defs = [bundle.def_leaves[i] for i in bundle.frozen_idx]
    frozen_plans = [bundle.plan_leaves[i] for i in bundle.frozen_idx]
    train_reps = [bundle.rep_factors[i] for i in bundle.train_idx]
    wd_mask = [len(d.shape) >= 2 and "_lora_" not in d.label
               for d in train_defs]
    dp_axes = mi.fsdp_axes
    tp_present = mi.tp > 1
    cell = run.shape
    bspecs = bundle.batch_spec(cell)
    # Optimizer state wider than param storage (ZeRO-2-for-experts,
    # hier's ('data','pod') opt sharding): grads get a reduce-scatter
    # over the widening axes before the update, updated shards get one
    # all-gather back per step.
    widen = {}
    for j, i in enumerate(bundle.train_idx):
        d = bundle.def_leaves[i]
        extra = tuple(
            a for a in _entry_axes(bundle.full_specs[i], d.fsdp_dim)
            if a not in _entry_axes(bundle.leaf_specs[i], d.fsdp_dim))
        if extra:
            widen[j] = (d.fsdp_dim, extra)

    # Pre-VMA JAX: shard_map's AD does not auto-insert the cross-axis
    # reductions for grads of params stored REPLICATED over some mesh
    # axes (pod-replicated MiCS/hier/frozen layouts, model-replicated
    # kv/norm weights, min_shard_size-replicated tensors) -- each device
    # would keep only its local partial. Current JAX's varying-mesh-axis
    # type system inserts these psums automatically (transpose of the
    # implicit pvary), so the explicit sum is gated on HAS_VMA. The
    # gather transposes already reduce over the axes present in the
    # storage spec; widened leaves' sum over the widening axes is
    # handled by the rs_widen reduce-scatter instead.
    grad_sync = {}
    if not HAS_VMA:
        for j, i in enumerate(bundle.train_idx):
            waxes = widen.get(j, (None, ()))[1]
            missing = tuple(a for a in mi.axis_names
                            if a not in spec_axes(bundle.leaf_specs[i])
                            and a not in waxes)
            if missing:
                grad_sync[j] = missing

    def rs_widen(g, dim, axes):
        return jax.lax.psum_scatter(g, axes, scatter_dimension=dim,
                                    tiled=True)

    def ag_widen(p_, dim, axes):
        for a in reversed(axes):   # invert the tiled multi-axis scatter
            p_ = all_gather_invariant(p_, a, axis=dim, tiled=True)
        return p_

    # -- async pod-axis gradient-reduce stream (scheduler stream 2) ---------
    use_async = sched.async_reduce_enabled(run, strategy, mi)
    use_xstep = sched.cross_step_enabled(run, strategy, mi)
    g1_model = (model.with_plans(sched.stage1_resident_plans(model.plans))
                if use_async else None)
    nm = run.microbatch or 0

    def loss_fn_of(train_params, frozen_params, batch):
        params = bundle.merge(train_params, frozen_params)
        loss_sum, cnt, aux = model.loss_fn(params, batch)
        loss_sum = jax.lax.psum(loss_sum, dp_axes) if dp_axes else loss_sum
        cnt = jax.lax.psum(cnt, dp_axes) if dp_axes else cnt
        aux = jax.lax.psum(aux, dp_axes) if dp_axes else aux
        ce = loss_sum / jnp.maximum(cnt, 1.0)
        aux_n = aux / jnp.maximum(cnt, 1.0)
        return ce + aux_n, (ce, aux_n, cnt)

    def mb_slice(x, i):
        b = x.shape[0] // nm
        return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)

    def mb_loss_of(params_builder, mdl):
        def mb_loss(tp_, mb):
            params = params_builder(tp_)
            ls, c, a = mdl.loss_fn(params, mb)
            ls = jax.lax.psum(ls, dp_axes) if dp_axes else ls
            c = jax.lax.psum(c, dp_axes) if dp_axes else c
            a = jax.lax.psum(a, dp_axes) if dp_axes else a
            ce = ls / jnp.maximum(c, 1.0)
            return ce + a / jnp.maximum(c, 1.0), ce
        return mb_loss

    def g1_of(leaves, defs_, plans_):
        return [sched.leaf_stage1(w, d, p)
                for w, d, p in zip(leaves, defs_, plans_)]

    def pod_reduce(pending):
        return [sched.leaf_stage1_reduce(g, d, p)
                for g, d, p in zip(pending, train_defs, train_plans)]

    def grad_zero(train_params):
        from repro.models.common import pvary_like
        return jax.tree.map(
            lambda p_: pvary_like(jnp.zeros_like(p_), p_), train_params)

    def accumulate_async(train_params, frozen_params, ce0, batch):
        """The stream-2 microbatch loop: differentiate each microbatch
        w.r.t. the stage-1-gathered view, reduce the PREVIOUS
        microbatch's stage-1 grads at the top of each iteration
        (microbatch 0 peeled so exactly nm-1 reduce-scatters run
        in-loop), and return the accumulated storage-level grads plus
        the last microbatch's still-pending stage-1 grads."""
        mb_loss = mb_loss_of(
            lambda tp_: bundle.merge(
                tp_, g1_of(frozen_params, frozen_defs, frozen_plans)),
            g1_model)

        def mb_grads(i):
            mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
            g1_tp = g1_of(train_params, train_defs, train_plans)
            return jax.value_and_grad(mb_loss, has_aux=True)(g1_tp, mb)

        def acc_body(carry, i):
            g_acc, pending, ce_acc = carry
            # stream 2: fold the PREVIOUS microbatch's stage-1 grads
            # down to storage shards -- a pure DCN reduce-scatter with
            # no data dependency on this microbatch's forward below, so
            # the latency-hiding scheduler overlaps the two
            g_acc = jax.tree.map(jnp.add, g_acc, pod_reduce(pending))
            (_, ce), g1_g = mb_grads(i)
            return (g_acc, g1_g, ce_acc + ce), None

        (_, ce_first), pending0 = mb_grads(0)
        (g_acc, pending, ce_sum), _ = jax.lax.scan(
            acc_body, (grad_zero(train_params), pending0, ce0 + ce_first),
            jnp.arange(1, nm))
        return g_acc, pending, ce_sum

    def accumulate_seq(train_params, frozen_params, ce0, batch):
        """Sequential accumulation: every microbatch's backward carries
        the full gather transposes (reduce inside the backward)."""
        mb_loss = mb_loss_of(
            lambda tp_: bundle.merge(tp_, frozen_params), model)

        def acc_body(carry, i):
            g_acc, ce_acc = carry
            mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
            (_, ce), g = jax.value_and_grad(
                mb_loss, has_aux=True)(train_params, mb)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, ce_acc + ce), None
        (grads, ce_sum), _ = jax.lax.scan(
            acc_body, (grad_zero(train_params), ce0), jnp.arange(nm))
        return grads, ce_sum

    def fold(g_acc, pending):
        """Retire the deferred last-microbatch reduce and normalize."""
        grads = jax.tree.map(jnp.add, g_acc, pod_reduce(pending))
        return jax.tree.map(lambda g: g / nm, grads)

    def apply_grads(grads, opt_state):
        """The optimizer epilogue: replicated-storage grad psums, widen
        reduce-scatter, global-norm clip, AdamW on shards, widened
        updated-shard all-gather. One call site per schedule so the op
        order (and therefore the bits) are identical whether the
        epilogue runs fused or carried across the step boundary."""
        if grad_sync:
            grads = [jax.lax.psum(g, grad_sync[j]) if j in grad_sync else g
                     for j, g in enumerate(grads)]
        if widen:
            grads = [rs_widen(g, *widen[j]) if j in widen else g
                     for j, g in enumerate(grads)]
        grads, gnorm = clip_by_global_norm(
            grads, train_reps, opt_cfg.grad_clip, dp_axes, tp_present)
        new_params, new_opt = adamw_update(
            grads, opt_state, opt_cfg, sys, wd_mask)
        if widen:
            new_params = [ag_widen(p_, *widen[j]) if j in widen else p_
                          for j, p_ in enumerate(new_params)]
        return new_params, new_opt, gnorm

    # derive the loss-carry zero from a replicated input rather than a
    # literal: scan requires the carry's replication type to match the
    # body output's (which is replicated over every axis after the loss
    # psums), and a bare constant carries no replication type on
    # pre-VMA JAX
    def ce_zero(opt_state):
        return (opt_state["step"] * 0).astype(jnp.float32)

    train_specs = [bundle.leaf_specs[i] for i in bundle.train_idx]
    frozen_specs = [bundle.leaf_specs[i] for i in bundle.frozen_idx]
    opt_leaf_specs = [bundle.full_specs[i] for i in bundle.train_idx]
    opt_specs = {"m": opt_leaf_specs, "v": opt_leaf_specs,
                 "master": opt_leaf_specs, "step": P()}
    metric_specs = {"loss": P(), "aux_loss": P(), "grad_norm": P(),
                    "tokens": P()}

    return SimpleNamespace(
        mesh=mesh, nm=nm, use_async=use_async, use_xstep=use_xstep,
        loss_fn_of=loss_fn_of, accumulate_async=accumulate_async,
        accumulate_seq=accumulate_seq, fold=fold, apply_grads=apply_grads,
        ce_zero=ce_zero, train_specs=train_specs,
        frozen_specs=frozen_specs, opt_specs=opt_specs, bspecs=bspecs,
        metric_specs=metric_specs)


# ---------------------------------------------------------------------------
# Cross-step carry pack/unpack
# ---------------------------------------------------------------------------

def _carry_io(bundle):
    layout = cross_step_carry_layout(bundle)
    specs = {k: [s for s, _, _ in v] for k, v in layout.items()}
    mention = {k: [tuple(sorted(spec_axes(s))) for s, _, _ in v]
               for k, v in layout.items()}

    def pack(g_acc, pending):
        return {"g_acc": [_lift(g, mention["g_acc"][j])[None]
                          for j, g in enumerate(g_acc)],
                "pending": [_lift(g, mention["pending"][j])[None]
                            for j, g in enumerate(pending)]}

    def unpack(carry):
        return ([x[0] for x in carry["g_acc"]],
                [x[0] for x in carry["pending"]])

    return specs, pack, unpack


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def build_train_step(bundle):
    """The steady-state train step for this bundle's schedule: the fused
    step (``(params, frozen, opt, batch) -> (params', opt', metrics)``)
    normally, the cross-step pipelined step (extra carry in/out, see the
    module docstring) when stream 3 is live -- StepBundle.train_input_sds
    tracks the signature, so dry-run/planner/bench lowering is uniform."""
    c = _build_parts(bundle)
    if c.use_xstep:
        return _build_piped(bundle, c)
    return _build_fused(bundle, c)


def _build_fused(bundle, c):
    def step_body(train_params, frozen_params, opt_state, batch):
        if c.nm > 1:
            ce0 = c.ce_zero(opt_state)
            if c.use_async:
                g_acc, pending, ce_sum = c.accumulate_async(
                    train_params, frozen_params, ce0, batch)
                # epilogue: the last microbatch's reduce has nothing
                # left to hide behind inside this step
                grads = c.fold(g_acc, pending)
            else:
                grads, ce_sum = c.accumulate_seq(
                    train_params, frozen_params, ce0, batch)
                grads = jax.tree.map(lambda g: g / c.nm, grads)
            ce, auxl, cnt = ce_sum / c.nm, jnp.float32(0), jnp.float32(1)
        else:
            (_, (ce, auxl, cnt)), grads = jax.value_and_grad(
                c.loss_fn_of, has_aux=True)(train_params, frozen_params,
                                            batch)
        new_params, new_opt, gnorm = c.apply_grads(grads, opt_state)
        metrics = {"loss": ce, "aux_loss": auxl, "grad_norm": gnorm,
                   "tokens": cnt}
        return new_params, new_opt, metrics

    fn = shard_map(
        step_body, mesh=c.mesh,
        in_specs=(c.train_specs, c.frozen_specs, c.opt_specs, c.bspecs),
        out_specs=(c.train_specs, c.opt_specs, c.metric_specs),
        check_vma=True)
    return jax.jit(fn, donate_argnums=(0, 2))


def _build_piped(bundle, c):
    """Steady-state cross-step body: finalize the carried epilogue of
    step i-1 (producing the updated params this step's forward
    consumes), then run this step's microbatch loop and emit the next
    carry. The epilogue collectives at the top have no data dependency
    on the batch, so they overlap the first microbatch's forward
    prologue under the latency-hiding scheduler."""
    carry_specs, pack, unpack = _carry_io(bundle)

    def step_body(train_params, frozen_params, opt_state, carry, batch):
        g_acc, pending = unpack(carry)
        new_params, new_opt, gnorm = c.apply_grads(
            c.fold(g_acc, pending), opt_state)
        g_acc2, pending2, ce_sum = c.accumulate_async(
            new_params, frozen_params, c.ce_zero(new_opt), batch)
        metrics = {"loss": ce_sum / c.nm, "aux_loss": jnp.float32(0),
                   "grad_norm": gnorm, "tokens": jnp.float32(1)}
        return new_params, new_opt, pack(g_acc2, pending2), metrics

    fn = shard_map(
        step_body, mesh=c.mesh,
        in_specs=(c.train_specs, c.frozen_specs, c.opt_specs, carry_specs,
                  c.bspecs),
        out_specs=(c.train_specs, c.opt_specs, carry_specs, c.metric_specs),
        check_vma=True)
    return jax.jit(fn, donate_argnums=(0, 2, 3))


def build_train_prime(bundle):
    """Pipeline-fill step: run the microbatch loop against the CURRENT
    parameters and defer the whole epilogue into the first carry.
    Parameters and optimizer state are left untouched (the caller keeps
    them for the first piped step); grad_norm is reported as 0 until the
    first finalize computes it."""
    c = _build_parts(bundle)
    if not c.use_xstep:
        raise ValueError("cross-step pipeline is not live for this run "
                         "(see core/schedule.py:cross_step_enabled)")
    carry_specs, pack, _ = _carry_io(bundle)

    def step_body(train_params, frozen_params, opt_state, batch):
        g_acc, pending, ce_sum = c.accumulate_async(
            train_params, frozen_params, c.ce_zero(opt_state), batch)
        metrics = {"loss": ce_sum / c.nm, "aux_loss": jnp.float32(0),
                   "grad_norm": jnp.float32(0), "tokens": jnp.float32(1)}
        return pack(g_acc, pending), metrics

    fn = shard_map(
        step_body, mesh=c.mesh,
        in_specs=(c.train_specs, c.frozen_specs, c.opt_specs, c.bspecs),
        out_specs=(carry_specs, c.metric_specs),
        check_vma=True)
    return jax.jit(fn)


def build_train_flush(bundle):
    """Pipeline-drain step: finalize the outstanding carry (the last
    step's epilogue) with no forward attached. Run once at the end of
    training and before any checkpoint save, so persisted state is
    always post-update."""
    c = _build_parts(bundle)
    if not c.use_xstep:
        raise ValueError("cross-step pipeline is not live for this run "
                         "(see core/schedule.py:cross_step_enabled)")
    carry_specs, _, unpack = _carry_io(bundle)

    def step_body(train_params, opt_state, carry):
        g_acc, pending = unpack(carry)
        new_params, new_opt, gnorm = c.apply_grads(
            c.fold(g_acc, pending), opt_state)
        return new_params, new_opt, {"grad_norm": gnorm}

    fn = shard_map(
        step_body, mesh=c.mesh,
        in_specs=(c.train_specs, c.opt_specs, carry_specs),
        out_specs=(c.train_specs, c.opt_specs, {"grad_norm": P()}),
        check_vma=True)
    return jax.jit(fn, donate_argnums=(0, 1, 2))
