"""Train-step builder: shard_map orchestration, gradient flow
(reduce-scatter via gather transposes), and optimizer application on
ZeRO shards. Consumes a StepBundle whose strategy already fixed the
storage layout and gather schedule.

Everything here is PER LEAF, so per-tensor mixed sharding
(CompositeStrategy) needs no special casing: the opt-widening
reduce-scatter/all-gather pair fires for exactly the leaves whose opt
spec is wider than their storage spec (hier embeddings, ZeRO-2-for-
experts), the pre-VMA gradient psums cover exactly the leaves stored
replicated over some axes (mics/hier groups, frozen layouts), and the
async reduce stream defers exactly the leaves with a non-empty stage 1
(the streaming groups) -- single-stage groups' reduces pass through
untouched.

Two gradient-reduce schedules exist on the accumulation path:

  sequential (default): each microbatch's backward contains the full
  gather transposes, so the pod-axis reduce-scatter serializes after
  every backward.

  async (SystemConfig.async_grad_reduce, strategy-gated): the scheduler's
  second stream. Each microbatch is differentiated with respect to the
  STAGE-1-GATHERED parameter view (core/schedule.py:
  stage1_resident_plans), so its backward stops at stage-1-level
  gradients with intra-pod reduces only; the pod-axis reduce-scatter of
  microbatch i then runs at the top of iteration i+1, where it has no
  data dependency on microbatch i+1's forward and overlaps with it.
  Memory trade: the stage-1-gathered param view is materialized at leaf
  level for the whole model (instead of per layer inside the scan) and
  one stage-1-sized gradient buffer rides the scan carry --
  core/schedule.py:async_buffer_bytes is the analytic per-chip cost,
  surfaced through core/cache.py. Per-step DCN volume is unchanged (the
  reduce moves, it is not added).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import HAS_VMA, all_gather_invariant, shard_map
from repro.core import schedule as sched
from repro.core.strategy import spec_axes
from repro.optim.adamw import adamw_update, clip_by_global_norm


def _entry_axes(spec: P, dim) -> tuple:
    """Mesh axes a PartitionSpec shards dimension ``dim`` over."""
    if dim is None or dim >= len(spec):
        return ()
    e = spec[dim]
    if e is None:
        return ()
    return tuple(e) if isinstance(e, (tuple, list)) else (e,)


def build_train_step(bundle):
    run, mesh, mi = bundle.run, bundle.mesh, bundle.mi
    sys, opt_cfg = run.system, run.optimizer
    strategy = bundle.strategy
    model = bundle.model
    train_defs = [bundle.def_leaves[i] for i in bundle.train_idx]
    train_plans = [bundle.plan_leaves[i] for i in bundle.train_idx]
    frozen_defs = [bundle.def_leaves[i] for i in bundle.frozen_idx]
    frozen_plans = [bundle.plan_leaves[i] for i in bundle.frozen_idx]
    train_reps = [bundle.rep_factors[i] for i in bundle.train_idx]
    wd_mask = [len(d.shape) >= 2 and "_lora_" not in d.label
               for d in train_defs]
    dp_axes = mi.fsdp_axes
    tp_present = mi.tp > 1
    cell = run.shape
    bspecs = bundle.batch_spec(cell)
    # Optimizer state wider than param storage (ZeRO-2-for-experts,
    # hier's ('data','pod') opt sharding): grads get a reduce-scatter
    # over the widening axes before the update, updated shards get one
    # all-gather back per step.
    widen = {}
    for j, i in enumerate(bundle.train_idx):
        d = bundle.def_leaves[i]
        extra = tuple(
            a for a in _entry_axes(bundle.full_specs[i], d.fsdp_dim)
            if a not in _entry_axes(bundle.leaf_specs[i], d.fsdp_dim))
        if extra:
            widen[j] = (d.fsdp_dim, extra)

    # Pre-VMA JAX: shard_map's AD does not auto-insert the cross-axis
    # reductions for grads of params stored REPLICATED over some mesh
    # axes (pod-replicated MiCS/hier/frozen layouts, model-replicated
    # kv/norm weights, min_shard_size-replicated tensors) -- each device
    # would keep only its local partial. Current JAX's varying-mesh-axis
    # type system inserts these psums automatically (transpose of the
    # implicit pvary), so the explicit sum is gated on HAS_VMA. The
    # gather transposes already reduce over the axes present in the
    # storage spec; widened leaves' sum over the widening axes is
    # handled by the rs_widen reduce-scatter instead.
    grad_sync = {}
    if not HAS_VMA:
        for j, i in enumerate(bundle.train_idx):
            waxes = widen.get(j, (None, ()))[1]
            missing = tuple(a for a in mi.axis_names
                            if a not in spec_axes(bundle.leaf_specs[i])
                            and a not in waxes)
            if missing:
                grad_sync[j] = missing

    def rs_widen(g, dim, axes):
        return jax.lax.psum_scatter(g, axes, scatter_dimension=dim,
                                    tiled=True)

    def ag_widen(p_, dim, axes):
        for a in reversed(axes):   # invert the tiled multi-axis scatter
            p_ = all_gather_invariant(p_, a, axis=dim, tiled=True)
        return p_

    # -- async pod-axis gradient-reduce stream (scheduler stream 2) ---------
    use_async = sched.async_reduce_enabled(run, strategy, mi)
    if use_async:
        g1_model = model.with_plans(
            sched.stage1_resident_plans(model.plans))

    def step_body(train_params, frozen_params, opt_state, batch):
        def loss_fn(train_params):
            params = bundle.merge(train_params, frozen_params)
            loss_sum, cnt, aux = model.loss_fn(params, batch)
            loss_sum = jax.lax.psum(loss_sum, dp_axes) if dp_axes else loss_sum
            cnt = jax.lax.psum(cnt, dp_axes) if dp_axes else cnt
            aux = jax.lax.psum(aux, dp_axes) if dp_axes else aux
            ce = loss_sum / jnp.maximum(cnt, 1.0)
            aux_n = aux / jnp.maximum(cnt, 1.0)
            return ce + aux_n, (ce, aux_n, cnt)

        if run.microbatch and run.microbatch > 1:
            # gradient accumulation over microbatches
            nm = run.microbatch
            def mb_slice(x, i):
                b = x.shape[0] // nm
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)
            from repro.models.common import pvary_like
            g0 = jax.tree.map(
                lambda p_: pvary_like(jnp.zeros_like(p_), p_),
                train_params)
            # derive the loss-carry zero from a replicated input rather
            # than a literal: scan requires the carry's replication type
            # to match the body output's (which is replicated over every
            # axis after the loss psums), and a bare constant carries no
            # replication type on pre-VMA JAX
            ce0 = (opt_state["step"] * 0).astype(jnp.float32)

            def mb_loss_of(params_builder, mdl):
                def mb_loss(tp_, mb):
                    params = params_builder(tp_)
                    ls, c, a = mdl.loss_fn(params, mb)
                    ls = jax.lax.psum(ls, dp_axes) if dp_axes else ls
                    c = jax.lax.psum(c, dp_axes) if dp_axes else c
                    a = jax.lax.psum(a, dp_axes) if dp_axes else a
                    ce = ls / jnp.maximum(c, 1.0)
                    return ce + a / jnp.maximum(c, 1.0), ce
                return mb_loss

            if use_async:
                # microbatch i's pod-axis reduce-scatter runs at the top
                # of iteration i+1, concurrently with that iteration's
                # forward: differentiate w.r.t. the stage-1-gathered
                # param view so the backward stops at stage-1-level
                # grads (intra reduces only), and carry them one step.
                # Microbatch 0 is peeled so exactly nm reduce-scatters
                # run per step (same DCN volume as the sequential path).
                def g1_of(leaves, defs_, plans_):
                    return [sched.leaf_stage1(w, d, p)
                            for w, d, p in zip(leaves, defs_, plans_)]

                def pod_reduce(pending):
                    return [sched.leaf_stage1_reduce(g, d, p)
                            for g, d, p in zip(pending, train_defs,
                                               train_plans)]

                mb_loss = mb_loss_of(
                    lambda tp_: bundle.merge(
                        tp_, g1_of(frozen_params, frozen_defs,
                                   frozen_plans)), g1_model)

                def mb_grads(i):
                    mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
                    g1_tp = g1_of(train_params, train_defs, train_plans)
                    return jax.value_and_grad(
                        mb_loss, has_aux=True)(g1_tp, mb)

                def acc_body(carry, i):
                    g_acc, pending, ce_acc = carry
                    # stream 2: fold the PREVIOUS microbatch's stage-1
                    # grads down to storage shards -- a pure DCN
                    # reduce-scatter with no data dependency on this
                    # microbatch's forward below, so the latency-hiding
                    # scheduler overlaps the two
                    g_acc = jax.tree.map(jnp.add, g_acc,
                                         pod_reduce(pending))
                    (_, ce), g1_g = mb_grads(i)
                    return (g_acc, g1_g, ce_acc + ce), None

                (_, ce_first), pending0 = mb_grads(0)
                (g_acc, pending, ce_sum), _ = jax.lax.scan(
                    acc_body, (g0, pending0, ce0 + ce_first),
                    jnp.arange(1, nm))
                # epilogue: the last microbatch's reduce has nothing
                # left to hide behind
                grads = jax.tree.map(jnp.add, g_acc, pod_reduce(pending))
            else:
                mb_loss = mb_loss_of(
                    lambda tp_: bundle.merge(tp_, frozen_params), model)

                def acc_body(carry, i):
                    g_acc, ce_acc = carry
                    mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
                    (_, ce), g = jax.value_and_grad(
                        mb_loss, has_aux=True)(train_params, mb)
                    g_acc = jax.tree.map(jnp.add, g_acc, g)
                    return (g_acc, ce_acc + ce), None
                (grads, ce_sum), _ = jax.lax.scan(
                    acc_body, (g0, ce0), jnp.arange(nm))
            grads = jax.tree.map(lambda g: g / nm, grads)
            ce, auxl, cnt = ce_sum / nm, jnp.float32(0), jnp.float32(1)
        else:
            (_, (ce, auxl, cnt)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(train_params)

        if grad_sync:
            grads = [jax.lax.psum(g, grad_sync[j]) if j in grad_sync else g
                     for j, g in enumerate(grads)]
        if widen:
            grads = [rs_widen(g, *widen[j]) if j in widen else g
                     for j, g in enumerate(grads)]
        grads, gnorm = clip_by_global_norm(
            grads, train_reps, opt_cfg.grad_clip, dp_axes, tp_present)
        new_params, new_opt = adamw_update(
            grads, opt_state, opt_cfg, sys, wd_mask)
        if widen:
            new_params = [ag_widen(p_, *widen[j]) if j in widen else p_
                          for j, p_ in enumerate(new_params)]
        metrics = {"loss": ce, "aux_loss": auxl, "grad_norm": gnorm,
                   "tokens": cnt}
        return new_params, new_opt, metrics

    train_specs = [bundle.leaf_specs[i] for i in bundle.train_idx]
    frozen_specs = [bundle.leaf_specs[i] for i in bundle.frozen_idx]
    opt_leaf_specs = [bundle.full_specs[i] for i in bundle.train_idx]
    opt_specs = {"m": opt_leaf_specs, "v": opt_leaf_specs,
                 "master": opt_leaf_specs, "step": P()}
    metric_specs = {"loss": P(), "aux_loss": P(), "grad_norm": P(),
                    "tokens": P()}

    fn = shard_map(
        step_body, mesh=mesh,
        in_specs=(train_specs, frozen_specs, opt_specs, bspecs),
        out_specs=(train_specs, opt_specs, metric_specs),
        check_vma=True)
    return jax.jit(fn, donate_argnums=(0, 2))
