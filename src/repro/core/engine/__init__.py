"""Step-function engine: StepBundle (per-cell state) plus the train and
serve step builders, all consuming a resolved ShardingStrategy."""
from repro.core.engine.bundle import StepBundle

__all__ = ["StepBundle"]
