"""StepBundle: everything needed to lower/run one (arch x shape x system)
cell -- model + ParamDefs, the resolved ShardingStrategy, leaf specs, and
ShapeDtypeStruct builders for the dry-run. The actual step-function
bodies live in engine/train.py and engine/serve.py.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig, ShapeCell
from repro.core import peft as peft_mod
from repro.core.partition import is_def, init_params, label_tree
from repro.core.residency import split_train_indices
from repro.core.strategy import GatherPlan, resolve_strategies, spec_axes
from repro.models.common import MeshInfo
from repro.models.registry import build_model


class StepBundle:
    """Everything needed to lower/run one (arch x shape x system) cell.

    The per-leaf strategy assignment (``ParamDef.strategy`` tag >
    ``SystemConfig.mode_overrides`` rule > ``SystemConfig.mode``) is
    resolved exactly once -- at model construction, and again here only
    when the PEFT/serve classification changes the def tree -- via
    ``core.strategy.resolve_strategies``; every spec/plan derivation
    below consumes the resolved strategy object (a plain singleton for
    uniform configs, a ``CompositeStrategy`` for mixed ones).
    """

    def __init__(self, run: RunConfig, mesh, defs_fn=None):
        self.run = run
        self.mesh = mesh
        self.mi = MeshInfo.from_mesh(mesh)
        cfg, sys = run.model, run.system
        self.model = build_model(cfg, sys, mesh)
        defs = self.model.defs
        if sys.peft:
            defs = peft_mod.apply_lora(defs, cfg, sys)
        elif run.shape.kind != "train" and sys.serve_frozen:
            # serving: all weights frozen -> FCDP-Comm cached layout
            defs = peft_mod.freeze_all(defs)
        if defs_fn is not None:
            # caller-supplied def transform applied after the PEFT/serve
            # classification (bench reference arms, tests): e.g. the
            # all-trainable clone of a LoRA-injected tree
            defs = defs_fn(defs)
        if defs is not self.model.defs:
            # injected (LoRA) or reclassified (frozen) leaves: re-label
            # and re-resolve the per-leaf strategies, then rebuild plans
            defs, strategy = resolve_strategies(sys, label_tree(defs))
            self.model._defs = defs
            self.model.strategy = strategy
            self.model._plans = strategy.plan_tree(
                defs, mesh, sys.min_shard_size,
                compress_bwd=(sys.grad_compress == "int8_pod"),
                param_compress=(sys.param_compress == "int8_pod"),
                quant_impl=sys.quant_impl,
                fused_matmul=sys.fused_matmul, fused_impl=sys.fused_impl)
        self.strategy = self.model.strategy
        self.defs = self.model.defs
        self.def_leaves, self.treedef = jax.tree.flatten(
            self.defs, is_leaf=is_def)
        # GatherPlan per leaf, aligned with def_leaves (same treedef)
        self.plan_leaves = jax.tree.leaves(
            self.model.plans, is_leaf=lambda x: isinstance(x, GatherPlan))
        # the train/frozen split is a residency property (update class),
        # not something the engine re-derives from ParamDef.frozen
        self.train_idx, self.frozen_idx = split_train_indices(
            self.plan_leaves)
        self.leaf_specs = [
            self.strategy.storage_spec(d, mesh, sys.min_shard_size)
            for d in self.def_leaves]
        # Optimizer-state layout may be wider than the param layout:
        # ZeRO-2-for-experts keeps 'inter_only' (weight-resident) params
        # pod-sharded with fully sharded opt state, and the hier strategy
        # shards opt state over ('data','pod') while params stay
        # intra-pod. engine/train.py reduce-scatters grads over the
        # widening axes before the update and gathers the updated shard
        # back once per step.
        self.full_specs = [
            self.strategy.opt_spec(d, mesh, sys.min_shard_size)
            for d in self.def_leaves]
        self.rep_factors = [self._replication(s) for s in self.full_specs]

    def _replication(self, spec: P) -> float:
        used = spec_axes(spec)
        rep = 1
        for a in self.mi.axis_names:
            if a not in used:
                rep *= self.mi.size(a)
        return float(rep)

    # -- param materialization ------------------------------------------------
    def init_all_params(self, seed: int = 0) -> List[jax.Array]:
        sys = self.run.system
        vals = init_params(self.defs, seed, self.mesh, self.strategy,
                           sys.min_shard_size)
        return jax.tree.leaves(vals)

    def split(self, leaves: List[Any]) -> Tuple[List[Any], List[Any]]:
        return ([leaves[i] for i in self.train_idx],
                [leaves[i] for i in self.frozen_idx])

    def merge(self, train: List[Any], frozen: List[Any]):
        leaves: List[Any] = [None] * len(self.def_leaves)
        for i, v in zip(self.train_idx, train):
            leaves[i] = v
        for i, v in zip(self.frozen_idx, frozen):
            leaves[i] = v
        return jax.tree.unflatten(self.treedef, leaves)

    def _leaf_sds(self, idxs) -> List[jax.ShapeDtypeStruct]:
        out = []
        for i in idxs:
            d = self.def_leaves[i]
            out.append(jax.ShapeDtypeStruct(
                d.shape, d.dtype,
                sharding=NamedSharding(self.mesh, self.leaf_specs[i])))
        return out

    # -- persisted-state shardings (checkpoint/restart) ----------------------
    def state_shardings(self, with_carry: bool = False):
        """NamedSharding tree for the persisted training state
        ``{"params", "opt"(, "carry")}`` under THIS bundle's mesh -- the
        restore placement used by ``runtime/elastic.reshard_state`` and
        the restart driver. Optimizer moments/master are placed under
        the (possibly wider) opt specs; the carry section uses the
        cross-step carry layout and is only meaningful when
        ``self.cross_step`` is live."""
        train_sh = [NamedSharding(self.mesh, self.leaf_specs[i])
                    for i in self.train_idx]
        opt_sh = [NamedSharding(self.mesh, self.full_specs[i])
                  for i in self.train_idx]
        out = {"params": train_sh,
               "opt": {"m": opt_sh, "v": opt_sh, "master": opt_sh,
                       "step": NamedSharding(self.mesh, P())}}
        if with_carry:
            from repro.core.engine.train import cross_step_carry_layout
            out["carry"] = {
                k: [NamedSharding(self.mesh, spec) for spec, _, _ in v]
                for k, v in cross_step_carry_layout(self).items()}
        return out

    # -- batch specs ------------------------------------------------------
    def batch_spec(self, cell: ShapeCell) -> Dict[str, P]:
        dp = self.mi.dp
        bspec = P(self.mi.fsdp_axes) if cell.global_batch % dp == 0 else P()
        cfg = self.run.model
        out = {"ids": bspec, "labels": bspec, "mask": bspec}
        if cfg.num_encoder_layers > 0:
            out["enc_embeds"] = bspec
        return out

    def batch_sds(self, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.run.model
        B, S = cell.global_batch, cell.seq_len
        specs = self.batch_spec(cell)
        out = {
            "ids": jax.ShapeDtypeStruct(
                (B, S), jnp.int32,
                sharding=NamedSharding(self.mesh, specs["ids"])),
            "labels": jax.ShapeDtypeStruct(
                (B, S), jnp.int32,
                sharding=NamedSharding(self.mesh, specs["labels"])),
            "mask": jax.ShapeDtypeStruct(
                (B, S), jnp.bool_,
                sharding=NamedSharding(self.mesh, specs["mask"])),
        }
        if cfg.num_encoder_layers > 0:
            # audio frontend stub: precomputed frame embeddings, 1/4 length
            out["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, max(S // 4, 8), cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(self.mesh, specs["enc_embeds"]))
        return out

    # -- step builders (bodies in engine/train.py, engine/serve.py) ---------
    @property
    def cross_step(self) -> bool:
        """Whether the cross-step pipelined optimizer stream (stream 3)
        is live for this run -- the steady-state train step then takes
        and returns a step-level carry (see engine/train.py)."""
        from repro.core import schedule as sched
        return sched.cross_step_enabled(self.run, self.strategy, self.mi)

    def make_train_step(self):
        from repro.core.engine.train import build_train_step
        return build_train_step(self)

    def make_train_prime(self):
        """Pipeline-fill step for the cross-step schedule (no update)."""
        from repro.core.engine.train import build_train_prime
        return build_train_prime(self)

    def make_train_flush(self):
        """Pipeline-drain step: finalize the outstanding carry."""
        from repro.core.engine.train import build_train_flush
        return build_train_flush(self)

    def make_prefill_step(self):
        from repro.core.engine.serve import build_prefill_step
        return build_prefill_step(self)

    def make_decode_step(self, seq_sharded: bool = False):
        from repro.core.engine.serve import build_decode_step
        return build_decode_step(self, seq_sharded=seq_sharded)

    def make_paged_decode_step(self, kv):
        from repro.core.engine.serve import build_paged_decode_step
        return build_paged_decode_step(self, kv)

    def make_prefill_chunk_step(self, kv):
        from repro.core.engine.serve import build_prefill_chunk_step
        return build_prefill_chunk_step(self, kv)

    def make_greedy_pick(self):
        from repro.core.engine.serve import build_greedy_pick
        return build_greedy_pick(self)

    # -- dry-run input ShapeDtypeStructs ------------------------------------
    def train_input_sds(self):
        """ShapeDtypeStructs for lowering the train step (no allocation)."""
        sys = self.run.system
        train_sds = self._leaf_sds(self.train_idx)
        frozen_sds = self._leaf_sds(self.frozen_idx)
        od, md = jnp.dtype(sys.opt_state_dtype), jnp.dtype(sys.master_dtype)
        opt_sh = [NamedSharding(self.mesh, self.full_specs[i])
                  for i in self.train_idx]
        def with_dtype(dt):
            return [jax.ShapeDtypeStruct(s.shape, dt, sharding=sh)
                    for s, sh in zip(train_sds, opt_sh)]
        opt_sds = {"m": with_dtype(od),
                   "v": with_dtype(od),
                   "master": with_dtype(md),
                   "step": jax.ShapeDtypeStruct(
                       (), jnp.int32,
                       sharding=NamedSharding(self.mesh, P()))}
        batch_sds = self.batch_sds(self.run.shape)
        if self.cross_step:
            # the steady-state (piped) step signature carries the
            # cross-step epilogue buffers in position 3
            return (train_sds, frozen_sds, opt_sds,
                    self.cross_step_carry_sds(), batch_sds)
        return train_sds, frozen_sds, opt_sds, batch_sds

    def cross_step_carry_sds(self):
        """ShapeDtypeStructs of the cross-step carry (stream 3)."""
        from repro.core.engine.train import cross_step_carry_layout
        layout = cross_step_carry_layout(self)
        return {k: [jax.ShapeDtypeStruct(
                        shape, dtype,
                        sharding=NamedSharding(self.mesh, spec))
                    for spec, shape, dtype in v]
                for k, v in layout.items()}

    # -- serve state (derivations in engine/serve.py) ------------------------
    def _serve_batch_dims(self, cell: ShapeCell,
                          seq_sharded: bool = False) -> Tuple[int, P]:
        from repro.core.engine.serve import serve_batch_dims
        return serve_batch_dims(self, cell, seq_sharded)

    def _state_specs(self, cell: ShapeCell, seq_sharded: bool):
        from repro.core.engine.serve import state_specs
        return state_specs(self, cell, seq_sharded)

    def _abstract_state(self, cell: ShapeCell, seq_sharded: bool):
        from repro.core.engine.serve import abstract_state
        return abstract_state(self, cell, seq_sharded)

    def init_state(self, cell: ShapeCell, seq_sharded: bool = False):
        """Materialize a decode state placed per state_specs (smoke/serve)."""
        cfg = self.run.model
        kw = {}
        if cfg.num_encoder_layers > 0:
            kw["enc_len"] = max(cell.seq_len // 4, 8)
        specs = self._state_specs(cell, seq_sharded)
        shardings = jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)
        fn = jax.jit(lambda: self.model.init_decode_state(
            cell.global_batch, cell.seq_len, seq_sharded=seq_sharded, **kw),
            out_shardings=shardings)
        return fn()

    def state_sds(self, cell: ShapeCell, seq_sharded: bool):
        """ShapeDtypeStruct state tree with shardings for dry-run."""
        abstract = self._abstract_state(cell, seq_sharded)
        specs = self._state_specs(cell, seq_sharded)

        def glue(a, s):
            return jax.ShapeDtypeStruct(
                a.shape, a.dtype, sharding=NamedSharding(self.mesh, s))
        return jax.tree.map(glue, abstract, specs)

    def prefill_input_sds(self):
        """Inputs for lowering the prefill step."""
        cell = self.run.shape
        cfg = self.run.model
        params_sds = self._leaf_sds(range(len(self.def_leaves)))
        _, bspec = self._serve_batch_dims(cell)
        B, S = cell.global_batch, cell.seq_len
        ids = jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=NamedSharding(self.mesh, bspec))
        state = self.state_sds(cell, seq_sharded=False)
        if cfg.num_encoder_layers > 0:
            enc = jax.ShapeDtypeStruct(
                (B, max(S // 4, 8), cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(self.mesh, bspec))
            return params_sds, enc, ids, state
        return params_sds, ids, state

    def decode_input_sds(self, seq_sharded: bool = False):
        """Inputs for lowering one decode step."""
        cell = self.run.shape
        params_sds = self._leaf_sds(range(len(self.def_leaves)))
        _, bspec = self._serve_batch_dims(cell, seq_sharded)
        tok = jax.ShapeDtypeStruct(
            (cell.global_batch, 1), jnp.int32,
            sharding=NamedSharding(self.mesh, bspec))
        state = self.state_sds(cell, seq_sharded=seq_sharded)
        return params_sds, tok, state

    # -- paged serve state (continuous batching; core/kv_cache.py) -----------
    def init_paged_state(self, kv):
        """Materialize the paged KV pools placed per paged_state_specs."""
        from repro.core.engine.serve import (paged_pages_global,
                                             paged_state_specs)
        cell = self.run.shape
        n_pages = paged_pages_global(self, cell, kv)
        specs = paged_state_specs(self, cell, kv)
        shardings = jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)
        fn = jax.jit(lambda: self.model.init_paged_state(
            n_pages, kv.page_size), out_shardings=shardings)
        return fn()

    def paged_state_sds(self, kv):
        from repro.core.engine.serve import (abstract_paged_state,
                                             paged_state_specs)
        cell = self.run.shape
        abstract = abstract_paged_state(self, cell, kv)
        specs = paged_state_specs(self, cell, kv)

        def glue(a, s):
            return jax.ShapeDtypeStruct(
                a.shape, a.dtype, sharding=NamedSharding(self.mesh, s))
        return jax.tree.map(glue, abstract, specs)

    def paged_decode_input_sds(self, kv):
        """Inputs for lowering one paged decode step."""
        cell = self.run.shape
        params_sds = self._leaf_sds(range(len(self.def_leaves)))
        _, bspec = self._serve_batch_dims(cell)
        sh = NamedSharding(self.mesh, bspec)
        B = cell.global_batch
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=sh)
        table = jax.ShapeDtypeStruct((B, kv.max_pages_per_seq), jnp.int32,
                                     sharding=sh)
        lengths = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=sh)
        return params_sds, tok, table, lengths, self.paged_state_sds(kv)
