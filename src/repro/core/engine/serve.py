"""Serve-step builders (prefill / decode) and the decode-state
PartitionSpec derivations they share with the dry-run.

Parameter layouts arrive per leaf (``bundle.leaf_specs``), so a served
model may mix strategy groups (per-tensor mixed sharding) -- e.g.
sharded-MoE decode against mics-group expert shards while the dense
trunk serves from the fcdp frozen layout; the scan-level gather
schedule is the GatherScheduler's job either way."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import HAS_VMA, shard_map
from repro.configs.base import ShapeCell

# Serve steps are gradient-free pure forwards: replication checking is a
# purely static verification there (the rep rewrite has no numerical
# role without AD). The pre-VMA checker cannot prove the decode-state
# outputs (e.g. rwkv token-shift xprev) are model-replicated even though
# they are, so keep the check on VMA-typed JAX and drop it on the
# legacy checker.
_SERVE_CHECK = HAS_VMA


def serve_batch_dims(bundle, cell: ShapeCell,
                     seq_sharded: bool = False) -> Tuple[int, P]:
    """Batch sharding for serving. When the sequence dimension owns
    'data' (long-context), batch may only use the remaining fsdp axes."""
    mi = bundle.mi
    axes = tuple(a for a in mi.fsdp_axes
                 if not (seq_sharded and a == mi.seq_axis))
    deg = 1
    for a in axes:
        deg *= mi.size(a)
    if axes and cell.global_batch % deg == 0:
        return cell.global_batch // deg, P(axes)
    return cell.global_batch, P()


def swap_adapters(bundle, params_leaves, adapter_leaves):
    """Adapter hot-swap over one cached base model: replace ONLY the
    trainable (adapter) leaves of a served parameter set, keeping the
    frozen trunk's leaves -- and hence its residency (pod-replicated /
    host-cached, zero steady-state DCN bytes) -- untouched. The swap is
    a flat-index splice, so no base-weight gather or re-layout runs;
    only the adapters' own (DCN-crossing) leaves are new arrays.

    bundle: a PEFT StepBundle (``sys.peft=True``). params_leaves: flat
    leaf list as the serve steps consume. adapter_leaves: new values for
    the bundle's trainable leaves, in ``bundle.train_idx`` order."""
    if len(adapter_leaves) != len(bundle.train_idx):
        raise ValueError(
            f"adapter hot-swap expects {len(bundle.train_idx)} trainable "
            f"leaves, got {len(adapter_leaves)}")
    out = list(params_leaves)
    for i, v in zip(bundle.train_idx, adapter_leaves):
        out[i] = v
    return out


def build_prefill_step(bundle):
    run, mesh = bundle.run, bundle.mesh
    model = bundle.model
    cell = run.shape
    b_local, bspec = serve_batch_dims(bundle, cell)
    cfg = run.model

    if cfg.num_encoder_layers > 0:
        def body(params_leaves, enc_embeds, ids, state):
            params = jax.tree.unflatten(bundle.treedef, params_leaves)
            return model.prefill_fn(params, enc_embeds, ids, state)
    else:
        def body(params_leaves, ids, state):
            params = jax.tree.unflatten(bundle.treedef, params_leaves)
            return model.prefill_fn(params, ids, state)

    st_specs = state_specs(bundle, cell, seq_sharded=False)
    logits_spec = P(bspec[0] if len(bspec) else None, "model")
    if cfg.num_encoder_layers > 0:
        in_specs = (bundle.leaf_specs, bspec, bspec, st_specs)
    else:
        in_specs = (bundle.leaf_specs, bspec, st_specs)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=(logits_spec, st_specs),
                   check_vma=_SERVE_CHECK)
    return jax.jit(fn, donate_argnums=(2,) if cfg.num_encoder_layers == 0
                   else (3,))


def build_decode_step(bundle, seq_sharded: bool = False):
    run, mesh = bundle.run, bundle.mesh
    model = bundle.model
    cell = run.shape
    b_local, bspec = serve_batch_dims(bundle, cell, seq_sharded)

    def body(params_leaves, tok, state):
        params = jax.tree.unflatten(bundle.treedef, params_leaves)
        return model.decode_fn(params, tok, state,
                               seq_sharded=seq_sharded)

    st_specs = state_specs(bundle, cell, seq_sharded)
    logits_spec = P(bspec[0] if len(bspec) else None, "model")
    fn = shard_map(body, mesh=mesh,
                   in_specs=(bundle.leaf_specs, bspec, st_specs),
                   out_specs=(logits_spec, st_specs),
                   check_vma=_SERVE_CHECK)
    return jax.jit(fn, donate_argnums=(2,))


def state_specs(bundle, cell: ShapeCell, seq_sharded: bool):
    """PartitionSpec tree matching init_decode_state's structure.

    States carry GLOBAL logical shapes; these specs slice them:
      - batch dim (1, after the stack dim) over the fsdp axes
      - kv-cache seq dim over 'data' when seq_sharded (long-context)
      - TP-owned dims ('model'): rwkv heads, mamba d_inner channels
    """
    _, bspec = serve_batch_dims(bundle, cell, seq_sharded)
    batch_axes = bspec[0] if len(bspec) else None
    example = abstract_state(bundle, cell, seq_sharded)
    return _specs_for_state(bundle, example, batch_axes, seq_sharded)


def _specs_for_state(bundle, example, batch_axes, seq_sharded: bool):
    from repro.compat import flatten_with_path
    mi = bundle.mi
    paths, treedef = flatten_with_path(example)
    specs = []
    for path, arr in paths:
        keys = [str(getattr(k, "key", getattr(k, "idx", k)))
                for k in path]
        name = keys[-1]
        kind = keys[-2] if len(keys) >= 2 else ""
        nd = arr.ndim
        ent = [None] * nd
        if nd >= 2 and batch_axes is not None:
            ent[1] = batch_axes
        if kind in ("attn", "xattn") and name in ("k", "v"):
            if seq_sharded and kind == "attn":
                ent[2] = mi.seq_axis   # batch axes already exclude it
            elif kind == "attn" and nd >= 4 and mi.tp > 1:
                ent[3] = "model"       # TP-sharded kv-head slots
        elif kind == "mamba":
            if name == "conv" and nd >= 4:
                ent[3] = "model"
            elif name == "h" and nd >= 3:
                ent[2] = "model"
        elif kind == "rwkv_tm" and name == "s" and nd >= 3:
            ent[2] = "model"
        specs.append(P(*ent))
    return jax.tree.unflatten(treedef, specs)


def abstract_state(bundle, cell: ShapeCell, seq_sharded: bool):
    cfg = bundle.run.model
    kw = {}
    if cfg.num_encoder_layers > 0:
        kw["enc_len"] = max(cell.seq_len // 4, 8)
    return jax.eval_shape(
        lambda: bundle.model.init_decode_state(
            cell.global_batch, cell.seq_len, seq_sharded=seq_sharded,
            **kw))


# ===========================================================================
# Paged-KV serve path (continuous batching; see core/kv_cache.py)
# ===========================================================================

def check_paged_plan(model) -> None:
    """The paged path is gated to attention-only mixer stacks: MoE
    dispatch couples batch rows through capacity dropping (breaking
    per-request bit-identity) and the recurrent mixers (mamba/rwkv)
    have no paged state."""
    bad = sorted({k for kinds in model.plan for k in kinds
                  if k not in ("attn", "mlp")})
    if bad:
        raise ValueError(
            f"paged serving supports (attn, mlp) stacks only, plan has "
            f"{bad}; use the single-request contiguous path instead")


def paged_replicas(bundle, cell: ShapeCell) -> int:
    """Data replicas the paged pool's page dim is split over (1 when
    the batch falls back to replicated P())."""
    b_local, _ = serve_batch_dims(bundle, cell)
    return cell.global_batch // b_local


def paged_pages_global(bundle, cell: ShapeCell, kv) -> int:
    return kv.pages_per_replica * paged_replicas(bundle, cell)


def default_paged_kv(bundle, cell: ShapeCell):
    """A pool sized so every batch slot can hold one max-length
    (cell.seq_len) sequence -- the capacity-neutral default matching
    the contiguous cache's footprint, plus the scratch page."""
    from repro.core.kv_cache import PagedKVConfig
    ps = 16 if cell.seq_len % 16 == 0 else 8
    mpps = -(-cell.seq_len // ps)
    slots = cell.global_batch // paged_replicas(bundle, cell)
    return PagedKVConfig(page_size=ps,
                         pages_per_replica=1 + slots * mpps,
                         max_pages_per_seq=mpps)


def paged_state_specs(bundle, cell: ShapeCell, kv):
    """Specs for the paged pools: page dim over the batch fsdp axes,
    kv-slot dim over 'model' -- the same positional rules as the
    contiguous state (the paged leaves are named k/v under attn too)."""
    _, bspec = serve_batch_dims(bundle, cell)
    batch_axes = bspec[0] if len(bspec) else None
    example = abstract_paged_state(bundle, cell, kv)
    return _specs_for_state(bundle, example, batch_axes,
                            seq_sharded=False)


def abstract_paged_state(bundle, cell: ShapeCell, kv):
    n_pages = paged_pages_global(bundle, cell, kv)
    return jax.eval_shape(
        lambda: bundle.model.init_paged_state(n_pages, kv.page_size))


def build_paged_decode_step(bundle, kv):
    """One continuous-batching decode step: (params, tok [B,1], table
    [B,max_pages], lengths [B], pools) -> (logits [B,V], pools)."""
    run, mesh = bundle.run, bundle.mesh
    model = bundle.model
    check_paged_plan(model)
    cell = run.shape
    _, bspec = serve_batch_dims(bundle, cell)

    def body(params_leaves, tok, table, lengths, state):
        params = jax.tree.unflatten(bundle.treedef, params_leaves)
        return model.paged_decode_fn(params, tok, state, table, lengths)

    st_specs = paged_state_specs(bundle, cell, kv)
    logits_spec = P(bspec[0] if len(bspec) else None, "model")
    fn = shard_map(body, mesh=mesh,
                   in_specs=(bundle.leaf_specs, bspec, bspec, bspec,
                             st_specs),
                   out_specs=(logits_spec, st_specs),
                   check_vma=_SERVE_CHECK)
    return jax.jit(fn, donate_argnums=(4,))


def build_prefill_chunk_step(bundle, kv):
    """One chunked-prefill step: (params, ids [B,C], table, pos0 [B],
    last_idx [B], pools) -> (last-prompt-token logits [B,V], pools).
    C is whatever the caller feeds (jit caches per chunk size); rows not
    prefilling this call must carry a scratch (all-zero) table row."""
    run, mesh = bundle.run, bundle.mesh
    model = bundle.model
    check_paged_plan(model)
    cell = run.shape
    _, bspec = serve_batch_dims(bundle, cell)

    def body(params_leaves, ids, table, pos0, last_idx, state):
        params = jax.tree.unflatten(bundle.treedef, params_leaves)
        return model.paged_prefill_fn(params, ids, state, table, pos0,
                                      last_idx)

    st_specs = paged_state_specs(bundle, cell, kv)
    logits_spec = P(bspec[0] if len(bspec) else None, "model")
    fn = shard_map(body, mesh=mesh,
                   in_specs=(bundle.leaf_specs, bspec, bspec, bspec,
                             bspec, st_specs),
                   out_specs=(logits_spec, st_specs),
                   check_vma=_SERVE_CHECK)
    return jax.jit(fn, donate_argnums=(5,))


def build_greedy_pick(bundle):
    """Greedy sampler, jitted ONCE for the whole decode loop: each TP
    rank reduces its local vocab shard to one (value, index) candidate
    and only the tp candidates cross the wire -- never the full [B, V]
    logits. Tie-breaking matches jnp.argmax over the concatenated
    vocab (lowest global index wins)."""
    from repro.compat import all_gather_invariant
    mesh = bundle.mesh
    cell = bundle.run.shape
    mi = bundle.mi
    _, bspec = serve_batch_dims(bundle, cell)

    def body(logits):                       # [b_local, V_local]
        v_loc = jnp.max(logits, axis=-1)
        i_loc = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if mi.tp > 1:
            i_loc = i_loc + jax.lax.axis_index("model") * logits.shape[-1]
            vs = all_gather_invariant(v_loc[None], "model", axis=0,
                                      tiled=True)     # [tp, b_local]
            ix = all_gather_invariant(i_loc[None], "model", axis=0,
                                      tiled=True)
            r = jnp.argmax(vs, axis=0)                # lowest rank on ties
            return jnp.take_along_axis(ix, r[None, :], axis=0)[0]
        return i_loc

    logits_spec = P(bspec[0] if len(bspec) else None, "model")
    out_spec = P(bspec[0] if len(bspec) else None)
    fn = shard_map(body, mesh=mesh, in_specs=(logits_spec,),
                   out_specs=out_spec, check_vma=_SERVE_CHECK)
    return jax.jit(fn)
