"""Paged (block) KV cache for continuous-batching serve.

Layout (vLLM-style, adapted to the FCDP serve mesh):

  - Every attention position in the layer plan owns one K and one V
    *pool*: [n_pages, page_size, tp*span, hd] with GLOBAL logical
    shape, stacked over the group dim like the contiguous decode state.
    Inside shard_map the page dim is split over the batch's fsdp axes
    (each data replica holds only its own sequences' pages) and the
    kv-slot dim over 'model' (the same kv-head span the contiguous
    cache stores).
  - A per-batch-row *page table* [B, max_pages_per_seq] of LOCAL page
    ids maps absolute token positions to pool rows:
    flat_slot(pos) = table[b, pos // page_size] * page_size + pos % page_size.
  - Page 0 of every replica's pool is the reserved SCRATCH page:
    inactive batch rows keep an all-zero table row, so their (ignored)
    decode writes land in scratch and never touch live pages. Scratch
    is never read unmasked -- each row's causal mask ends at its own
    position -- so duplicate scratch writes are harmless.

Allocation is host-side and conservative: a request is admitted only
when ceil((prompt_len + max_new_tokens) / page_size) free pages exist in
its slot's replica, so an admitted sequence can never be starved
mid-decode and no preemption/swap path is needed (documented trade in
ARCHITECTURE.md; the planner shrinks pool *capacity*, which bounds
concurrency, never correctness).

The pools are a first-class MemoryPlanner tenant: see
``core/cache.py`` (``kv_page_bytes_per_chip`` accounting and
``MemoryPlanner.plan_serve``'s demotion order).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

SCRATCH_PAGE = 0


@dataclass(frozen=True)
class PagedKVConfig:
    """Static shape of the paged KV cache (per data replica).

    page_size: tokens per page.
    pages_per_replica: pool size INCLUDING the scratch page; the global
      pool page dim is pages_per_replica * n_replicas.
    max_pages_per_seq: page-table width -- bounds one sequence's
      prompt + generation to max_pages_per_seq * page_size tokens.
    """
    page_size: int = 16
    pages_per_replica: int = 64
    max_pages_per_seq: int = 8

    def __post_init__(self):
        if self.page_size <= 0:
            raise ValueError(f"page_size must be > 0, got {self.page_size}")
        if self.pages_per_replica <= 1:
            raise ValueError("pages_per_replica must leave room beyond the "
                             f"scratch page, got {self.pages_per_replica}")
        if self.max_pages_per_seq <= 0:
            raise ValueError("max_pages_per_seq must be > 0, got "
                             f"{self.max_pages_per_seq}")
        if self.pages_per_replica < 1 + self.max_pages_per_seq:
            # the planner's demotion floor: scratch + one max-length seq
            raise ValueError(
                f"pages_per_replica {self.pages_per_replica} cannot hold "
                f"the scratch page + one max-length sequence "
                f"({1 + self.max_pages_per_seq})")

    @property
    def max_seq_len(self) -> int:
        return self.max_pages_per_seq * self.page_size

    def pages_needed(self, total_len: int) -> int:
        """Pages one sequence of prompt+generation length needs."""
        return -(-total_len // self.page_size)


def kv_page_bytes_per_chip(cfg_model, mi, plan, n_groups: int,
                           kv: PagedKVConfig) -> float:
    """Analytic per-chip bytes of the paged KV pools (K+V, bf16).

    Per chip each attention position holds pages_per_replica pages of
    its LOCAL slice: span kv-head slots (the 'model' shard of tp*span)
    by head_dim, page_size tokens per page.
    """
    from repro.models.attention import kv_span
    from repro.models.common import pad_heads
    n_attn = sum(1 for kinds in plan for k in kinds if k == "attn")
    if n_attn == 0:
        return 0.0
    hd = cfg_model.resolved_head_dim()
    n_kv = cfg_model.num_kv_heads
    hp = pad_heads(cfg_model.num_heads, mi.tp)
    span = kv_span(hp // mi.tp, hp // n_kv, n_kv)
    elems = (n_groups * n_attn * kv.pages_per_replica * kv.page_size
             * span * hd)
    return float(elems * 2 * 2)          # K + V, bf16


class PageAllocator:
    """Host-side free-list for ONE replica's page pool. Page 0 (the
    scratch page) is never handed out."""

    def __init__(self, kv: PagedKVConfig):
        self.kv = kv
        # LIFO keeps recently-freed (cache-warm) pages hot; order is
        # irrelevant for correctness
        self._free: List[int] = list(range(kv.pages_per_replica - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages or None (all-or-nothing: conservative admission)."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not (0 < p < self.kv.pages_per_replica):
                raise ValueError(f"freeing invalid page id {p}")
        self._free.extend(pages)
