"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=256000 -- GeGLU, head_dim=256, MQA, tied embeddings, embed scaling.
[arXiv:2403.08295; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    d_ff=16384, vocab_size=256000, head_dim=256,
    act="geglu", qkv_bias=False, rope_theta=10000.0,
    norm_eps=1e-6, tie_embeddings=True, sub_quadratic=False)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=192, vocab_size=512, head_dim=16,
    act="geglu", tie_embeddings=True, sub_quadratic=False)
