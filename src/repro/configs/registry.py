"""Architecture registry: --arch <id> resolution for every assigned
architecture, with its full config, smoke config, and shape-cell
applicability (long_500k only for sub-quadratic archs)."""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig, SHAPE_CELLS, ShapeCell

_ARCH_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "gemma-2b": "gemma_2b",
    "granite-3-8b": "granite_3_8b",
    "yi-34b": "yi_34b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "chameleon-34b": "chameleon_34b",
    "rwkv6-3b": "rwkv6_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)


def _load(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {list(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _load(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _load(arch).SMOKE


def cell_supported(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """(supported, reason-if-skipped) for one (arch x shape) cell."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skip (pure full-attention arch; 500k decode needs sub-quadratic state)"
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """[(arch, cell_name, supported, reason)] for all 40 cells."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in SHAPE_CELLS:
            ok, why = cell_supported(cfg, cell)
            out.append((arch, cell.name, ok, why))
    return out
