"""Configuration dataclasses for the repro framework.

Everything an (arch x shape x system) cell needs is described here;
model code, partitioner, and launchers consume these frozen configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # layers that are MoE: every `moe_period` starting at `moe_offset`
    moe_period: int = 1
    moe_offset: int = 0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64   # rank of the data-dependent decay LoRA
    tokenshift: bool = True


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    act: str = "swiglu"         # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 1 << 19
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # hybrid (jamba): within each period, which positions are attention
    hybrid_period: int = 0           # 0 -> not hybrid
    hybrid_attn_positions: Tuple[int, ...] = ()
    # encdec
    num_encoder_layers: int = 0      # >0 -> encoder-decoder
    # vlm / audio frontends are stubs: inputs arrive pre-embedded
    frontend: str = "none"           # none | vq_image | audio_frames
    # which sublayer mixes tokens, decided per family in models/registry
    sub_quadratic: bool = False      # True -> supports long_500k

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_count(self) -> int:
        """Approximate total parameter count (used for roofline MODEL_FLOPS)."""
        from repro.models.registry import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""
    name: str               # train_4k | prefill_32k | decode_32k | long_500k
    kind: str               # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)


def shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(f"unknown shape cell {name!r}; have {[c.name for c in SHAPE_CELLS]}")


# remat/activation policies understood by core/fcdp.py:make_remat_policy
ACTIVATION_POLICIES = ("save_all", "block_io", "offload_acts",
                       "save_collectives")


@dataclass(frozen=True)
class SystemConfig:
    """Which distributed-training system and caching policy to use.

    mode:
      zero3   - full sharding, re-gather fwd+bwd               (paper baseline)
      zeropp  - device-cached intra shard, intra-only bwd AG   (ZeRO++ analog)
      fcdp    - host-cached intra shard, intra-only bwd AG     (the paper)
      mics    - subgroup (pod-local) sharding, no cross-pod AG (MiCS analog)
      hier    - pod-local param sharding, optimizer state sharded over
                ('data','pod') (hierarchical partitioning, Xu et al.)

    Validated at construction: device_cache_fraction must lie in [0, 1],
    activation_policy must be a known policy, prefetch_depth must be
    a non-negative int (None derives it from the legacy `prefetch`
    bool), and every mode_overrides rule must be well-formed and name a
    registered strategy. `mode` itself is validated at strategy
    resolution.
    """
    mode: str = "fcdp"
    # Per-tensor strategy overrides: ordered (path-glob, mode) rules
    # matched (fnmatch, first match wins) against the label_tree dotted
    # path of each ParamDef at StepBundle/model construction -- e.g.
    # (("blocks.*.moe.we_*", "mics"), ("embed", "hier")) keeps the dense
    # trunk on `mode` while experts ride MiCS pod-replication and the
    # embedding shards hierarchically. An explicit ParamDef.strategy tag
    # beats every rule; a rule that is the first match for zero params
    # raises at resolution. 'pattern=mode' strings are accepted and
    # canonicalized to pairs (the CLI --mode-override form).
    mode_overrides: Tuple[Tuple[str, str], ...] = ()
    # FCDP-Cache: fraction of layers allowed to keep the cached shard on
    # device (planner output; tau in the paper). 0.0 -> all host, 1.0 -> all device.
    device_cache_fraction: float = 0.0
    # Streaming gather scheduler (core/schedule.py): depth of the ring
    # buffer of in-flight stage-1 (inter/DCN) gather caches. Step i
    # issues layer i+k's stage-1 all-gather -- no data dependency on
    # layer i's compute, so XLA's latency-hiding scheduler overlaps the
    # DCN transfer -- while computing layer i from the oldest ring slot.
    # 0 = sequential schedule (the paper-faithful baseline the mode
    # comparisons are defined on); k trades k in-flight stage-1 buffers
    # (carried across the layer scan, so the backward reads them back
    # instead of re-gathering) for up to k layers' worth of DCN overlap.
    # Strategy-gated: a no-op for MiCS/hier / frozen / single-pod paths
    # where stage 1 is structurally empty. None -> derived from the
    # legacy `prefetch` bool (True -> 1).
    prefetch_depth: Optional[int] = None
    # DEPRECATED legacy alias (DeprecationWarning on use, removed next
    # release -- pass prefetch_depth): an init-only bool (True -> depth
    # 1, False -> depth 0). Because it is an InitVar,
    # dataclasses.replace() never carries it over, so a non-None value
    # here was ALWAYS passed explicitly in this construction and wins
    # over a (possibly replace-carried) prefetch_depth. Old readers
    # keep working through the read-only `prefetch` property
    # (== prefetch_depth > 0) installed below.
    prefetch: dataclasses.InitVar[Optional[bool]] = None
    # second scheduler stream (engine/train.py): on the gradient-
    # accumulation path, hold microbatch i's stage-1-level gradients for
    # one iteration and run their pod-axis reduce-scatter concurrently
    # with microbatch i+1's forward instead of serializing it inside the
    # backward. Trades one in-flight stage-1-sized gradient buffer for
    # DCN overlap; total reduce volume is unchanged. Strategy-gated
    # (needs a non-empty stage 1; MiCS/hier decline).
    async_grad_reduce: bool = False
    # third scheduler stream (engine/train.py): pipeline the once-per-step
    # optimizer epilogue -- the LAST microbatch's pod-axis reduce-scatter,
    # the optimizer apply, and the widened updated-shard all-gather --
    # across the step boundary: step i returns a carry of (accumulated
    # storage-level grads, the last microbatch's stage-1-level pending
    # grads) and step i+1 finalizes it at its top, where the epilogue
    # collectives have no data dependency on step i+1's first microbatch
    # forward prologue and overlap with it. Staleness-free: step i+1's
    # forward consumes the UPDATED parameters (the swap happens before the
    # first layer that reads them); only the collectives' latency is
    # hidden, per-step DCN volume is byte-identical. Requires
    # async_grad_reduce (the deferred pod reduce is the stream-2
    # primitive, validated here) and gradient accumulation
    # (RunConfig.microbatch >= 2, validated at RunConfig construction);
    # strategy-gated via supports_cross_step (MiCS/hier decline on their
    # own -- no stage-1 reduce to carry -- but their widened epilogue
    # collectives ride the carry when mixed with a streaming group).
    cross_step_pipeline: bool = False
    host_offload: bool = True          # False -> Saveable instead of Offloadable
    # FCDP-Comm / PEFT
    peft: bool = False
    lora_rank: int = 8
    lora_targets: Tuple[str, ...] = ("wq", "wk", "wv", "wo")
    # LoRA alpha: the adapter term is scaled by alpha/rank. None ->
    # alpha = 2*rank (scale 2.0). Single source of truth -- both the
    # analytic peft accounting and models/attention.py read the scale
    # through core.peft.lora_scale(sys).
    lora_alpha: Optional[float] = None
    # activation checkpointing: save_all (paper-faithful torch default),
    # block_io (remat layer internals), offload_acts
    activation_policy: str = "save_all"
    # beyond-paper: int8 block-quantized gradient stage over the pod axis
    grad_compress: str = "none"        # none | int8_pod
    # beyond-paper (ZeRO++ qwZ): int8 block-quantized stage-1 (pod-axis)
    # parameter all-gather -- blocks + fp32 scales on the wire,
    # dequantized on arrival so the FCDP host cache stays bf16 and the
    # backward reuse is free and full-precision
    param_compress: str = "none"       # none | int8_pod
    # implementation of the quantize/dequantize hot loops shared by
    # grad_compress / param_compress / act_psum
    quant_impl: str = "jnp"            # jnp | pallas | pallas_interpret
    # gather-fused collective matmul (kernels/collective_matmul.py):
    # consume stage-2 (intra-pod) weight chunks as the ring delivers
    # them instead of all-gathering before the first matmul.
    #   none      -- unfused (gather_stage2 then matmul)
    #   ag_matmul -- fused forward; backward replays the exact unfused
    #                op sequence, so losses/grads stay bit-identical
    #   both      -- backward ring-fused too (matmul->reduce-scatter
    #                dual; re-associates the dx sum, exact vs the
    #                kernels/ref.py oracle rather than the unfused path)
    # Eligibility is per-leaf and plan-level: see GatherPlan.fused in
    # core/strategy.py.
    fused_matmul: str = "none"         # none | ag_matmul | both
    # per-chunk matmul codepath for the fused ring
    fused_impl: str = "jnp"            # jnp | pallas | pallas_interpret
    # chunked cross-entropy (beyond-paper memory optimization)
    loss_chunk: int = 0                # 0 -> unchunked
    # param/compute dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    master_dtype: str = "float32"
    opt_state_dtype: str = "float32"
    # replicate tensors smaller than this many elements instead of ZeRO-sharding
    min_shard_size: int = 2048
    # sequence parallelism over the model axis (beyond-paper optimization)
    sequence_parallel: bool = False
    remat_scan: bool = True            # scan over layer groups
    # serving: store all weights in the FCDP-Comm frozen layout
    # (pod-replicated, intra-sharded host cache) -> zero DCN traffic/token
    serve_frozen: bool = True
    # attention implementation: jnp | pallas | pallas_interpret
    attn_impl: str = "jnp"
    # MoE dispatch token chunk (bounds the [E,C,D] buffer)
    moe_token_chunk: int = 8192
    # beyond-paper: keep expert weights resident (ZeRO over pod only) --
    # per-step gather volume >> resident size for MoE tensors
    moe_weight_resident: bool = False
    # beyond-paper: int8 transport for the large TP activation
    # all-reduces (the dominant ICI term on dense train cells)
    act_psum: str = "bf16"            # bf16 | int8
    # beyond-paper: decode-time gather-free MoE -- compute against the
    # sharded expert weights (tokens all-gathered over the shard axes,
    # partial-contraction psum) instead of gathering GBs of expert
    # weights per layer for a handful of tokens
    moe_serve_sharded: bool = False

    def __post_init__(self, prefetch):
        if self.mode_overrides:
            # canonicalize + validate (unknown strategy name / malformed
            # rule raises naming the offending rule); zero-match
            # patterns raise later, at per-leaf resolution, where the
            # ParamDef tree exists. Deferred import: the strategy
            # registry pulls in jax, which plain config construction
            # should not require.
            from repro.core.strategy import normalize_mode_overrides
            object.__setattr__(self, "mode_overrides",
                               normalize_mode_overrides(self.mode_overrides))
        if not 0.0 <= self.device_cache_fraction <= 1.0:
            raise ValueError(
                "device_cache_fraction must be in [0, 1], got "
                f"{self.device_cache_fraction!r}")
        if self.activation_policy not in ACTIVATION_POLICIES:
            raise ValueError(
                f"unknown activation_policy {self.activation_policy!r}; "
                f"known: {sorted(ACTIVATION_POLICIES)}")
        depth = self.prefetch_depth
        if prefetch is not None:
            # one-release migration path: the boolean knob is deprecated
            # in favor of the single prefetch_depth int (the launchers
            # already dropped --prefetch/--no-prefetch for
            # --prefetch-depth); next release the InitVar goes away.
            import warnings
            warnings.warn(
                "SystemConfig(prefetch=...) is deprecated; pass "
                "prefetch_depth instead (True -> 1, False -> 0). The "
                "boolean shim will be removed in the next release.",
                DeprecationWarning, stacklevel=3)
        if depth is None:                    # legacy bool shim
            depth = 1 if prefetch else 0
        elif prefetch is not None:
            # an explicit legacy bool wins over a carried depth:
            # replace(prefetch=False) must actually disable the schedule
            depth = (depth or 1) if prefetch else 0
        if not isinstance(depth, int) or isinstance(depth, bool) \
                or depth < 0:
            raise ValueError(
                f"prefetch_depth must be a non-negative int, got {depth!r}")
        object.__setattr__(self, "prefetch_depth", depth)
        for knob in ("grad_compress", "param_compress"):
            if getattr(self, knob) not in ("none", "int8_pod"):
                raise ValueError(
                    f"unknown {knob} {getattr(self, knob)!r}; "
                    "known: none, int8_pod")
        if self.quant_impl not in ("jnp", "pallas", "pallas_interpret"):
            raise ValueError(
                f"unknown quant_impl {self.quant_impl!r}; "
                "known: jnp, pallas, pallas_interpret")
        if self.fused_matmul not in ("none", "ag_matmul", "both"):
            raise ValueError(
                f"unknown fused_matmul {self.fused_matmul!r}; "
                "known: none, ag_matmul, both")
        if self.fused_impl not in ("jnp", "pallas", "pallas_interpret"):
            raise ValueError(
                f"unknown fused_impl {self.fused_impl!r}; "
                "known: jnp, pallas, pallas_interpret")
        if self.cross_step_pipeline and not self.async_grad_reduce:
            raise ValueError(
                "cross_step_pipeline=True requires async_grad_reduce=True: "
                "the carried epilogue is the stream-2 deferred pod reduce "
                "plus the optimizer apply; without the async stream there "
                "is no stage-1-level pending gradient to carry")

    def replace(self, **kw) -> "SystemConfig":
        # dataclasses.replace re-derives unspecified InitVars via
        # getattr, which would read the `prefetch` property and smuggle
        # the OLD on/off state back in (overriding e.g. an explicit
        # prefetch_depth=0). Pin it to None unless the caller passes it.
        kw.setdefault("prefetch", None)
        return dataclasses.replace(self, **kw)


# legacy read-only view of the scheduler knob (the InitVar above holds
# this class-attribute slot until we overwrite it post-decoration)
SystemConfig.prefetch = property(lambda self: self.prefetch_depth > 0)


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"     # cosine | linear | constant


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeCell
    system: SystemConfig = field(default_factory=SystemConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0
    microbatch: int = 0          # 0 -> no gradient accumulation

    def __post_init__(self):
        if self.system.cross_step_pipeline and self.microbatch < 2:
            raise ValueError(
                "cross_step_pipeline=True requires gradient accumulation "
                f"(microbatch >= 2), got microbatch={self.microbatch!r}: "
                "the carried epilogue is defined per accumulation step")

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
