"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 -- llama-arch GQA. [arXiv:2403.04652; hf]
56 heads pad to 64 for tp=16 (padded heads masked, zero-init)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    act="swiglu", qkv_bias=False, rope_theta=5_000_000.0,
    norm_eps=1e-5, sub_quadratic=False)

SMOKE = ModelConfig(
    name="yi-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=6, num_kv_heads=2,  # pad 6->8
    d_ff=128, vocab_size=512, head_dim=16,
    act="swiglu", sub_quadratic=False)
