"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 -- GQA. [hf:ibm-granite/granite-3.0-2b-base family; hf]
Vocab 49155 is padded to a multiple of tp=16 at build time."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=12800, vocab_size=49155, head_dim=128,
    act="swiglu", qkv_bias=False, rope_theta=10000.0,
    norm_eps=1e-5, sub_quadratic=False)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=515, head_dim=16,  # odd vocab exercises padding
    act="swiglu", sub_quadratic=False)
