"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 -- trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]
Optimizer-state dtype bf16 is recommended at 512 chips (EXPERIMENTS.md)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=112,
    act="swiglu", qkv_bias=False, rope_theta=50000.0,
    norm_eps=1e-5, sub_quadratic=False,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25))

SMOKE = ModelConfig(
    name="kimi-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=64, vocab_size=512, head_dim=16,
    act="swiglu", sub_quadratic=False,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64))
