"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 -- early-fusion, VQ image tokens, qk-norm.
[arXiv:2405.09818; unverified]
Early fusion: VQ image tokens share the text vocab; the VQ tokenizer
frontend is a stub -- inputs are token ids over the unified vocab."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536, head_dim=128,
    act="swiglu", qkv_bias=False, rope_theta=10000.0,
    norm_eps=1e-5, frontend="vq_image", sub_quadratic=False)

SMOKE = ModelConfig(
    name="chameleon-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    act="swiglu", frontend="vq_image", sub_quadratic=False)
