"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 -- MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]
40 heads pad to 48 for tp=16."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    act="swiglu", qkv_bias=False, rope_theta=500000.0,
    norm_eps=1e-5, sub_quadratic=False,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  capacity_factor=1.25))

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=6, num_kv_heads=2,
    d_ff=96, vocab_size=512, head_dim=16,
    act="swiglu", sub_quadratic=False,
    moe=MoEConfig(num_experts=8, top_k=1, d_ff_expert=96))
