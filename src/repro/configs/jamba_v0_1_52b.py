"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
MoE 16e top-2 -- Mamba+attn 1:7 interleave, MoE every other layer.
[arXiv:2403.19887; hf]
Period-8 groups: attention at in-group position 4, mamba elsewhere;
MoE FFN on odd positions. Supports long_500k (mamba state is O(1);
the 4 attention layers hold a sequence-sharded KV cache)."""
from repro.configs.base import ModelConfig, MambaConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    act="swiglu", qkv_bias=False, rope_theta=10000.0,
    norm_eps=1e-6, sub_quadratic=True,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                  capacity_factor=1.25, moe_period=2, moe_offset=1),
    hybrid_period=8, hybrid_attn_positions=(4,))

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16, sub_quadratic=True,
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, dt_rank=8),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                  moe_period=2, moe_offset=1),
    hybrid_period=2, hybrid_attn_positions=(0,))
