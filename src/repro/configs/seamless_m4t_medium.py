"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 -- enc-dec, multimodal. [arXiv:2308.11596; hf]
Backbone only: the audio frontend is a STUB -- input_specs() provides
precomputed frame embeddings [B, S/4, D]. 12 encoder + 12 decoder layers.
long_500k skipped (full attention enc-dec). Vocab padded to tp multiple."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    act="gelu", qkv_bias=False, norm_eps=1e-5,
    num_encoder_layers=12, frontend="audio_frames", sub_quadratic=False)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=515, head_dim=16,
    act="gelu", num_encoder_layers=2, frontend="audio_frames",
    sub_quadratic=False)
