"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 -- GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151936, head_dim=128,
    act="swiglu", qkv_bias=True, rope_theta=1_000_000.0,
    norm_eps=1e-6, sub_quadratic=False)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    act="swiglu", qkv_bias=True, sub_quadratic=False)
