"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536
-- Finch, data-dependent decay. [arXiv:2404.05892; hf]
40 heads (head_dim 64) pad to 48 for tp=16. Supports long_500k
(constant-size recurrent state)."""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=0,
    d_ff=8960, vocab_size=65536, head_dim=64,
    act="relu", norm_eps=1e-5, sub_quadratic=True,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64))

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=0,
    d_ff=128, vocab_size=512, head_dim=16, sub_quadratic=True,
    rwkv=RWKVConfig(head_dim=16, decay_lora=8))
