"""Sublayer library: ParamDefs + apply functions for every mixer/FFN kind
used by the assigned architectures.

Each sublayer kind K provides:
  K_defs(cfg)                    -> ParamDef tree (unstacked; caller stacks)
  K_apply(cfg, sys, mi, p, x, .) -> output  (train/prefill: full sequence)
  K_decode(...)                  -> (output, new_state) for one-token decode

TP conventions (see DESIGN.md §4):
  attention: q/o head-parallel over 'model' (heads padded), k/v replicated
  mlp:       in/gate column-parallel, out row-parallel (+psum)
  moe:       experts sharded over 'model' (EP), all_to_all dispatch
  mamba:     d_inner channel-parallel, B/C psum'd
  rwkv:      heads padded + head-parallel; channel-mix column-parallel
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import all_gather_invariant

from repro.configs.base import ModelConfig, SystemConfig
from repro.core.partition import ParamDef
from repro.models import attention as attn_mod
from repro.models.common import (MeshInfo, local_head_mask, pad_heads,
                                 psum_tp, psum_tp_act, tp_rank)
from repro.models import layers
from repro.models.layers import act_fn, rms_norm

BF16 = jnp.bfloat16


# ===========================================================================
# Attention
# ===========================================================================

def attn_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    hd = cfg.resolved_head_dim()
    hp = pad_heads(cfg.num_heads, tp)
    d = cfg.d_model
    kvd = cfg.num_kv_heads * hd
    out: Dict[str, ParamDef] = {
        "wq": ParamDef((d, hp * hd), ("fsdp", "tp")),
        "wk": ParamDef((d, kvd), ("fsdp", None)),
        "wv": ParamDef((d, kvd), ("fsdp", None)),
        "wo": ParamDef((hp * hd, d), ("tp", "fsdp"), fusable=True),
        "norm": ParamDef((d,), ("fsdp",), init="ones"),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDef((hp * hd,), ("tp",), init="zeros")
        out["bk"] = ParamDef((kvd,), (None,), init="zeros")
        out["bv"] = ParamDef((kvd,), (None,), init="zeros")
    if cfg.frontend == "vq_image":  # chameleon uses qk-norm
        out["q_norm"] = ParamDef((hd,), (None,), init="ones")
        out["k_norm"] = ParamDef((hd,), (None,), init="ones")
    return out


def _lora_kwargs(sys, p) -> Dict[str, Any]:
    """Adapter leaves riding in the sublayer dict + the alpha/rank scale
    (single source of truth: SystemConfig.lora_alpha via
    core.peft.lora_scale)."""
    lora = {k: v for k, v in p.items() if "_lora_" in k} or None
    if lora is None:
        return {}
    from repro.core.peft import lora_scale
    return {"lora": lora, "lora_alpha": lora_scale(sys)}


def attn_apply(cfg, sys: SystemConfig, mi: MeshInfo, p, x, positions,
               causal: bool = True, kv_cache=None, xa_kv=None):
    from repro.models.common import tp_region_in
    h = tp_region_in(rms_norm(x, p["norm"], cfg.norm_eps), mi)
    y, new_cache = attn_mod.attention_block(
        h, p["wq"], p["wk"], p["wv"], p["wo"],
        p.get("bq"), p.get("bk"), p.get("bv"),
        cfg, mi, positions, attn_impl=getattr(sys, "attn_impl", "jnp"),
        kv_cache=kv_cache,
        q_norm=p.get("q_norm"), k_norm=p.get("k_norm"),
        causal=causal, **_lora_kwargs(sys, p))
    return x + y, new_cache


def attn_init_state(cfg, mi: MeshInfo, batch: int, max_len: int,
                    seq_sharded: bool = False):
    """KV cache state with GLOBAL logical shape; sharding is applied by
    the step function's in_specs -- inside shard_map the local slice
    appears.

    Default layout: TP-sharded by kv-head span -- each 'model' rank stores
    only the kv_span(h_local, n_rep, n_kv) heads its q heads read, so the
    global kv-slot dim is tp*span (sharded over 'model'). For the
    seq-sharded long-context layout the cache keeps all kv heads and
    shards the sequence dim over 'data' instead."""
    from repro.models.attention import kv_span
    hd = cfg.resolved_head_dim()
    n_kv = cfg.num_kv_heads
    if seq_sharded:
        shape = (batch, max_len, n_kv, hd)
    else:
        hp = pad_heads(cfg.num_heads, mi.tp)
        h_local = hp // mi.tp
        n_rep = hp // n_kv
        span = kv_span(h_local, n_rep, n_kv)
        shape = (batch, max_len, mi.tp * span, hd)
    return {"k": jnp.zeros(shape, BF16), "v": jnp.zeros(shape, BF16),
            "idx": jnp.zeros((), jnp.int32)}


def attn_init_paged_state(cfg, mi: MeshInfo, n_pages: int, page_size: int):
    """Paged KV pool with GLOBAL logical shape: [n_pages, page_size,
    tp*span, hd]. The page dim is sharded over the batch's fsdp axes
    (per-replica sub-pools -- each data replica owns only its own
    sequences' pages), the slot dim over 'model' exactly like the
    contiguous cache. Page 0 of every replica is the reserved scratch
    page (see core/kv_cache.py)."""
    from repro.models.attention import kv_span
    hd = cfg.resolved_head_dim()
    n_kv = cfg.num_kv_heads
    hp = pad_heads(cfg.num_heads, mi.tp)
    h_local = hp // mi.tp
    n_rep = hp // n_kv
    span = kv_span(h_local, n_rep, n_kv)
    shape = (n_pages, page_size, mi.tp * span, hd)
    return {"k": jnp.zeros(shape, BF16), "v": jnp.zeros(shape, BF16)}


def attn_paged(cfg, sys, mi: MeshInfo, p, x, state, positions, table,
               prefill: bool = False):
    """Attention over the paged KV cache (continuous batching): one
    decode token (x: [B,1,D]) or one prefill chunk (x: [B,C,D]) per
    call. positions: [B,S] per-row absolute positions; table: [B,
    max_pages] local page ids. Mirrors attn_apply (prefill) /
    attn_decode (decode) op-for-op so per-request numerics are
    bit-identical to the single-request contiguous-cache path."""
    from repro.models.common import tp_region_in
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    if prefill:
        h = tp_region_in(h, mi)
    y, (pk, pv) = attn_mod.attention_block(
        h, p["wq"], p["wk"], p["wv"], p["wo"],
        p.get("bq"), p.get("bk"), p.get("bv"), cfg, mi, positions,
        paged_kv=(state["k"], state["v"], table),
        q_norm=p.get("q_norm"), k_norm=p.get("k_norm"),
        **_lora_kwargs(sys, p))
    return x + y, {"k": pk, "v": pv}


def attn_decode(cfg, sys, mi: MeshInfo, p, x, state, seq_sharded: bool = False):
    """One-token decode. x: [B,1,D]."""
    pos = state["idx"][None, None]  # [1,1] absolute position
    if not seq_sharded:
        kv = (state["k"], state["v"], state["idx"])
        h = rms_norm(x, p["norm"], cfg.norm_eps)
        y, (k_new, v_new, idx_new) = attn_mod.attention_block(
            h, p["wq"], p["wk"], p["wv"], p["wo"],
            p.get("bq"), p.get("bk"), p.get("bv"), cfg, mi, pos,
            kv_cache=kv, q_norm=p.get("q_norm"), k_norm=p.get("k_norm"),
            **_lora_kwargs(sys, p))
        return x + y, {"k": k_new, "v": v_new, "idx": idx_new}
    # sequence-sharded cache (long_500k): write lands on owner shard
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    B, S, D = h.shape
    hd = cfg.resolved_head_dim()
    hp = pad_heads(cfg.num_heads, mi.tp)
    h_local = hp // mi.tp
    q = (h @ p["wq"])
    if p.get("bq") is not None:
        q = q + p["bq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if p.get("bk") is not None:
        k = k + p["bk"]
    if p.get("bv") is not None:
        v = v + p["bv"]
    q = q.reshape(B, 1, h_local, hd)
    k = k.reshape(B, 1, cfg.num_kv_heads, hd)
    v = v.reshape(B, 1, cfg.num_kv_heads, hd)
    if p.get("q_norm") is not None:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = attn_mod.apply_rope_heads(q, pos, cfg.rope_theta)
    k = attn_mod.apply_rope_heads(k, pos, cfg.rope_theta)
    # write k,v into the shard that owns position idx
    S_local = state["k"].shape[1]
    shard = state["idx"] // S_local
    off = state["idx"] % S_local
    seq_ax = mi.seq_axis
    my_shard = jax.lax.axis_index(seq_ax)
    k_upd = jax.lax.dynamic_update_slice_in_dim(
        state["k"], k.astype(state["k"].dtype), off, axis=1)
    v_upd = jax.lax.dynamic_update_slice_in_dim(
        state["v"], v.astype(state["v"].dtype), off, axis=1)
    is_mine = (shard == my_shard)
    k_cache = jnp.where(is_mine, k_upd, state["k"])
    v_cache = jnp.where(is_mine, v_upd, state["v"])
    # valid length within this shard
    valid = jnp.clip((state["idx"] + 1) - my_shard * S_local, 0, S_local)
    # expand q heads to padded-global mapping handled inside:
    n_rep = hp // cfg.num_kv_heads
    k_exp, v_exp = attn_mod.slice_expand_kv(k_cache, v_cache, h_local,
                                            n_rep, mi)
    out = attn_mod.seq_sharded_decode_attention(
        q, k_exp, v_exp, valid, mi, seq_ax)
    mask = local_head_mask(mi, hp, cfg.num_heads)
    out = out * mask[None, None, :, None].astype(out.dtype)
    y = layers.matmul(out.reshape(B, 1, h_local * hd), p["wo"])
    y = psum_tp(y, mi)
    return x + y, {"k": k_cache, "v": v_cache, "idx": state["idx"] + 1}


# ===========================================================================
# Cross-attention (encoder-decoder)
# ===========================================================================

def xattn_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    d = attn_defs(cfg, tp)
    d.pop("bq", None); d.pop("bk", None); d.pop("bv", None)
    d.pop("q_norm", None); d.pop("k_norm", None)
    return d


def xattn_init_state(cfg, mi: MeshInfo, batch: int, enc_len: int):
    hd = cfg.resolved_head_dim()
    shape = (batch, enc_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, BF16), "v": jnp.zeros(shape, BF16)}


def xattn_apply(cfg, sys, mi: MeshInfo, p, x, enc_kv):
    """enc_kv: (k, v) precomputed from encoder output: [B,Senc,KVH,hd]."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim()
    hp = pad_heads(cfg.num_heads, mi.tp)
    h_local = hp // mi.tp
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, h_local, hd)
    k, v = enc_kv
    n_rep = hp // cfg.num_kv_heads
    k_exp, v_exp = attn_mod.slice_expand_kv(k, v, h_local, n_rep, mi)
    out = attn_mod.chunked_causal_attention(q, k_exp, v_exp, causal=False)
    mask = local_head_mask(mi, hp, cfg.num_heads)
    out = out * mask[None, None, :, None].astype(out.dtype)
    y = layers.matmul(out.reshape(B, S, h_local * hd), p["wo"])
    return x + psum_tp(y, mi), None


def xattn_make_kv(cfg, mi: MeshInfo, p, enc_out):
    """Project encoder output once into this cross-attn layer's K/V."""
    B, S, D = enc_out.shape
    hd = cfg.resolved_head_dim()
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    return k, v


# ===========================================================================
# Dense MLP (GLU or plain)
# ===========================================================================

def mlp_defs(cfg: ModelConfig, tp: int, d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    out = {
        "w_in": ParamDef((d, f), ("fsdp", "tp")),
        "w_out": ParamDef((f, d), ("tp", "fsdp"), fusable=True),
        "norm": ParamDef((d,), ("fsdp",), init="ones"),
    }
    if cfg.act in ("swiglu", "geglu"):
        out["w_gate"] = ParamDef((d, f), ("fsdp", "tp"))
    return out


def mlp_apply(cfg, sys, mi: MeshInfo, p, x):
    from repro.models.common import tp_region_in
    h = tp_region_in(rms_norm(x, p["norm"], cfg.norm_eps), mi)
    if "w_gate" in p:
        z = act_fn(cfg.act)(h @ p["w_gate"]) * (h @ p["w_in"])
    else:
        z = act_fn(cfg.act)(h @ p["w_in"])
    y = layers.matmul(z, p["w_out"])
    return x + psum_tp_act(y, mi)


# ===========================================================================
# MoE (GShard-style capacity dispatch, EP over 'model')
# ===========================================================================

def moe_defs(cfg: ModelConfig, tp: int,
             weight_resident: bool = False) -> Dict[str, ParamDef]:
    """Expert weights: EP over 'model'; ZeRO over (pod,data) by default.

    weight_resident (beyond-paper): per-step expert-weight gather volume
    (E_local*3*d*fe bytes per layer, fwd+bwd) usually exceeds the resident
    size by 10x+ at decode/small-batch shapes, so ZeRO-shard them over the
    pod axis only and keep the intra-pod shard resident in HBM.
    """
    m = cfg.moe
    d, fe, e = cfg.d_model, m.d_ff_expert, m.num_experts
    scope = "inter_only" if weight_resident else "full"
    out = {
        "router": ParamDef((d, e), ("fsdp", None), init_scale=0.1),
        "we_in": ParamDef((e, d, fe), ("tp", "fsdp", None), fsdp_scope=scope),
        "we_gate": ParamDef((e, d, fe), ("tp", "fsdp", None),
                            fsdp_scope=scope),
        "we_out": ParamDef((e, fe, d), ("tp", None, "fsdp"),
                           fsdp_scope=scope),
        "norm": ParamDef((cfg.d_model,), ("fsdp",), init="ones"),
    }
    return out


def _dispatch_indices(eid_flat, num_experts: int, capacity: int):
    """Position of each (token,slot) within its expert's capacity buffer."""
    n = eid_flat.shape[0]
    order = jnp.argsort(eid_flat, stable=True)
    sorted_e = eid_flat[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < capacity
    return pos, keep


def _moe_chunk(x_flat, p, cfg, mi: MeshInfo, capacity: int):
    """x_flat: [T, D] tokens; returns ([T, D], aux_loss_sum)."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    T, D = x_flat.shape
    logits = (x_flat @ p["router"]).astype(jnp.float32)       # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eid = jax.lax.top_k(probs, k)                  # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    eid_flat = eid.reshape(-1)                                # [T*k]
    pos, keep = _dispatch_indices(eid_flat, E, capacity)
    # scatter tokens into [E+1, C, D]; dropped slots go to the dummy row
    e_idx = jnp.where(keep, eid_flat, E)
    x_slots = jnp.repeat(x_flat, k, axis=0)                   # [T*k, D]
    buf = jnp.zeros((E + 1, capacity, D), x_flat.dtype)
    buf = buf.at[e_idx, jnp.where(keep, pos, 0)].set(
        jnp.where(keep[:, None], x_slots, 0))
    buf = buf[:E]                                             # [E, C, D]
    # EP all_to_all over 'model': [E, C, D] -> [E_local, tp*C, D]
    if mi.tp >= 1:
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)
    h = jnp.einsum("ecd,edf->ecf", buf, p["we_in"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["we_gate"])
    z = act_fn(cfg.act)(g) * h
    y = jnp.einsum("ecf,efd->ecd", z, p["we_out"])
    if mi.tp >= 1:
        y = jax.lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                               tiled=True)                    # [E, C, D]
    # combine
    gathered = y[jnp.where(keep, eid_flat, 0), jnp.where(keep, pos, 0)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    out = jnp.sum(gathered.reshape(T, k, D)
                  * gate_vals[..., None].astype(y.dtype), axis=1)
    # load-balance aux loss (GShard): E * sum_e f_e * p_e
    ones = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], eid].set(1.0)
    f_e = jnp.mean(ones, axis=0) / k
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e) * T                          # sum-scaled
    return out, aux


def _moe_chunk_sharded(x_flat, p, cfg, mi: MeshInfo, capacity: int,
                       we_plans=None):
    """Gather-free expert compute for decode: expert weights stay in
    their sharded storage (fsdp axes on the d_model dims); the (tiny)
    token buffers are all-gathered over those axes instead, partials are
    contraction-psum'd, and each rank keeps its own token block. Moves
    MBs of activations instead of GBs of weights per layer.

    p carries raw we_* shards plus their GatherPlans under '_we_plans'.
    """
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    T, D = x_flat.shape
    plans = we_plans
    waxes = tuple(plans["we_in"].inter_axes) + tuple(plans["we_in"].intra_axes)
    # single shard axis only (the frozen serving layout: intra=('data',));
    # multi-axis would need spec-major block ordering in the reassembly
    assert len(waxes) <= 1, f"sharded MoE compute expects <=1 axis, {waxes}"
    n_w = 1
    for a in waxes:
        n_w *= mi.size(a)

    logits = (x_flat @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eid = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    eid_flat = eid.reshape(-1)
    pos, keep = _dispatch_indices(eid_flat, E, capacity)
    e_idx = jnp.where(keep, eid_flat, E)
    x_slots = jnp.repeat(x_flat, k, axis=0)
    buf = jnp.zeros((E + 1, capacity, D), x_flat.dtype)
    buf = buf.at[e_idx, jnp.where(keep, pos, 0)].set(
        jnp.where(keep[:, None], x_slots, 0))
    buf = buf[:E]
    # EP all_to_all over 'model'
    buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                             tiled=True)                  # [E_loc, tp*C, D]
    if waxes:
        # share tokens across the weight-shard ranks (tiny at decode)
        for a in waxes:
            buf = all_gather_invariant(buf, a, axis=1, tiled=True)
        my = 0
        for a in waxes:
            my = my * mi.size(a) + jax.lax.axis_index(a)
        C_here = buf.shape[1]
        # partial contraction over this rank's d_model slice
        d_loc = p["we_in"].shape[1]
        off = my * d_loc
        buf_slice = jax.lax.dynamic_slice_in_dim(buf, off, d_loc, axis=2)
        h = jnp.einsum("ecd,edf->ecf", buf_slice, p["we_in"])
        g = jnp.einsum("ecd,edf->ecf", buf_slice, p["we_gate"])
        h = jax.lax.psum(h, waxes)
        g = jax.lax.psum(g, waxes)
        z = act_fn(cfg.act)(g) * h
        # we_out sharded on its OUTPUT (d_model) dim: local columns + AG
        y_loc = jnp.einsum("ecf,efd->ecd", z, p["we_out"])
        y = y_loc
        for a in waxes:
            y = all_gather_invariant(y, a, axis=2, tiled=True)
        # keep this rank's token block
        y = jax.lax.dynamic_slice_in_dim(
            y, my * (C_here // n_w), C_here // n_w, axis=1)
    else:  # weights fully resident: plain local compute
        h = jnp.einsum("ecd,edf->ecf", buf, p["we_in"])
        g = jnp.einsum("ecd,edf->ecf", buf, p["we_gate"])
        z = act_fn(cfg.act)(g) * h
        y = jnp.einsum("ecf,efd->ecd", z, p["we_out"])
    y = jax.lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                           tiled=True)                    # [E, C, D]
    gathered = y[jnp.where(keep, eid_flat, 0), jnp.where(keep, pos, 0)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    out = jnp.sum(gathered.reshape(T, k, D)
                  * gate_vals[..., None].astype(y.dtype), axis=1)
    return out, jnp.float32(0)


def moe_apply(cfg, sys, mi: MeshInfo, p, x, sharded: bool = False):
    """x: [B, S, D]. Tokens are split over the 'model' axis before
    dispatch (activations are TP-replicated; without the split every rank
    would dispatch the same tokens -- tp-fold redundant expert compute),
    then combined with an all-gather. Chunked dispatch bounds [E,C,D].
    sharded=True (decode): gather-free expert compute, see
    _moe_chunk_sharded."""
    m = cfg.moe
    B, S, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    h_flat = h.reshape(B * S, D)
    T_orig = B * S
    # pad tokens to a multiple of tp so every rank dispatches a distinct
    # slice (single code path; padding outputs are sliced away after the
    # invariant gather)
    T_pad = -(-T_orig // mi.tp) * mi.tp
    if T_pad != T_orig:
        h_flat = jnp.pad(h_flat, ((0, T_pad - T_orig), (0, 0)))
    rank = tp_rank(mi)
    T = T_pad // mi.tp
    h_flat = jax.lax.dynamic_slice_in_dim(h_flat, rank * T, T, axis=0)
    tok_gathered = True
    chunk = min(getattr(sys, "moe_token_chunk", 8192), T)
    n = T // chunk if T % chunk == 0 else 1
    if n == 1:
        chunk = T
    capacity = int(math.ceil(chunk * m.top_k / m.num_experts
                             * m.capacity_factor))
    capacity = max(4, ((capacity + 3) // 4) * 4)
    # inner remat: dispatch buffers/sorts recomputed in backward.
    # GatherPlans are static metadata -- keep them out of the checkpoint
    # arguments (closure capture instead).
    we_plans = p.pop("_we_plans", None)
    if sharded:
        chunk_fn = lambda xc, pp: _moe_chunk_sharded(
            xc, pp, cfg, mi, capacity, we_plans)
    else:
        chunk_fn = lambda xc, pp: _moe_chunk(xc, pp, cfg, mi, capacity)
    moe_fn = jax.checkpoint(
        chunk_fn, policy=jax.checkpoint_policies.nothing_saveable)
    if n == 1:
        out, aux = moe_fn(h_flat, p)
    else:
        def body(carry, xc):
            out_c, aux_c = moe_fn(xc, p)
            return carry + aux_c, out_c
        from repro.models.common import pvary_like
        aux0 = pvary_like(jnp.float32(0), h_flat)
        aux, outs = jax.lax.scan(
            body, aux0, h_flat.reshape(n, chunk, D))
        out = outs.reshape(T, D)
    # invariant gather: every rank reconstructs the same full token set
    out = all_gather_invariant(out, "model", axis=0, tiled=True)
    aux = jax.lax.psum(aux, "model")
    out = out[:T_orig]
    y = out.reshape(B, S, D).astype(x.dtype)
    return x + y, aux * m.aux_loss_weight


# ===========================================================================
# Mamba (selective scan; for Jamba)
# ===========================================================================

def mamba_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    mc = cfg.mamba
    d = cfg.d_model
    d_in = mc.expand * d
    dt_rank = mc.dt_rank or -(-d // 16)
    ns = mc.d_state
    return {
        "norm": ParamDef((d,), ("fsdp",), init="ones"),
        "in_proj": ParamDef((d, 2 * d_in), ("fsdp", "tp")),
        "conv_w": ParamDef((d_in, mc.d_conv), ("tp", None), init_scale=0.5),
        "conv_b": ParamDef((d_in,), ("tp",), init="zeros"),
        "x_proj": ParamDef((d_in, dt_rank + 2 * ns), ("tp", None)),
        "dt_proj": ParamDef((dt_rank, d_in), (None, "tp")),
        "dt_bias": ParamDef((d_in,), ("tp",), init="zeros"),
        "A_log": ParamDef((d_in, ns), ("tp", None), init="ones"),
        "D_skip": ParamDef((d_in,), ("tp",), init="ones"),
        "out_proj": ParamDef((d_in, d), ("tp", "fsdp"), fusable=True),
    }


def _mamba_core(cfg, mi, p, xz, conv_state=None, h_state=None, chunk=512):
    """xz: [B, S, 2*d_in_local]. Returns (y_local [B,S,d_in_local], states)."""
    mc = cfg.mamba
    ns = mc.d_state
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    B, S, _ = xz.shape
    d_loc = xz.shape[-1] // 2
    x, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv (k = d_conv)
    k = mc.d_conv
    if conv_state is None:
        x_pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    new_conv_state = x_pad[:, -(k - 1):, :] if k > 1 else None
    idx = jnp.arange(S)[:, None] + jnp.arange(k)[None, :]
    xs = x_pad[:, idx]                                    # [B,S,k,dloc]
    xc = jnp.einsum("bskd,dk->bsd", xs, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    # projections: B,C are global (psum over model); dt per-channel local
    xdb = xc @ p["x_proj"]                                # [B,S,r+2n] partial
    xdb = psum_tp(xdb, mi)
    dt_in, Bc, Cc = jnp.split(xdb, [dt_rank, dt_rank + ns], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])  # [B,S,dloc]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # [dloc, ns]
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)    # [B,S,dloc,ns]
    b = (dt.astype(jnp.float32) * xc.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[..., None, :]            # [B,S,dloc,ns]

    def scan_chunk(h0, ab):
        a_c, b_c = ab

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl
        a_acc, b_acc = jax.lax.associative_scan(comb, (a_c, b_c), axis=1)
        hs = a_acc * h0[:, None] + b_acc                  # [B,c,dloc,ns]
        return hs[:, -1], hs

    h0 = (jnp.zeros((B, d_loc, ns), jnp.float32)
          if h_state is None else h_state)
    from repro.models.common import pvary_like
    h0 = pvary_like(pvary_like(h0, a), b)
    c = min(chunk, S)
    scan_fn = jax.checkpoint(
        scan_chunk, policy=jax.checkpoint_policies.nothing_saveable)
    if S % c == 0 and S > c:
        n = S // c
        a_r = a.reshape(B, n, c, d_loc, ns).swapaxes(0, 1)
        b_r = b.reshape(B, n, c, d_loc, ns).swapaxes(0, 1)
        h_last, hs = jax.lax.scan(scan_fn, h0, (a_r, b_r))
        hs = hs.swapaxes(0, 1).reshape(B, S, d_loc, ns)
    else:
        h_last, hs = scan_fn(h0, (a, b))
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cc.astype(jnp.float32))
    y = y + p["D_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xz.dtype)
    return y, (new_conv_state, h_last)


def mamba_apply(cfg, sys, mi: MeshInfo, p, x):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = h @ p["in_proj"]
    y, _ = _mamba_core(cfg, mi, p, xz)
    out = layers.matmul(y, p["out_proj"])
    return x + psum_tp_act(out, mi)


def mamba_prefill(cfg, sys, mi: MeshInfo, p, x):
    """Full-sequence forward that also returns final recurrent state."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = h @ p["in_proj"]
    y, (conv_s, h_s) = _mamba_core(cfg, mi, p, xz)
    out = layers.matmul(y, p["out_proj"])
    return x + psum_tp(out, mi), {"conv": conv_s.astype(BF16), "h": h_s}


def mamba_init_state(cfg, mi: MeshInfo, batch: int):
    """Global logical shape; d_inner dim is 'model'-sharded via in_specs."""
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, mc.d_conv - 1, d_in), BF16),
            "h": jnp.zeros((batch, d_in, mc.d_state), jnp.float32)}


def mamba_decode(cfg, sys, mi: MeshInfo, p, x, state):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = h @ p["in_proj"]
    y, (conv_s, h_s) = _mamba_core(cfg, mi, p, xz,
                                   conv_state=state["conv"],
                                   h_state=state["h"])
    out = layers.matmul(y, p["out_proj"])
    return x + psum_tp(out, mi), {"conv": conv_s.astype(BF16), "h": h_s}


# ===========================================================================
# RWKV-6 (Finch)
# ===========================================================================

def rwkv_tm_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    rc = cfg.rwkv
    d = cfg.d_model
    hd = rc.head_dim
    n_heads = d // hd
    hp = pad_heads(n_heads, tp)
    da = hp * hd                        # padded attention width
    lr = rc.decay_lora
    return {
        "norm": ParamDef((d,), ("fsdp",), init="ones"),
        "maa_base": ParamDef((6, d), (None, "fsdp"), init="zeros"),  # x,w,k,v,r,g
        "maa_w1": ParamDef((d, 5 * 32), ("fsdp", None), init="zeros"),
        "maa_w2": ParamDef((5, 32, d), (None, None, "fsdp"), init_scale=0.1),
        "w_r": ParamDef((d, da), ("fsdp", "tp")),
        "w_k": ParamDef((d, da), ("fsdp", "tp")),
        "w_v": ParamDef((d, da), ("fsdp", "tp")),
        "w_g": ParamDef((d, da), ("fsdp", "tp")),
        "decay_base": ParamDef((da,), ("tp",), init="zeros"),
        "decay_w1": ParamDef((d, lr), ("fsdp", None), init="zeros"),
        "decay_w2": ParamDef((lr, da), (None, "tp"), init_scale=0.1),
        "u": ParamDef((da,), ("tp",), init="zeros"),
        "ln_x": ParamDef((da,), ("tp",), init="ones"),
        "w_o": ParamDef((da, d), ("tp", "fsdp"), fusable=True),
    }


def _token_shift(x, xprev_last=None):
    """x: [B,S,D] -> previous-token tensor; xprev_last: [B,D] carry."""
    if xprev_last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([xprev_last[:, None], x[:, :-1]], axis=1)
    return prev


def _rwkv_mix(p, x, prev):
    """Data-dependent lerp (ddlerp) producing the 5 mixed inputs."""
    dx = prev - x
    mx = x + dx * p["maa_base"][0]
    k5 = jnp.tanh(mx @ p["maa_w1"])                   # [B,S,5*32]
    B, S, _ = k5.shape
    k5 = k5.reshape(B, S, 5, 32)
    deltas = jnp.einsum("bsfr,frd->bsfd", k5, p["maa_w2"])  # [B,S,5,D]
    outs = []
    for i, name in enumerate(("w", "k", "v", "r", "g")):
        mu = p["maa_base"][i + 1] + deltas[:, :, i]
        outs.append(x + dx * mu)
    return outs  # xw, xk, xv, xr, xg


def _wkv_chunked(r, k, v, logw, u, chunk: int = 64,
                 s0=None):
    """RWKV-6 WKV with per-step per-channel decay, chunked.

    r,k,v: [B,S,H,hd]; logw: [B,S,H,hd] (log decay, <=0); u: [H,hd].
    Returns ([B,S,H,hd], final_state [B,H,hd,hd]).
    State recurrence: S = diag(w_t) S + k_t v_t^T;  o_t = r_t (S_prev + u k_t v_t^T)
    """
    B, S, H, hd = r.shape
    c = min(chunk, S)
    assert S % c == 0, f"wkv seq {S} not divisible by chunk {c}"
    n = max(S // c, 1)
    rs = r.reshape(B, n, c, H, hd).swapaxes(0, 1).astype(jnp.float32)
    ks = k.reshape(B, n, c, H, hd).swapaxes(0, 1).astype(jnp.float32)
    vs = v.reshape(B, n, c, H, hd).swapaxes(0, 1).astype(jnp.float32)
    lws = logw.reshape(B, n, c, H, hd).swapaxes(0, 1).astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def body(S0, inp):
        rc, kc, vc, lwc = inp                          # [B,c,H,hd]
        cw = jnp.cumsum(lwc, axis=1)                   # log prod_{j<=t} w_j
        cw_prev = cw - lwc                             # log prod_{j<t}
        # inter-chunk: q_t = r_t * exp(cw_prev)
        q = rc * jnp.exp(cw_prev)
        o_inter = jnp.einsum("bthk,bhkv->bthv", q, S0)
        # intra-chunk: A[t,i] = sum_ch r[t]k[i] exp(cw_prev[t]-cw[i]), i<t.
        # mask the LOG ratio before exponentiation: for i >= t it is a
        # positive log-sum that overflows under strong decay, and
        # inf * 0 would poison the output with NaNs.
        ratio_log = cw_prev[:, :, None] - cw[:, None, :]       # [B,t,i,H,hd]
        tri = jnp.tril(jnp.ones((c, c), jnp.bool_), -1)        # strict: i<t
        ratio_log = jnp.where(tri[None, :, :, None, None], ratio_log, -1e30)
        A = jnp.einsum("bthk,bihk,btihk->bthi", rc, kc, jnp.exp(ratio_log))
        o_intra = jnp.einsum("bthi,bihv->bthv", A, vc)
        # diagonal (current token, u bonus)
        diag = jnp.einsum("bthk,bthk->bth", rc, uf[None, None] * kc)
        o_diag = diag[..., None] * vc
        o = o_inter + o_intra + o_diag
        # state update: S' = diag(exp(cw_c)) S0 + sum_i outer(k_i exp(cw_c-cw_i), v_i)
        cw_c = cw[:, -1]                               # [B,H,hd]
        kd = kc * jnp.exp(cw_c[:, None] - cw)
        S_new = jnp.exp(cw_c)[..., None] * S0 + jnp.einsum(
            "bihk,bihv->bhkv", kd, vc)
        return S_new, o

    S0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if s0 is None else s0)
    from repro.models.common import pvary_like
    S0 = pvary_like(pvary_like(S0, rs), lws)
    Sf, os = jax.lax.scan(body, S0, (rs, ks, vs, lws))
    out = os.swapaxes(0, 1).reshape(B, S, H, hd)
    return out.astype(r.dtype), Sf


def _group_norm_heads(x, scale, eps=1e-5):
    """x: [B,S,H,hd] normalized per head (rwkv ln_x); scale: [H*hd]."""
    B, S, H, hd = x.shape
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf.reshape(B, S, H * hd)
            * scale.astype(jnp.float32)).astype(x.dtype)


def _rwkv_tm_core(cfg, mi, p, x, xprev_last=None, s0=None):
    rc = cfg.rwkv
    hd = rc.head_dim
    n_heads = cfg.d_model // hd
    hp = pad_heads(n_heads, mi.tp)
    h_local = hp // mi.tp
    B, S, D = x.shape
    prev = _token_shift(x, xprev_last)
    xw, xk, xv, xr, xg = _rwkv_mix(p, x, prev)
    r = (xr @ p["w_r"]).reshape(B, S, h_local, hd)
    k = (xk @ p["w_k"]).reshape(B, S, h_local, hd)
    v = (xv @ p["w_v"]).reshape(B, S, h_local, hd)
    g = jax.nn.silu(xg @ p["w_g"])
    logw = -jnp.exp(
        (p["decay_base"] + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
         ).astype(jnp.float32)).reshape(B, S, h_local, hd)
    u = p["u"].astype(jnp.float32).reshape(h_local, hd)
    wkv_fn = jax.checkpoint(
        lambda r_, k_, v_, w_, u_: _wkv_chunked(r_, k_, v_, w_, u_, s0=s0),
        policy=jax.checkpoint_policies.nothing_saveable)
    out, s_new = wkv_fn(r, k, v, logw, u)
    hmask = local_head_mask(mi, hp, n_heads)
    out = out * hmask[None, None, :, None].astype(out.dtype)
    out = _group_norm_heads(out, p["ln_x"], cfg.norm_eps)
    out = out * g.astype(out.dtype)
    y = layers.matmul(out, p["w_o"])
    return psum_tp(y, mi), (x[:, -1], s_new)


def rwkv_tm_apply(cfg, sys, mi: MeshInfo, p, x):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    y, _ = _rwkv_tm_core(cfg, mi, p, h)
    return x + y


def rwkv_tm_prefill(cfg, sys, mi: MeshInfo, p, x):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    y, (xlast, s_new) = _rwkv_tm_core(cfg, mi, p, h)
    return x + y, {"xprev": xlast.astype(BF16), "s": s_new}


def rwkv_tm_init_state(cfg, mi: MeshInfo, batch: int):
    """Global logical shape; head dim is 'model'-sharded via in_specs."""
    rc = cfg.rwkv
    hd = rc.head_dim
    hp = pad_heads(cfg.d_model // hd, mi.tp)
    return {"xprev": jnp.zeros((batch, cfg.d_model), BF16),
            "s": jnp.zeros((batch, hp, hd, hd), jnp.float32)}


def rwkv_tm_decode(cfg, sys, mi: MeshInfo, p, x, state):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    y, (xlast, s_new) = _rwkv_tm_core(
        cfg, mi, p, h, xprev_last=state["xprev"].astype(h.dtype),
        s0=state["s"])
    return x + y, {"xprev": xlast.astype(BF16), "s": s_new}


def rwkv_cm_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm": ParamDef((d,), ("fsdp",), init="ones"),
        "mu_k": ParamDef((d,), ("fsdp",), init="zeros"),
        "mu_r": ParamDef((d,), ("fsdp",), init="zeros"),
        "w_k": ParamDef((d, f), ("fsdp", "tp")),
        "w_v": ParamDef((f, d), ("tp", "fsdp"), fusable=True),
        "w_r": ParamDef((d, d), ("fsdp", "tp")),
    }


def _rwkv_cm_core(cfg, mi, p, x, xprev_last=None):
    B, S, D = x.shape
    prev = _token_shift(x, xprev_last)
    dx = prev - x
    xk = x + dx * p["mu_k"]
    xr = x + dx * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    kv = layers.matmul(kk, p["w_v"])
    kv = jax.lax.psum_scatter(kv, "model", scatter_dimension=2,
                              tiled=True)                  # [B,S,D/tp]
    gate = jax.nn.sigmoid(xr @ p["w_r"])                   # [B,S,D/tp]
    out = gate * kv
    out = all_gather_invariant(out, "model", axis=2, tiled=True)
    return out, x[:, -1]


def rwkv_cm_apply(cfg, sys, mi: MeshInfo, p, x):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    y, _ = _rwkv_cm_core(cfg, mi, p, h)
    return x + y


def rwkv_cm_prefill(cfg, sys, mi: MeshInfo, p, x):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    y, xlast = _rwkv_cm_core(cfg, mi, p, h)
    return x + y, {"xprev": xlast.astype(BF16)}


def rwkv_cm_init_state(cfg, mi: MeshInfo, batch: int):
    return {"xprev": jnp.zeros((batch, cfg.d_model), BF16)}


def rwkv_cm_decode(cfg, sys, mi: MeshInfo, p, x, state):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    y, xlast = _rwkv_cm_core(cfg, mi, p, h,
                             xprev_last=state["xprev"].astype(h.dtype))
    return x + y, {"xprev": xlast.astype(BF16)}
