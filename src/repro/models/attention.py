"""Attention: GQA/MQA with chunked (flash-style) jnp implementation,
plus decode paths (batch-sharded KV and sequence-sharded KV for
long-context with partial-softmax psum reconstruction).

TP layout: q heads column-parallel over 'model' (padded to a multiple of
tp); K/V projections replicated over 'model' (GQA kv-head counts are not
divisible by tp=16 for most assigned archs), ZeRO-sharded like all
params. Padding heads are masked to zero so they neither contribute
output nor receive gradient.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (MeshInfo, local_head_mask, psum_tp,
                                 psum_tp_act)

NEG_INF = -1e30


def _expand_kv(k, n_rep: int):
    """[B,S,KVH,hd] -> [B,S,KVH*n_rep,hd] by repeating each kv head."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d)


def kv_span(h_local: int, n_rep: int, n_kv: int) -> int:
    """Static count of kv heads one TP rank's q heads touch."""
    if n_rep <= 0:
        return n_kv
    aligned = (h_local % n_rep == 0) or (n_rep % h_local == 0)
    span = max(h_local // n_rep, 1) + (0 if aligned else 1)
    return min(span, n_kv)


def slice_expand_kv(k_all, v_all, h_local: int, n_rep: int, mi: MeshInfo):
    """Produce this TP rank's [B,S,h_local,hd] expanded K/V without ever
    materializing the full expanded tensor: slice the (at most
    ceil((h_local-1)/n_rep)+1) kv heads this rank's q heads map onto,
    expand only those, then slice the exact local head range."""
    n_kv = k_all.shape[2]
    rank_start = jax.lax.axis_index("model") * h_local
    span = kv_span(h_local, n_rep, n_kv)
    kv_first = jnp.minimum(rank_start // n_rep, n_kv - span)
    k_loc = jax.lax.dynamic_slice_in_dim(k_all, kv_first, span, axis=2)
    v_loc = jax.lax.dynamic_slice_in_dim(v_all, kv_first, span, axis=2)
    off = rank_start - kv_first * n_rep
    k_exp = jax.lax.dynamic_slice_in_dim(
        _expand_kv(k_loc, n_rep), off, h_local, axis=2)
    v_exp = jax.lax.dynamic_slice_in_dim(
        _expand_kv(v_loc, n_rep), off, h_local, axis=2)
    return k_exp, v_exp


def chunked_causal_attention(q, k, v, *, q_chunk: int = 1024,
                             kv_chunk: int = 1024, causal: bool = True,
                             softmax_scale: Optional[float] = None,
                             q_offset: int = 0):
    """Flash-style attention in pure jnp: O(chunk^2) live memory.

    q: [B, Sq, H, hd]; k, v: [B, Skv, H, hd] (kv already head-expanded).
    q_offset: absolute position of q[0] relative to k[0] (for prefill
    continuation); causal masking uses absolute positions. May be a
    scalar (all rows share one offset -- the contiguous-cache path) or a
    [B] array (per-row offsets -- the paged continuous-batching path,
    where every sequence in the batch sits at its own position). The
    scalar path lowers exactly as before, so single-request serving is
    bit-identical.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = softmax_scale or (1.0 / math.sqrt(hd))
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    # pad to multiples
    if Sq % q_chunk:
        q = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    if Skv % kv_chunk:
        pad = nk * kv_chunk - Skv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qs = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 3, 2, 4)  # [nq,B,H,qc,hd]
    ks = k.reshape(B, nk, kv_chunk, H, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, nk, kv_chunk, H, hd).transpose(1, 0, 3, 2, 4)

    kv_pos = (jnp.arange(nk * kv_chunk)).reshape(nk, kv_chunk)

    q_off = jnp.asarray(q_offset)

    def q_block(qi_qc):
        qi, qc = qi_qc
        rel = qi * q_chunk + jnp.arange(q_chunk)
        # [B, qc] when q_offset is per-row, [1, qc] for the scalar path
        # (identical broadcast shape to the original scalar code)
        q_pos = (q_off[:, None] + rel[None, :] if q_off.ndim == 1
                 else (q_off + rel)[None, :])

        def kv_body(carry, inp):
            m, l, acc = carry
            kc, vc, kpos = inp
            s = jnp.einsum("bhqd,bhkd->bhqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            mask = kpos[None, None, None, :] < Skv  # kv padding
            if causal:
                mask = mask & (kpos[None, None, None, :] <= q_pos[:, None, :, None])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        from repro.models.common import pvary_like
        m0 = pvary_like(jnp.full((B, H, q_chunk), NEG_INF, jnp.float32), qc)
        m0 = pvary_like(m0, ks)
        l0 = pvary_like(pvary_like(
            jnp.zeros((B, H, q_chunk), jnp.float32), qc), ks)
        a0 = pvary_like(pvary_like(
            jnp.zeros((B, H, q_chunk, hd), jnp.float32), qc), ks)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (ks, vs, kv_pos))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out  # [B,H,qc,hd]

    outs = jax.lax.map(q_block, (jnp.arange(nq), qs))        # [nq,B,H,qc,hd]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Sq].astype(q.dtype)


def _lora_term(x, lora, name, scale):
    a = lora.get(f"{name}_lora_a") if lora else None
    if a is None:
        return None
    b = lora[f"{name}_lora_b"]
    return ((x @ a) @ b) * scale


def attention_block(x, wq, wk, wv, wo, bq, bk, bv, cfg, mi: MeshInfo,
                    positions, attn_impl: str = "jnp",
                    kv_cache: Optional[Tuple] = None,
                    paged_kv: Optional[Tuple] = None,
                    q_norm=None, k_norm=None, lora=None,
                    # adapter scale alpha/rank: callers thread the
                    # resolved value from core.peft.lora_scale(sys)
                    # (source of truth: SystemConfig.lora_alpha); the
                    # default only covers direct lora-less unit calls
                    lora_alpha: float = 2.0, causal: bool = True):
    """Full attention sublayer on local shards.

    x: [B, S, D]. wq: [D, Hpad_local*hd]; wk/wv: [D, KVH*hd] (replicated
    over model); wo: [Hpad_local*hd, D]. Returns ([B,S,D], new_kv).

    paged_kv: (pool_k, pool_v, page_table) -- the paged KV cache path
    for continuous batching. pool_k/pool_v: [n_pages, page_size, span,
    hd] (this rank's kv-head span, this replica's pages); page_table:
    [B, max_pages] LOCAL page ids, where page 0 is the reserved scratch
    page rows of inactive batch slots point at. ``positions`` must then
    be the per-row absolute positions [B, S] (contiguous per row).
    Returns (pool_k, pool_v) as new_kv. Mutually exclusive with
    kv_cache.
    """
    B, S, D = x.shape
    hd = cfg.resolved_head_dim()
    n_kv = cfg.num_kv_heads
    h_local = wq.shape[1] // hd
    padded_heads = h_local * mi.tp

    q = x @ wq
    if bq is not None:
        q = q + bq
    k = x @ wk
    v = x @ wv
    if bk is not None:
        k = k + bk
    if bv is not None:
        v = v + bv
    for name, ref in (("wq", "q"), ("wk", "k"), ("wv", "v")):
        t = _lora_term(x, lora, name, lora_alpha)
        if t is not None:
            if ref == "q":
                q = q + t.astype(q.dtype)
            elif ref == "k":
                k = k + t.astype(k.dtype)
            else:
                v = v + t.astype(v.dtype)
    q = q.reshape(B, S, h_local, hd)
    k = k.reshape(B, S, n_kv, hd)
    v = v.reshape(B, S, n_kv, hd)
    if q_norm is not None:  # chameleon-style qk-norm
        from repro.models.layers import rms_norm
        q = rms_norm(q, q_norm, cfg.norm_eps)
        k = rms_norm(k, k_norm, cfg.norm_eps)
    q = apply_rope_heads(q, positions, cfg.rope_theta)
    k = apply_rope_heads(k, positions, cfg.rope_theta)

    if padded_heads % n_kv != 0:
        raise ValueError(
            f"padded heads {padded_heads} not divisible by kv heads {n_kv}")
    n_rep = padded_heads // n_kv

    new_cache = None
    if paged_kv is not None:
        pool_k, pool_v, table = paged_kv
        span = pool_k.shape[2]
        if span < n_kv or mi.tp > 1:
            rank_start = (jax.lax.axis_index("model") * h_local
                          if mi.tp > 1 else 0)
            kv_first = jnp.minimum(rank_start // n_rep, n_kv - span)
            k_w = jax.lax.dynamic_slice_in_dim(k, kv_first, span, axis=2)
            v_w = jax.lax.dynamic_slice_in_dim(v, kv_first, span, axis=2)
            off = rank_start - kv_first * n_rep
        else:
            k_w, v_w, off = k, v, 0
        n_pages, page_size = pool_k.shape[0], pool_k.shape[1]
        flat_k = pool_k.reshape(n_pages * page_size, span, hd)
        flat_v = pool_v.reshape(n_pages * page_size, span, hd)
        # absolute position -> flat pool slot through the page table.
        # Positions past the table width (chunk-padding overshoot) are
        # redirected to the scratch page: never read (the causal mask
        # stops at each row's own position), so duplicate writes there
        # may land in any order.
        page_idx = positions // page_size
        in_range = page_idx < table.shape[1]
        pageof = jnp.take_along_axis(
            table, jnp.minimum(page_idx, table.shape[1] - 1), axis=1)
        pageof = jnp.where(in_range, pageof, 0)
        slot = pageof * page_size + positions % page_size          # [B, S]
        flat_idx = slot.reshape(-1)
        flat_k = flat_k.at[flat_idx].set(
            k_w.astype(flat_k.dtype).reshape(B * S, span, hd))
        flat_v = flat_v.at[flat_idx].set(
            v_w.astype(flat_v.dtype).reshape(B * S, span, hd))
        new_cache = (flat_k.reshape(pool_k.shape),
                     flat_v.reshape(pool_v.shape))
        # gather every page a row can address into one contiguous view
        # [B, max_pages*page_size, span, hd]; rows beyond a sequence's
        # written length come from scratch/stale pages and are masked by
        # the per-row causal offset below (finite garbage -> exact zero
        # contribution after the NEG_INF mask, see chunked attention).
        gather_idx = (table[..., None] * page_size
                      + jnp.arange(page_size)[None, None, :]
                      ).reshape(B, table.shape[1] * page_size)
        k_gat = flat_k[gather_idx]
        v_gat = flat_v[gather_idx]
        q_offset = positions[:, 0]
        k_exp = jax.lax.dynamic_slice_in_dim(
            _expand_kv(k_gat, n_rep), off, h_local, axis=2)
        v_exp = jax.lax.dynamic_slice_in_dim(
            _expand_kv(v_gat, n_rep), off, h_local, axis=2)
    elif kv_cache is not None:
        # TP-sharded KV cache: each rank stores only the kv_span heads its
        # q heads read (cache local shape [B, S_max, span, hd]); fresh K/V
        # are sliced before the write so the full cache never materializes.
        k_cache, v_cache, cache_index = kv_cache
        span = k_cache.shape[2]
        if span < n_kv or mi.tp > 1:
            rank_start = (jax.lax.axis_index("model") * h_local
                          if mi.tp > 1 else 0)
            kv_first = jnp.minimum(rank_start // n_rep, n_kv - span)
            k_w = jax.lax.dynamic_slice_in_dim(k, kv_first, span, axis=2)
            v_w = jax.lax.dynamic_slice_in_dim(v, kv_first, span, axis=2)
            off = rank_start - kv_first * n_rep
        else:
            k_w, v_w, off = k, v, 0
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_w.astype(k_cache.dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_w.astype(v_cache.dtype), cache_index, axis=1)
        new_cache = (k_cache, v_cache, cache_index + S)
        q_offset = cache_index
        k_exp = jax.lax.dynamic_slice_in_dim(
            _expand_kv(k_cache, n_rep), off, h_local, axis=2)
        v_exp = jax.lax.dynamic_slice_in_dim(
            _expand_kv(v_cache, n_rep), off, h_local, axis=2)
    else:
        q_offset = 0
        k_exp, v_exp = slice_expand_kv(k, v, h_local, n_rep, mi)

    if (attn_impl in ("pallas", "pallas_interpret") and causal
            and kv_cache is None and paged_kv is None):
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            q, k_exp, v_exp, causal=True,
            interpret=(attn_impl == "pallas_interpret"))
    else:
        # inner remat: recompute attention internals in the backward from
        # (q, k, v), exactly like FlashAttention -- without this the
        # chunk-scan residuals (probs, partial sums) get stacked and saved
        attn_fn = jax.checkpoint(
            lambda q_, k_, v_: chunked_causal_attention(
                q_, k_, v_, q_offset=q_offset, causal=causal),
            policy=jax.checkpoint_policies.nothing_saveable)
        out = attn_fn(q, k_exp, v_exp)

    mask = local_head_mask(mi, padded_heads, cfg.num_heads)
    out = out * mask[None, None, :, None].astype(out.dtype)
    out = out.reshape(B, S, h_local * hd)
    from repro.models.layers import matmul
    y = matmul(out, wo)
    t = _lora_term(out, lora, "wo", lora_alpha)
    if t is not None:
        y = y + t.astype(y.dtype)
    return psum_tp_act(y, mi), new_cache


def apply_rope_heads(x, positions, theta):
    from repro.models.layers import apply_rope
    return apply_rope(x, positions, theta)


# ---------------------------------------------------------------------------
# Decode attention over a sequence-sharded KV cache (long_500k).
# Flash-decoding adapted to the mesh: each 'data' shard holds S/data of the
# KV cache; partial (max, sumexp, weighted-V) stats are combined with
# collectives instead of a second kernel pass.
# ---------------------------------------------------------------------------

def seq_sharded_decode_attention(q, k_shard, v_shard, valid_len_local,
                                 mi: MeshInfo, seq_axis: str = "data"):
    """q: [B, 1, H, hd]; k_shard/v_shard: [B, S_local, KVH, hd] (this
    rank's slice of the cache); valid_len_local: [] number of valid
    positions in the local shard. Returns [B, 1, H, hd]."""
    B, _, H, hd = q.shape
    S_local = k_shard.shape[1]
    n_kv = k_shard.shape[2]
    n_rep = H // n_kv
    k_exp = _expand_kv(k_shard, n_rep).astype(jnp.float32)
    v_exp = _expand_kv(v_shard, n_rep).astype(jnp.float32)
    qf = q[:, 0].astype(jnp.float32)                       # [B,H,hd]
    s = jnp.einsum("bhd,bkhd->bhk", qf, k_exp) / math.sqrt(hd)
    pos = jnp.arange(S_local)
    s = jnp.where(pos[None, None, :] < valid_len_local, s, NEG_INF)
    m_local = jax.lax.stop_gradient(jnp.max(s, axis=-1))    # [B,H]
    m = jax.lax.pmax(m_local, seq_axis)
    p = jnp.exp(s - m[..., None])
    l_local = jnp.sum(p, axis=-1)
    acc_local = jnp.einsum("bhk,bkhd->bhd", p, v_exp)
    l = jax.lax.psum(l_local, seq_axis)
    acc = jax.lax.psum(acc_local, seq_axis)
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out[:, None].astype(q.dtype)                     # [B,1,H,hd]
