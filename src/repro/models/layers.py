"""Core layers: norms, rotary embeddings, GLU MLPs, TP embeddings,
TP-sharded cross-entropy. All functions run inside shard_map on local
shards; collectives are explicit."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (MeshInfo, psum_tp, psum_tp_act,
                                 pmax_tp, tp_rank)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5, offset: float = 0.0):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (scale.astype(jnp.float32) + offset)).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Matmul (gather-fused dispatch seam)
# ---------------------------------------------------------------------------

def matmul(x, w):
    """``x @ w`` where ``w`` may be a :class:`core.fcdp.FusedParam`.

    A fused param is the stage-1 cached shard of an output-dim-sharded
    weight: the stage-2 intra all-gather happens INSIDE the ring-
    scheduled matmul (kernels/collective_matmul.py), chunk transfers
    overlapped with per-chunk compute. Every output-projection matmul
    routes through here so the plan decides, per leaf, whether its
    weight arrives whole or as a ring."""
    from repro.core.fcdp import FusedParam
    if isinstance(w, FusedParam):
        from repro.kernels import ops as kops
        plan = w.plan
        return kops.collective_ag_matmul(
            x, w.cache, plan.intra_axes[0], mode=plan.fused,
            impl=plan.fused_impl)
    return x @ w


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {"swiglu": jax.nn.silu, "geglu": lambda x: jax.nn.gelu(x, approximate=True),
            "gelu": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def glu_mlp(x, w_in, w_gate, w_out, act: str, mi: MeshInfo):
    """Column-parallel in/gate, row-parallel out (+psum over model)."""
    h = act_fn(act)(x @ w_gate) * (x @ w_in)
    y = matmul(h, w_out)
    return psum_tp_act(y, mi)


def dense_mlp(x, w_in, w_out, act: str, mi: MeshInfo):
    h = act_fn(act)(x @ w_in)
    return psum_tp_act(matmul(h, w_out), mi)


# ---------------------------------------------------------------------------
# TP embedding + logits
# ---------------------------------------------------------------------------

def embed_lookup(table, ids, mi: MeshInfo, scale: float = 1.0):
    """table: [V_local, D] (vocab TP-sharded); ids: [B, S] global ids."""
    v_local = table.shape[0]
    offset = tp_rank(mi) * v_local
    local_ids = ids - offset
    valid = (local_ids >= 0) & (local_ids < v_local)
    x = jnp.take(table, jnp.clip(local_ids, 0, v_local - 1), axis=0)
    x = jnp.where(valid[..., None], x, 0).astype(table.dtype)
    x = psum_tp(x, mi)
    if scale != 1.0:
        x = (x.astype(jnp.float32) * scale).astype(table.dtype)
    return x


def tp_softmax_xent(logits_local, labels, mi: MeshInfo, vocab_size: int,
                    mask=None):
    """Cross entropy over a vocab-TP-sharded logits tensor.

    logits_local: [..., V_local]; labels: [...] global ids.
    Returns (sum_loss, sum_count) over unmasked positions (no mean).
    """
    v_local = logits_local.shape[-1]
    offset = tp_rank(mi) * v_local
    lf = logits_local.astype(jnp.float32)
    # numerically-stable logsumexp across shards (max is stability-only;
    # its gradient contribution cancels, so stop_gradient is exact)
    local_max = jax.lax.stop_gradient(jnp.max(lf, axis=-1))
    gmax = pmax_tp(local_max, mi)
    sumexp = jnp.sum(jnp.exp(lf - gmax[..., None]), axis=-1)
    sumexp = psum_tp(sumexp, mi)
    lse = gmax + jnp.log(sumexp)
    # the label logit lives on exactly one shard
    local_label = labels - offset
    valid = (local_label >= 0) & (local_label < v_local)
    picked = jnp.take_along_axis(
        lf, jnp.clip(local_label, 0, v_local - 1)[..., None], axis=-1)[..., 0]
    picked = psum_tp(jnp.where(valid, picked, 0.0), mi)
    nll = lse - picked
    if mask is None:
        mask = labels < vocab_size
    else:
        mask = mask & (labels < vocab_size)
    nll = jnp.where(mask, nll, 0.0)
    return jnp.sum(nll), jnp.sum(mask.astype(jnp.float32))


def chunked_tp_softmax_xent(x, head_w, labels, mi: MeshInfo, vocab_size: int,
                            chunk: int, mask=None):
    """Beyond-paper memory optimization: compute logits + CE in sequence
    chunks under remat so the full [B,S,V_local] tensor never materializes."""
    B, S, D = x.shape
    if chunk <= 0 or S % chunk != 0 or S == chunk:
        logits = x @ head_w
        return tp_softmax_xent(logits, labels, mi, vocab_size, mask)
    n = S // chunk
    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)          # [n,B,c,D]
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)        # [n,B,c]
    ms = None if mask is None else mask.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, inp):
        if ms is None:
            xc, lc = inp
            mc = None
        else:
            xc, lc, mc = inp
        def f(xc, lc):
            logits = xc @ head_w
            return tp_softmax_xent(logits, lc, mi, vocab_size, mc)
        s, c = jax.checkpoint(f)(xc, lc)
        return (carry[0] + s, carry[1] + c), None

    inps = (xs, ls) if ms is None else (xs, ls, ms)
    from repro.models.common import pvary_like
    z = pvary_like(jnp.float32(0), x)
    (tot, cnt), _ = jax.lax.scan(body, (z, z), inps)
    return tot, cnt
