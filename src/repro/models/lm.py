"""Decoder-only language model covering the dense / moe / ssm / hybrid /
vlm families. Chameleon-style VLM is a decoder over a unified token space
(VQ image tokens arrive pre-embedded through the frontend stub)."""
from __future__ import annotations

import copy
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SystemConfig
from repro.core.fcdp import gather_param
from repro.core.partition import ParamDef, label_tree
from repro.core.strategy import resolve_strategies
from repro.models import stack as stk
from repro.models.common import MeshInfo, pad_vocab, psum_tp
from repro.models.layers import (chunked_tp_softmax_xent, embed_lookup,
                                 rms_norm, tp_softmax_xent)


def layer_plan(cfg: ModelConfig) -> Tuple[List[Tuple[str, ...]], int]:
    """Returns (plan, n_groups). plan[i] = sublayer kinds at position i."""
    if cfg.family in ("dense", "vlm"):
        return [("attn", "mlp")], cfg.num_layers
    if cfg.family == "moe":
        return [("attn", "moe")], cfg.num_layers
    if cfg.family == "ssm":
        return [("rwkv_tm", "rwkv_cm")], cfg.num_layers
    if cfg.family == "hybrid":
        period = cfg.hybrid_period
        assert cfg.num_layers % period == 0
        plan = []
        m = cfg.moe
        for i in range(period):
            mixer = "attn" if i in cfg.hybrid_attn_positions else "mamba"
            ffn = "moe" if (m and i % m.moe_period == m.moe_offset) else "mlp"
            plan.append((mixer, ffn))
        return plan, cfg.num_layers // period
    raise ValueError(f"layer_plan: unsupported family {cfg.family}")


class LM:
    """Bundles defs + step-fn bodies for one decoder-only architecture."""

    def __init__(self, cfg: ModelConfig, sys: SystemConfig, mesh):
        self.cfg, self.sys, self.mesh = cfg, sys, mesh
        self.mi = MeshInfo.from_mesh(mesh, act_psum=sys.act_psum,
                                     quant_impl=sys.quant_impl)
        self.plan, self.n_groups = layer_plan(cfg)
        self.vpad = pad_vocab(cfg.vocab_size, self.mi.tp)
        # labels first (override rules match dotted paths), then the
        # per-leaf strategy resolution (ParamDef tag > mode_overrides >
        # mode); uniform configs get the plain singleton strategy back
        self._defs, self.strategy = resolve_strategies(
            sys, label_tree(self._build_defs()),
            strict=not sys.peft)  # adapter-targeting rules match post-injection
        self._plans = self.strategy.plan_tree(
            self._defs, mesh, sys.min_shard_size,
            compress_bwd=(sys.grad_compress == "int8_pod"),
            param_compress=(sys.param_compress == "int8_pod"),
            quant_impl=sys.quant_impl,
            fused_matmul=sys.fused_matmul, fused_impl=sys.fused_impl)

    # -- parameters ---------------------------------------------------------
    def _build_defs(self):
        cfg, tp = self.cfg, self.mi.tp
        defs: Dict[str, Any] = {
            "embed": ParamDef((self.vpad, cfg.d_model), ("tp", "fsdp"),
                              init="embed"),
            "final_norm": ParamDef((cfg.d_model,), ("fsdp",), init="ones"),
            "blocks": stk.stack_defs(
                stk.group_defs(cfg, self.plan, tp, self.sys), self.n_groups),
        }
        if not cfg.tie_embeddings:
            defs["head"] = ParamDef((cfg.d_model, self.vpad), ("fsdp", "tp"))
        return defs

    @property
    def defs(self):
        return self._defs

    @property
    def plans(self):
        return self._plans

    def with_plans(self, plans):
        """Shallow view of this model bound to a different GatherPlan
        tree (the async grad-reduce stream feeds stage-1-resident
        params, see core/schedule.py:stage1_resident_plans)."""
        m = copy.copy(self)
        m._plans = plans
        return m

    # -- shared forward pieces ----------------------------------------------
    def _embed(self, params, ids):
        cfg = self.cfg
        table = gather_param(params["embed"], self._plans["embed"])
        scale = math.sqrt(cfg.d_model) if cfg.name.startswith("gemma") else 1.0
        x = embed_lookup(table, ids, self.mi, scale=scale)
        return x.astype(jnp.dtype(self.sys.compute_dtype))

    def _head_weights(self, params):
        if self.cfg.tie_embeddings:
            table = gather_param(params["embed"], self._plans["embed"])
            return table.T                     # [D, V_local]
        return gather_param(params["head"], self._plans["head"])

    def _segments(self):
        """(start, length, placement) segments implementing FCDP-Cache's
        device-fraction split over the layer stack."""
        n_dev = self.strategy.device_cache_groups(
            self.n_groups, self.sys.device_cache_fraction)
        segs = []
        if n_dev > 0:
            segs.append((0, n_dev, "device"))
        if n_dev < self.n_groups:
            segs.append((n_dev, self.n_groups - n_dev, None))
        return segs

    def _run_blocks(self, params, x, ctx, state=None):
        aux = jnp.float32(0)
        new_state_parts = []
        for (start, length, placement) in self._segments():
            p_slice = jax.tree.map(lambda a: a[start:start + length],
                                   params["blocks"])
            s_slice = (jax.tree.map(lambda a: a[start:start + length], state)
                       if state is not None else None)
            x, s_new, a = stk.apply_stack(
                self.cfg, self.sys, self.mi, self.plan, p_slice,
                self._plans["blocks"], x, ctx, s_slice, placement,
                strategy=self.strategy)
            aux = aux + a
            if s_new is not None:
                new_state_parts.append(s_new)
        if new_state_parts:
            new_state = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_state_parts)
        else:
            new_state = None
        return x, new_state, aux

    # -- training loss -------------------------------------------------------
    def loss_fn(self, params, batch):
        """Runs inside shard_map. batch: ids/labels/mask [B_local, S].
        Returns (loss_sum, token_count, aux_sum) -- caller psums."""
        cfg, sys, mi = self.cfg, self.sys, self.mi
        ids, labels = batch["ids"], batch["labels"]
        mask = batch.get("mask")
        S = ids.shape[1]
        x = self._embed(params, ids)
        ctx = {"positions": jnp.arange(S)[None, :], "causal": True}
        x, _, aux = self._run_blocks(params, x, ctx)
        x = rms_norm(x, gather_param(params["final_norm"],
                                     self._plans["final_norm"]), cfg.norm_eps)
        head = self._head_weights(params)
        loss_sum, cnt = chunked_tp_softmax_xent(
            x, head, labels, mi, cfg.vocab_size, sys.loss_chunk, mask)
        return loss_sum, cnt, aux

    # -- serving -------------------------------------------------------------
    def init_decode_state(self, batch_local: int, max_len: int,
                          seq_sharded: bool = False):
        return stk.init_group_state(self.cfg, self.plan, self.mi, batch_local,
                                    max_len, self.n_groups, seq_sharded)

    def prefill_fn(self, params, ids, state):
        """Full-sequence forward that also fills decode state.
        Returns (last-token logits [B, V_local], new_state)."""
        S = ids.shape[1]
        x = self._embed(params, ids)
        ctx = {"positions": jnp.arange(S)[None, :], "causal": True,
               "prefill": True}
        x, new_state, _ = self._run_blocks(params, x, ctx, state)
        x = rms_norm(x, gather_param(params["final_norm"],
                                     self._plans["final_norm"]),
                     self.cfg.norm_eps)
        logits = x[:, -1:] @ self._head_weights(params)
        return logits[:, 0], new_state

    def decode_fn(self, params, tok, state, seq_sharded: bool = False):
        """One decode step. tok: [B_local, 1] token ids.
        Returns (logits [B_local, V_local], new_state)."""
        x = self._embed(params, tok)
        ctx = {"decode": True, "seq_sharded": seq_sharded}
        x, new_state, _ = self._run_blocks(params, x, ctx, state)
        x = rms_norm(x, gather_param(params["final_norm"],
                                     self._plans["final_norm"]),
                     self.cfg.norm_eps)
        logits = x @ self._head_weights(params)
        return logits[:, 0], new_state

    # -- paged serving (continuous batching) ---------------------------------
    def init_paged_state(self, n_pages: int, page_size: int):
        """Paged KV pools, stacked like the contiguous decode state."""
        return stk.init_paged_group_state(self.cfg, self.plan, self.mi,
                                          n_pages, page_size, self.n_groups)

    def paged_decode_fn(self, params, tok, state, table, lengths):
        """One decode step over the paged cache. tok: [B_local, 1];
        table: [B_local, max_pages] local page ids; lengths: [B_local]
        current written length per row (the incoming token's absolute
        position). Returns (logits [B_local, V_local], new_state)."""
        x = self._embed(params, tok)
        ctx = {"paged": True, "decode": True,
               "positions": lengths[:, None], "page_table": table}
        x, new_state, _ = self._run_blocks(params, x, ctx, state)
        x = rms_norm(x, gather_param(params["final_norm"],
                                     self._plans["final_norm"]),
                     self.cfg.norm_eps)
        logits = x @ self._head_weights(params)
        return logits[:, 0], new_state

    def paged_prefill_fn(self, params, ids, state, table, pos0, last_idx):
        """One prefill CHUNK over the paged cache. ids: [B_local, C]
        (rows not prefilling this call carry padding and a scratch
        table row); pos0: [B_local] absolute position of each row's
        chunk start; last_idx: [B_local] position within the chunk of
        the row's last prompt token (logits are taken there -- only
        meaningful for rows finishing their prompt this chunk).
        Returns (logits [B_local, V_local], new_state)."""
        S = ids.shape[1]
        x = self._embed(params, ids)
        positions = pos0[:, None] + jnp.arange(S, dtype=pos0.dtype)[None, :]
        ctx = {"paged": True, "prefill_chunk": True,
               "positions": positions, "page_table": table}
        x, new_state, _ = self._run_blocks(params, x, ctx, state)
        x = rms_norm(x, gather_param(params["final_norm"],
                                     self._plans["final_norm"]),
                     self.cfg.norm_eps)
        x_last = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)
        logits = x_last @ self._head_weights(params)
        return logits[:, 0], new_state
