"""Model registry: family -> model class, plus analytic parameter counts
used for roofline MODEL_FLOPS."""
from __future__ import annotations

import math
from typing import Any

from repro.configs.base import ModelConfig, SystemConfig


def build_model(cfg: ModelConfig, sys: SystemConfig, mesh):
    if cfg.num_encoder_layers > 0:
        from repro.models.encdec import EncDec
        return EncDec(cfg, sys, mesh)
    from repro.models.lm import LM
    return LM(cfg, sys, mesh)


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim()
    d = cfg.d_model
    q = d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * d
    b = (cfg.num_heads * hd + 2 * cfg.num_kv_heads * hd) if cfg.qkv_bias else 0
    return q + kv + o + b + d


def _mlp_params(cfg: ModelConfig, d_ff=None) -> int:
    f = d_ff or cfg.d_ff
    glu = cfg.act in ("swiglu", "geglu")
    return cfg.d_model * f * (3 if glu else 2) + cfg.d_model


def _moe_params(cfg: ModelConfig, active_only: bool) -> int:
    m = cfg.moe
    e = m.top_k if active_only else m.num_experts
    glu = cfg.act in ("swiglu", "geglu")
    return (cfg.d_model * m.d_ff_expert * (3 if glu else 2)) * e \
        + cfg.d_model * m.num_experts + cfg.d_model


def _mamba_params(cfg: ModelConfig) -> int:
    mc = cfg.mamba
    d = cfg.d_model
    d_in = mc.expand * d
    r = mc.dt_rank or -(-d // 16)
    return (d * 2 * d_in + d_in * mc.d_conv + d_in
            + d_in * (r + 2 * mc.d_state) + r * d_in + d_in
            + d_in * mc.d_state + d_in + d_in * d + d)


def _rwkv_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    rc = cfg.rwkv
    tm = (6 * d + d * 5 * 32 + 5 * 32 * d          # ddlerp
          + 4 * d * d                               # r,k,v,g
          + d + d * rc.decay_lora + rc.decay_lora * d  # decay
          + d + d                                   # u, ln_x
          + d * d + d)                              # out + norm
    cm = 2 * d + d * cfg.d_ff + cfg.d_ff * d + d * d + d
    return tm + cm


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic (unpadded) parameter count; MoE active counts top_k only."""
    d, V = cfg.d_model, cfg.vocab_size
    total = V * d + d                                 # embed + final norm
    if not cfg.tie_embeddings:
        total += d * V
    def layer_cost(mixer: str, ffn: str) -> int:
        if mixer == "rwkv_tm":
            return _rwkv_params(cfg)                 # tm+cm combined
        c = _attn_params(cfg) if mixer == "attn" else _mamba_params(cfg)
        c += _mlp_params(cfg) if ffn == "mlp" else _moe_params(cfg, active_only)
        return c

    if cfg.num_encoder_layers > 0:
        per = _attn_params(cfg) + _mlp_params(cfg)
        xattn = _attn_params(cfg)
        total += cfg.num_encoder_layers * per + d
        total += cfg.num_layers * (per + xattn)
        return total
    if cfg.family in ("dense", "vlm"):
        total += cfg.num_layers * layer_cost("attn", "mlp")
    elif cfg.family == "moe":
        total += cfg.num_layers * layer_cost("attn", "moe")
    elif cfg.family == "ssm":
        total += cfg.num_layers * _rwkv_params(cfg)
    elif cfg.family == "hybrid":
        from repro.models.lm import layer_plan
        plan, n_groups = layer_plan(cfg)
        total += n_groups * sum(layer_cost(m, f) for m, f in plan)
    return total
