"""Encoder-decoder backbone (seamless-m4t-medium).

Audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings [B, S_enc, D] delivered by input_specs().
The decoder is a standard causal stack with per-layer cross-attention.
"""
from __future__ import annotations

import copy
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SystemConfig
from repro.core.fcdp import gather_param
from repro.core.partition import ParamDef, label_tree
from repro.core.strategy import resolve_strategies
from repro.models import stack as stk
from repro.models.common import MeshInfo, pad_vocab
from repro.models.layers import chunked_tp_softmax_xent, embed_lookup, rms_norm

ENC_PLAN = [("attn", "mlp")]
DEC_PLAN = [("attn", "xattn", "mlp")]


class EncDec:
    def __init__(self, cfg: ModelConfig, sys: SystemConfig, mesh):
        assert cfg.num_encoder_layers > 0
        self.cfg, self.sys, self.mesh = cfg, sys, mesh
        self.mi = MeshInfo.from_mesh(mesh, act_psum=sys.act_psum,
                                     quant_impl=sys.quant_impl)
        self.n_enc = cfg.num_encoder_layers
        self.n_dec = cfg.num_layers
        self.plan_enc, self.plan_dec = ENC_PLAN, DEC_PLAN
        self.vpad = pad_vocab(cfg.vocab_size, self.mi.tp)
        # labels first, then per-leaf strategy resolution (see models/lm.py)
        self._defs, self.strategy = resolve_strategies(
            sys, label_tree(self._build_defs()),
            strict=not sys.peft)  # adapter-targeting rules match post-injection
        self._plans = self.strategy.plan_tree(
            self._defs, mesh, sys.min_shard_size,
            compress_bwd=(sys.grad_compress == "int8_pod"),
            param_compress=(sys.param_compress == "int8_pod"),
            quant_impl=sys.quant_impl,
            fused_matmul=sys.fused_matmul, fused_impl=sys.fused_impl)

    def _build_defs(self):
        cfg, tp = self.cfg, self.mi.tp
        return {
            "embed": ParamDef((self.vpad, cfg.d_model), ("tp", "fsdp"),
                              init="embed"),
            "enc_blocks": stk.stack_defs(
                stk.group_defs(cfg, self.plan_enc, tp), self.n_enc),
            "enc_norm": ParamDef((cfg.d_model,), ("fsdp",), init="ones"),
            "dec_blocks": stk.stack_defs(
                stk.group_defs(cfg, self.plan_dec, tp), self.n_dec),
            "final_norm": ParamDef((cfg.d_model,), ("fsdp",), init="ones"),
            "head": ParamDef((cfg.d_model, self.vpad), ("fsdp", "tp")),
        }

    defs = property(lambda self: self._defs)
    plans = property(lambda self: self._plans)

    def with_plans(self, plans):
        """Shallow view bound to a different GatherPlan tree (async
        grad-reduce stream, see core/schedule.py)."""
        m = copy.copy(self)
        m._plans = plans
        return m

    def _encode(self, params, enc_embeds):
        """enc_embeds: [B, S_enc, D] precomputed frame embeddings (stub)."""
        S = enc_embeds.shape[1]
        ctx = {"positions": jnp.arange(S)[None, :], "causal": False}
        x = enc_embeds.astype(jnp.dtype(self.sys.compute_dtype))
        x, _, _ = stk.apply_stack(self.cfg, self.sys, self.mi, self.plan_enc,
                                  params["enc_blocks"],
                                  self._plans["enc_blocks"], x, ctx,
                                  strategy=self.strategy)
        return rms_norm(x, gather_param(params["enc_norm"],
                                        self._plans["enc_norm"]),
                        self.cfg.norm_eps)

    def loss_fn(self, params, batch):
        """batch: enc_embeds [B,S_enc,D], ids/labels/mask [B,S_dec]."""
        cfg, sys, mi = self.cfg, self.sys, self.mi
        enc_out = self._encode(params, batch["enc_embeds"])
        ids, labels = batch["ids"], batch["labels"]
        S = ids.shape[1]
        table = gather_param(params["embed"], self._plans["embed"])
        x = embed_lookup(table, ids, mi).astype(
            jnp.dtype(sys.compute_dtype))
        ctx = {"positions": jnp.arange(S)[None, :], "causal": True,
               "enc_out": enc_out}
        x, _, aux = stk.apply_stack(cfg, sys, mi, self.plan_dec,
                                    params["dec_blocks"],
                                    self._plans["dec_blocks"], x, ctx,
                                    strategy=self.strategy)
        x = rms_norm(x, gather_param(params["final_norm"],
                                     self._plans["final_norm"]), cfg.norm_eps)
        head = gather_param(params["head"], self._plans["head"])
        loss_sum, cnt = chunked_tp_softmax_xent(
            x, head, labels, mi, cfg.vocab_size, sys.loss_chunk,
            batch.get("mask"))
        return loss_sum, cnt, aux

    def init_decode_state(self, batch_local: int, max_len: int,
                          enc_len: int, seq_sharded: bool = False):
        return stk.init_group_state(self.cfg, self.plan_dec, self.mi,
                                    batch_local, max_len, self.n_dec,
                                    seq_sharded, enc_len=enc_len)

    def prefill_fn(self, params, enc_embeds, ids, state):
        """Encode source + run decoder prefix, filling decode state."""
        enc_out = self._encode(params, enc_embeds)
        S = ids.shape[1]
        table = gather_param(params["embed"], self._plans["embed"])
        x = embed_lookup(table, ids, self.mi).astype(
            jnp.dtype(self.sys.compute_dtype))
        ctx = {"positions": jnp.arange(S)[None, :], "causal": True,
               "enc_out": enc_out, "prefill": True}
        x, new_state, _ = stk.apply_stack(
            self.cfg, self.sys, self.mi, self.plan_dec, params["dec_blocks"],
            self._plans["dec_blocks"], x, ctx, state,
            strategy=self.strategy)
        x = rms_norm(x, gather_param(params["final_norm"],
                                     self._plans["final_norm"]),
                     self.cfg.norm_eps)
        head = gather_param(params["head"], self._plans["head"])
        logits = x[:, -1:] @ head
        return logits[:, 0], new_state

    def decode_fn(self, params, tok, state, seq_sharded: bool = False):
        table = gather_param(params["embed"], self._plans["embed"])
        x = embed_lookup(table, tok, self.mi).astype(
            jnp.dtype(self.sys.compute_dtype))
        ctx = {"decode": True, "seq_sharded": seq_sharded}
        x, new_state, _ = stk.apply_stack(
            self.cfg, self.sys, self.mi, self.plan_dec, params["dec_blocks"],
            self._plans["dec_blocks"], x, ctx, state,
            strategy=self.strategy)
        x = rms_norm(x, gather_param(params["final_norm"],
                                     self._plans["final_norm"]),
                     self.cfg.norm_eps)
        head = gather_param(params["head"], self._plans["head"])
        logits = x @ head
        return logits[:, 0], new_state
