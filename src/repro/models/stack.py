"""Generic layer-stack machinery: build stacked ParamDefs for a repeating
group of heterogeneous sublayers, and apply them under scan with the
FCDP gather + remat schedule.

A "plan" is a list of positions; each position is a tuple of sublayer
kinds. The whole group repeats `n_groups` times (params stacked on a
leading 'stack' dim, applied with jax.lax.scan).

This module owns the model-specific part only -- building per-position
sublayer bodies and dispatching them. WHICH gather runs when is the
streaming gather scheduler's job (``core/schedule.py``):
``apply_stack`` hands its group body to a :class:`GatherScheduler`,
which runs either the sequential schedule (each scan step fuses its own
two-stage gather; ``SystemConfig.prefetch_depth == 0``) or the depth-k
prefetch schedule (a ring buffer of k in-flight stage-1 / DCN gather
caches riding the scan carry, so layer i+k's DCN transfer overlaps
layer i's compute and the backward reads the carried caches back
instead of re-gathering). Both the stateless scan (training loss /
encoder) and the stateful prefill/decode scan run under the scheduler;
strategy gating and the memory trade are documented in
``core/schedule.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SystemConfig
from repro.core.fcdp import checkpoint_layer
from repro.core.partition import ParamDef, tree_map_defs
from repro.core.schedule import GatherScheduler
from repro.core.strategy import GatherPlan, resolve_strategy
from repro.models import sublayers as sl
from repro.models.common import MeshInfo

_is_plan = lambda x: isinstance(x, GatherPlan)  # noqa: E731

KIND_DEFS = {
    "attn": sl.attn_defs,
    "xattn": sl.xattn_defs,
    "mlp": sl.mlp_defs,
    "moe": sl.moe_defs,
    "mamba": sl.mamba_defs,
    "rwkv_tm": sl.rwkv_tm_defs,
    "rwkv_cm": sl.rwkv_cm_defs,
}

STATEFUL_KINDS = ("attn", "xattn", "mamba", "rwkv_tm", "rwkv_cm")


def group_defs(cfg: ModelConfig, plan: List[Tuple[str, ...]], tp: int,
               sys: Optional[SystemConfig] = None
               ) -> Dict[str, Dict[str, Dict[str, ParamDef]]]:
    """Unstacked defs for one group: {pos{i}: {kind: {param: def}}}."""
    out: Dict[str, Any] = {}
    for i, kinds in enumerate(plan):
        pos: Dict[str, Any] = {}
        for kind in kinds:
            if kind == "moe":
                pos[kind] = sl.moe_defs(
                    cfg, tp, weight_resident=bool(
                        sys and sys.moe_weight_resident))
            else:
                pos[kind] = KIND_DEFS[kind](cfg, tp)
        out[f"pos{i}"] = pos
    return out


def stack_defs(defs, n_groups: int):
    """Prepend the scan ('stack') dimension to every def."""
    def add_stack(d: ParamDef) -> ParamDef:
        return dataclasses.replace(
            d, shape=(n_groups,) + d.shape, dims=("stack",) + d.dims)
    return tree_map_defs(add_stack, defs)


def apply_sublayer(kind: str, cfg, sys, mi, p, x, ctx: Dict[str, Any],
                   state=None):
    """Dispatch one sublayer. Returns (x, new_state, aux)."""
    if kind == "attn":
        if ctx.get("paged"):
            x, new_state = sl.attn_paged(
                cfg, sys, mi, p, x, state, ctx["positions"],
                ctx["page_table"],
                prefill=bool(ctx.get("prefill_chunk")))
            return x, new_state, 0.0
        if ctx.get("decode"):
            x, new_state = sl.attn_decode(
                cfg, sys, mi, p, x, state,
                seq_sharded=ctx.get("seq_sharded", False))
            return x, new_state, 0.0
        x, new_cache = sl.attn_apply(
            cfg, sys, mi, p, x, ctx["positions"],
            causal=ctx.get("causal", True),
            kv_cache=(state["k"], state["v"], state["idx"])
            if (state is not None and ctx.get("prefill")) else None)
        if new_cache is not None:
            k, v, idx = new_cache
            return x, {"k": k, "v": v, "idx": idx}, 0.0
        return x, state, 0.0
    if kind == "xattn":
        if ctx.get("prefill") and state is not None:
            # project encoder output once; store for decode
            k, v = sl.xattn_make_kv(cfg, mi, p, ctx["enc_out"])
            state = {"k": k.astype(state["k"].dtype),
                     "v": v.astype(state["v"].dtype)}
            x, _ = sl.xattn_apply(cfg, sys, mi, p, x, (k, v))
            return x, state, 0.0
        if ctx.get("decode"):
            x, _ = sl.xattn_apply(cfg, sys, mi, p, x,
                                  (state["k"], state["v"]))
            return x, state, 0.0
        k, v = sl.xattn_make_kv(cfg, mi, p, ctx["enc_out"])
        x, _ = sl.xattn_apply(cfg, sys, mi, p, x, (k, v))
        return x, state, 0.0
    if kind == "mlp":
        return sl.mlp_apply(cfg, sys, mi, p, x), state, 0.0
    if kind == "moe":
        x, aux = sl.moe_apply(cfg, sys, mi, p, x,
                              sharded=bool(ctx.get("moe_sharded")))
        return x, state, aux
    if kind == "mamba":
        if ctx.get("decode"):
            x, new_state = sl.mamba_decode(cfg, sys, mi, p, x, state)
            return x, new_state, 0.0
        if ctx.get("prefill") and state is not None:
            x, new_state = sl.mamba_prefill(cfg, sys, mi, p, x)
            return x, new_state, 0.0
        return sl.mamba_apply(cfg, sys, mi, p, x), state, 0.0
    if kind == "rwkv_tm":
        if ctx.get("decode"):
            x, new_state = sl.rwkv_tm_decode(cfg, sys, mi, p, x, state)
            return x, new_state, 0.0
        if ctx.get("prefill") and state is not None:
            x, new_state = sl.rwkv_tm_prefill(cfg, sys, mi, p, x)
            return x, new_state, 0.0
        return sl.rwkv_tm_apply(cfg, sys, mi, p, x), state, 0.0
    if kind == "rwkv_cm":
        if ctx.get("decode"):
            x, new_state = sl.rwkv_cm_decode(cfg, sys, mi, p, x, state)
            return x, new_state, 0.0
        if ctx.get("prefill") and state is not None:
            x, new_state = sl.rwkv_cm_prefill(cfg, sys, mi, p, x)
            return x, new_state, 0.0
        return sl.rwkv_cm_apply(cfg, sys, mi, p, x), state, 0.0
    raise ValueError(f"unknown sublayer kind {kind!r}")


def init_group_state(cfg, plan, mi: MeshInfo, batch_local: int,
                     max_len: int, n_groups: int,
                     seq_sharded: bool = False, enc_len: int = 0):
    """Decode state for one group, stacked over n_groups."""
    out: Dict[str, Any] = {}
    for i, kinds in enumerate(plan):
        pos: Dict[str, Any] = {}
        for kind in kinds:
            if kind == "attn":
                pos[kind] = sl.attn_init_state(cfg, mi, batch_local, max_len,
                                               seq_sharded)
            elif kind == "xattn":
                pos[kind] = sl.xattn_init_state(cfg, mi, batch_local, enc_len)
            elif kind == "mamba":
                pos[kind] = sl.mamba_init_state(cfg, mi, batch_local)
            elif kind == "rwkv_tm":
                pos[kind] = sl.rwkv_tm_init_state(cfg, mi, batch_local)
            elif kind == "rwkv_cm":
                pos[kind] = sl.rwkv_cm_init_state(cfg, mi, batch_local)
        if pos:
            out[f"pos{i}"] = pos
    # stack over groups
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), out)


def init_paged_group_state(cfg, plan, mi: MeshInfo, n_pages: int,
                           page_size: int, n_groups: int):
    """Paged decode state for one group, stacked over n_groups. The
    paged serve path shares one page table across all layers, so the
    only per-layer state is the attention KV pool itself; any other
    stateful mixer in the plan has no paged equivalent."""
    out: Dict[str, Any] = {}
    for i, kinds in enumerate(plan):
        pos: Dict[str, Any] = {}
        for kind in kinds:
            if kind == "attn":
                pos[kind] = sl.attn_init_paged_state(cfg, mi, n_pages,
                                                     page_size)
            elif kind in STATEFUL_KINDS:
                raise ValueError(
                    "paged serving supports attention-only stacks; "
                    f"plan position {i} has stateful kind {kind!r}")
        if pos:
            out[f"pos{i}"] = pos
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), out)


def apply_stack(cfg: ModelConfig, sys: SystemConfig, mi: MeshInfo,
                plan: List[Tuple[str, ...]],
                stacked_params, stacked_plans, x, ctx: Dict[str, Any],
                stacked_state=None, placement: Optional[str] = None,
                strategy=None):
    """Scan the group over the stack dimension under the streaming
    gather scheduler (core/schedule.py: sequential or depth-k prefetch).

    stacked_params: pytree with leading stack dim on every leaf.
    stacked_plans: GatherPlan tree (body-level dims, see plan_tree(stacked=True)).
    strategy: resolved ShardingStrategy or CompositeStrategy (required:
      the per-leaf resolution happens at model construction; this module
      never resolves SystemConfig.mode itself).
    Returns (x, new_stacked_state, aux_sum).
    """
    strategy = resolve_strategy(strategy)

    moe_sharded = (getattr(sys, "moe_serve_sharded", False)
                   and ctx.get("decode"))
    if moe_sharded:
        ctx = dict(ctx, moe_sharded=True)

    def make_group_body(gather_leaf):
        """Group apply; ``gather_leaf`` reconstructs one param leaf --
        the full two-stage gather on the sequential schedule, stage 2
        only when consuming the prefetched stage-1 cache."""
        def group_body(x, params_slice, state_slice):
            new_state: Dict[str, Any] = {}
            aux = jnp.float32(0)
            for i, kinds in enumerate(plan):
                key = f"pos{i}"
                pos_new = {}
                for kind in kinds:
                    p_shard = params_slice[key][kind]
                    gplan = stacked_plans[key][kind]
                    if kind == "moe" and moe_sharded:
                        # gather-free expert weights: raw shards + plans
                        p = {k: (gather_leaf(v, gplan[k])
                                 if not k.startswith("we_") else v)
                             for k, v in p_shard.items()}
                        p["_we_plans"] = {k: gplan[k] for k in p_shard
                                          if k.startswith("we_")}
                    else:
                        p = jax.tree.map(gather_leaf, p_shard, gplan,
                                         is_leaf=_is_plan)
                    st = (state_slice.get(key, {}).get(kind)
                          if state_slice else None)
                    x, st_new, a = apply_sublayer(kind, cfg, sys, mi, p, x,
                                                  ctx, st)
                    aux = aux + a
                    if st_new is not None and kind in STATEFUL_KINDS:
                        pos_new[kind] = st_new
                if pos_new:
                    new_state[key] = pos_new
            return x, new_state, aux
        return group_body

    def wrap(body):
        return checkpoint_layer(body, strategy, sys.activation_policy,
                                sys.host_offload, placement=placement)

    from repro.models.common import pvary_like
    aux0 = pvary_like(jnp.float32(0), x)
    # the gather-free sharded-MoE decode path consumes raw expert shards;
    # pre-gathering them would break its partial-contraction math
    sched = GatherScheduler(strategy, sys, mi, stacked_plans,
                            enabled=not moe_sharded)
    return sched.run(make_group_body, wrap, stacked_params, x, aux0,
                     stacked_state)
