"""Shared model-side infrastructure: mesh info carried into shard_map,
axis-aware collectives, head padding."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import pvary, typeof
from repro.launch.mesh import fsdp_axes_of


@dataclass(frozen=True)
class MeshInfo:
    """Static view of the mesh, closed over by code running inside shard_map."""
    axis_names: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    # transport for the large TP activation all-reduces (sublayer outputs):
    # 'bf16' (exact) or 'int8' (block-quantized, ~half the ICI bytes)
    act_psum: str = "bf16"
    # quantize/dequantize codepath for the int8 transports
    # (SystemConfig.quant_impl): 'jnp' | 'pallas' | 'pallas_interpret'
    quant_impl: str = "jnp"

    @classmethod
    def from_mesh(cls, mesh, act_psum: str = "bf16",
                  quant_impl: str = "jnp") -> "MeshInfo":
        return cls(tuple(mesh.axis_names),
                   tuple(mesh.shape[a] for a in mesh.axis_names),
                   act_psum, quant_impl)

    def size(self, name: str) -> int:
        return self.axis_sizes[self.axis_names.index(name)] if name in self.axis_names else 1

    @property
    def tp(self) -> int:
        return self.size("model")

    @property
    def fsdp_axes(self) -> Tuple[str, ...]:
        return fsdp_axes_of(self.axis_names)

    @property
    def dp(self) -> int:
        return math.prod(self.size(a) for a in self.fsdp_axes)

    @property
    def has_pod(self) -> bool:
        return "pod" in self.axis_names

    @property
    def seq_axis(self) -> str:
        """Axis used for sequence sharding in long-context decode."""
        return "data"


def psum_tp(x, mi: MeshInfo):
    # applied even at tp degree 1: the collective is free but the VMA
    # type transition (varying -> invarying over 'model') is required
    return jax.lax.psum(x, "model")


def psum_tp_act(x, mi: MeshInfo):
    """TP reduction for the LARGE activation tensors (sublayer outputs).
    Honors mi.act_psum: int8 transport halves the dominant ICI term on
    dense train cells (see EXPERIMENTS.md SSPerf)."""
    if mi.act_psum == "int8" and mi.tp > 1:
        from repro.core.act_compress import int8_psum
        return int8_psum(x, "model", mi.quant_impl)
    return jax.lax.psum(x, "model")


def tp_region_in(x, mi: MeshInfo):
    """Mark the entry of a column-parallel (TP) region: under
    act_psum='int8' the implicit backward all-reduce on this tensor's
    cotangent runs in int8 (Megatron g-bar compression)."""
    if mi.act_psum == "int8" and mi.tp > 1:
        vma = set(getattr(typeof(x), "vma", ()) or ())
        if "model" not in vma:
            from repro.core.act_compress import int8_bwd_psum
            return int8_bwd_psum(x, "model", mi.quant_impl)
    return x


def pmax_tp(x, mi: MeshInfo):
    return jax.lax.pmax(x, "model")


def psum_dp(x, mi: MeshInfo):
    axes = mi.fsdp_axes
    return jax.lax.psum(x, axes) if axes else x


def tp_rank(mi: MeshInfo):
    return jax.lax.axis_index("model")


def pad_heads(n_heads: int, tp: int) -> int:
    return ((n_heads + tp - 1) // tp) * tp


def pad_vocab(v: int, tp: int) -> int:
    return ((v + tp - 1) // tp) * v if False else ((v + tp - 1) // tp) * tp


def pvary_like(x, ref):
    """Lift x's varying-mesh-axes (VMA) type to match ref's.

    Zero-initialized scan carries are invarying constants, while scan
    bodies produce device-varying values; under shard_map's VMA typing
    the carry init must be pvary'd to the body's type. No-op outside
    shard_map (avals then carry no vma)."""
    want = set(getattr(typeof(ref), "vma", ()) or ())
    have = set(getattr(typeof(x), "vma", ()) or ())
    missing = tuple(want - have)
    return pvary(x, missing) if missing else x


def pvary_tree_like(tree, ref_tree):
    return jax.tree.map(pvary_like, tree, ref_tree)


def local_head_mask(mi: MeshInfo, padded_heads: int, real_heads: int):
    """[local_heads] bool mask; False for padding heads on the last TP ranks."""
    local = padded_heads // mi.tp
    start = tp_rank(mi) * local
    idx = start + jnp.arange(local)
    return idx < real_heads
