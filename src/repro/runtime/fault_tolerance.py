"""Fault tolerance: heartbeat watchdog, straggler detection, failure
injection, and the retry/restart training-loop driver.

Designed for the multi-host deployment model (each host runs the same
SPMD program): the watchdog observes *local* step completion, the
straggler monitor keeps per-step wall-time statistics, and the driver
restarts from the last checkpoint on any step failure -- including
elastic downscale to a smaller mesh via runtime/elastic.py when devices
are gone for good.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional


class HeartbeatMonitor:
    """Watchdog: flags a hang if no step completes within `timeout_s`."""

    def __init__(self, timeout_s: float = 300.0,
                 on_hang: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self.on_hang = on_hang
        self._last_beat = time.monotonic()
        self._stop = threading.Event()
        self._hung = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def beat(self, step: int = -1):
        self._last_beat = time.monotonic()

    @property
    def hung(self) -> bool:
        return self._hung.is_set()

    def _watch(self):
        while not self._stop.wait(min(self.timeout_s / 4, 5.0)):
            if time.monotonic() - self._last_beat > self.timeout_s:
                self._hung.set()
                if self.on_hang:
                    self.on_hang()
                return

    def stop(self):
        self._stop.set()


class StragglerMonitor:
    """Per-step wall-time ring buffer with z-score outlier flagging.

    On a real cluster each host reports its step time; hosts whose times
    are persistent outliers get flagged so the scheduler can migrate
    their data shards / drain them.
    """

    def __init__(self, window: int = 50, z_threshold: float = 3.0,
                 min_samples: int = 10):
        self.window = window
        self.z = z_threshold
        self.min_samples = min_samples
        self.times: Deque[float] = deque(maxlen=window)
        self.flagged_steps: List[int] = []
        self._step = 0

    def record(self, seconds: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        import math
        is_outlier = False
        if len(self.times) >= self.min_samples:
            mean = sum(self.times) / len(self.times)
            var = sum((t - mean) ** 2 for t in self.times) / len(self.times)
            std = math.sqrt(var)
            if std > 0 and (seconds - mean) / std > self.z:
                is_outlier = True
                self.flagged_steps.append(self._step)
        self.times.append(seconds)
        self._step += 1
        return is_outlier

    def summary(self) -> Dict[str, float]:
        if not self.times:
            return {}
        ts = sorted(self.times)
        return {"mean_s": sum(ts) / len(ts), "p50_s": ts[len(ts) // 2],
                "max_s": ts[-1], "n_flagged": len(self.flagged_steps)}


@dataclass
class FailureInjector:
    """Deterministic failure injection for tests/examples: raises at the
    configured steps to exercise the restart path."""
    fail_at_steps: tuple = ()
    exception: type = RuntimeError
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise self.exception(f"injected failure at step {step}")


def run_with_restarts(train_steps: int, step_fn: Callable[[int], Any],
                      save_fn: Callable[[int], None],
                      restore_fn: Callable[[], int],
                      checkpoint_every: int = 50,
                      max_restarts: int = 3,
                      monitor: Optional[StragglerMonitor] = None,
                      heartbeat: Optional[HeartbeatMonitor] = None,
                      flush_fn: Optional[Callable[[], None]] = None):
    """Checkpoint/restart driver. step_fn(step) runs one step (stateful
    via closure); restore_fn() reloads the last checkpoint and returns
    the step to resume from.

    ``flush_fn`` (optional) is called on a step failure BEFORE
    restore_fn: a schedule that carries state across the step boundary
    (the cross-step optimizer pipeline) drains its in-flight epilogue
    there, so the last completed step's update is applied rather than
    silently dropped -- load-bearing when restore_fn has no checkpoint
    to fall back to and resumes from the live state. A flush_fn failure
    (e.g. the carry's buffers were donated by the step that died) is
    swallowed: the restore that follows re-establishes a consistent
    state either way.

    ``max_restarts`` bounds CONSECUTIVE failures, not lifetime failures:
    the counter resets after a full checkpoint interval completes
    cleanly (progress reached the next save without a failure), so a
    long run with sparse transient faults does not accumulate toward
    the limit. The returned ``restarts`` is still the lifetime total.
    """
    restarts = 0            # consecutive failures since the last clean
    #                         checkpoint interval -- compared to
    #                         max_restarts
    total_restarts = 0      # lifetime count, reported in the result
    step = restore_fn()
    safe_step = step        # last step persisted (or resumed from)
    while step < train_steps:
        try:
            t0 = time.monotonic()
            step_fn(step)
            dt = time.monotonic() - t0
            if monitor is not None:
                monitor.record(dt)
            if heartbeat is not None:
                heartbeat.beat(step)
            step += 1
            if step % checkpoint_every == 0 or step == train_steps:
                save_fn(step)
                if step - safe_step >= checkpoint_every:
                    restarts = 0    # a full interval ran clean: forgive
                    #                 earlier transient failures
                safe_step = step
        except Exception:
            restarts += 1
            total_restarts += 1
            if restarts > max_restarts:
                raise
            if flush_fn is not None:
                try:
                    flush_fn()
                except Exception:
                    pass
            step = restore_fn()
            safe_step = step
    return {"final_step": step, "restarts": total_restarts,
            "consecutive_restarts": restarts,
            "stragglers": monitor.summary() if monitor else {}}
