"""Elastic scaling: rebuild the mesh from surviving devices and reshard
training state.

The flow on a pod loss (DCN partition, hardware failure):
  1. the launcher detects missing hosts (heartbeat / init timeout),
  2. `remesh()` builds the largest valid mesh from what's left
     (2x16x16 -> 16x16: drop the 'pod' axis; fewer chips -> shrink 'data')
     over exactly the surviving devices,
  3. a new StepBundle is built against the new mesh, and the last
     checkpoint is restored under the new shardings (global batch is
     preserved -- per-device batch grows, or grad-accumulation kicks in).

Checkpoints store global arrays (see checkpoint/), so restore-under-a-
different-mesh is just device_put with the new sharding tree -- for
everything EXCEPT the cross-step carry (scheduler stream 3): its leaves
carry a leading partial dim sharded over mesh axes, i.e. they are
mesh-shaped pre-reduction partials, not global state. `reshard_state`
therefore restores the carry bit-exactly only when the saved mesh
signature and the carry layout of the new bundle both match; on any
mesh change the carry is invalidated (dropped via a section-filtered
restore, never `device_put` as stale partials) and the caller must
resume one step earlier so the restart driver re-primes the pipeline --
re-running the last step rebuilds the identical carry, so no update is
silently lost.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax

from repro.launch.mesh import make_mesh


def surviving_mesh_shape(n_devices: int, tp: int = 16
                         ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) / (data, model) mesh covering
    <= n_devices with the given TP degree."""
    tp = min(tp, n_devices)
    per_pod = 256
    if n_devices >= 2 * per_pod:
        pods = n_devices // per_pod
        return (pods, per_pod // tp, tp), ("pod", "data", "model")
    data = max(n_devices // tp, 1)
    return (data, tp), ("data", "model")


def remesh(n_devices: Optional[int] = None, tp: int = 16):
    """Build the best mesh over currently-visible devices. The mesh is
    laid over exactly the first prod(shape) survivors -- NOT all visible
    devices: when the surviving shape covers fewer chips than remain
    visible (e.g. 300 survivors -> a 256-chip single-pod mesh), the
    excess devices must not be folded into the mesh."""
    avail = len(jax.devices()) if n_devices is None else n_devices
    shape, axes = surviving_mesh_shape(avail, tp)
    used = math.prod(shape)
    return make_mesh(shape, axes, devices=jax.devices()[:used])


def _mesh_signature(mesh) -> dict:
    return {"shape": [int(mesh.shape[a]) for a in mesh.axis_names],
            "axes": list(mesh.axis_names)}


def mesh_meta(mesh) -> dict:
    """Manifest ``meta`` entry recording the mesh a checkpoint was taken
    on -- what `reshard_state` compares to detect a mesh change (a
    cross-step carry never survives one)."""
    return {"mesh": _mesh_signature(mesh)}


def _carry_compatible(ckpt_manifest: dict, bundle) -> bool:
    """Whether the saved carry section can be restored bit-exactly under
    ``bundle``: the cross-step pipeline must be live, the saved mesh
    signature (when recorded) must equal the new bundle's, and the saved
    carry shapes/dtypes must match the new carry layout exactly."""
    if not bundle.cross_step:
        return False
    saved_mesh = ckpt_manifest.get("meta", {}).get("mesh")
    if saved_mesh is not None and saved_mesh != _mesh_signature(bundle.mesh):
        return False
    from repro.core.engine.train import cross_step_carry_signature
    saved = [(tuple(l["shape"]), l["dtype"])
             for l in ckpt_manifest.get("leaves", [])
             if l.get("section") == "carry"]
    return saved == cross_step_carry_signature(bundle)


def reshard_state(ckpt, step: int, bundle, example_tree: Any
                  ) -> Tuple[Any, bool]:
    """Restore a checkpoint under a (possibly different) bundle's mesh,
    carry-aware.

    bundle: the new StepBundle; example_tree: ``{"params": [...],
    "opt": {...}}`` matching the saved params/opt sections (leaf values
    may be arrays or ShapeDtypeStructs -- only structure is read; the
    carry example, when one is restorable, is derived from the bundle).

    Returns ``(state, carry_invalidated)``. ``state["carry"]`` is
    present exactly when the checkpoint held a carry AND it is
    bit-exactly restorable under this bundle (same mesh signature, same
    carry layout). ``carry_invalidated`` is True when a saved carry had
    to be dropped (mesh change, or ``cross_step_pipeline`` off at
    restore) -- the caller must then resume at ``saved_step - 1`` so the
    driver re-primes the pipeline by re-running the last step, instead
    of silently losing its update.
    """
    manifest = ckpt.manifest(step)
    has_carry = any(l.get("section") == "carry"
                    for l in manifest.get("leaves", []))
    if not has_carry:
        return (ckpt.restore(step, example_tree,
                             shardings=bundle.state_shardings()), False)
    if _carry_compatible(manifest, bundle):
        example = dict(example_tree)
        example["carry"] = bundle.cross_step_carry_sds()
        return (ckpt.restore(step, example,
                             shardings=bundle.state_shardings(
                                 with_carry=True)), False)
    # mesh-shaped carry under a different mesh (or pipeline off at
    # restore): drop it explicitly -- a stale device_put would feed the
    # next finalize partial sums from a mesh that no longer exists
    sections = tuple(sorted(example_tree))
    state = ckpt.restore(step, example_tree,
                         shardings=bundle.state_shardings(),
                         sections=sections)
    return state, True
