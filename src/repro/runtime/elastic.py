"""Elastic scaling: rebuild the mesh from surviving devices and reshard
training state.

The flow on a pod loss (DCN partition, hardware failure):
  1. the launcher detects missing hosts (heartbeat / init timeout),
  2. `remesh()` builds the largest valid mesh from what's left
     (2x16x16 -> 16x16: drop the 'pod' axis; fewer chips -> shrink 'data'),
  3. a new StepBundle is built against the new mesh, and the last
     checkpoint is restored under the new shardings (global batch is
     preserved -- per-device batch grows, or grad-accumulation kicks in).

Checkpoints store global arrays (see checkpoint/), so restore-under-a-
different-mesh is just device_put with the new sharding tree.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

import jax

from repro.launch.mesh import make_mesh


def surviving_mesh_shape(n_devices: int, tp: int = 16
                         ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) / (data, model) mesh covering
    <= n_devices with the given TP degree."""
    tp = min(tp, n_devices)
    per_pod = 256
    if n_devices >= 2 * per_pod:
        pods = n_devices // per_pod
        return (pods, per_pod // tp, tp), ("pod", "data", "model")
    data = max(n_devices // tp, 1)
    return (data, tp), ("data", "model")


def remesh(n_devices: Optional[int] = None, tp: int = 16):
    """Build the best mesh over currently-visible devices."""
    avail = len(jax.devices()) if n_devices is None else n_devices
    shape, axes = surviving_mesh_shape(avail, tp)
    used = math.prod(shape)
    return make_mesh(shape, axes)


def reshard_state(ckpt, step: int, bundle, example_tree):
    """Restore a checkpoint under a (possibly different) bundle's mesh.

    bundle: the new StepBundle; example_tree: matching structure of the
    saved state (train_params list, opt_state, ...).
    """
    from jax.sharding import NamedSharding
    train_sh = [NamedSharding(bundle.mesh, bundle.leaf_specs[i])
                for i in bundle.train_idx]
    shardings = {
        "params": train_sh,
        "opt": {"m": train_sh, "v": train_sh, "master": train_sh,
                "step": NamedSharding(
                    bundle.mesh, jax.sharding.PartitionSpec())},
    }
    return ckpt.restore(step, example_tree, shardings=shardings)
