"""Sharded checkpointing with elastic restore and a versioned manifest.

Save path writes one .npy holding the GLOBAL array per pytree leaf, plus
a JSON manifest (schema v2: step, treedef, per-leaf key paths / top-level
sections / logical shapes and dtypes, and a caller-supplied ``meta``
dict). The restore path reassembles global arrays and `device_put`s them
under the *current* mesh's shardings -- so a checkpoint written on the
2-pod mesh restores onto a 1-pod mesh (elastic downscale) or a smoke
mesh (debug), which runtime/elastic.py relies on.

Restore is validating, never silently wrong: the saved treedef, leaf
count, per-leaf paths, and logical shapes are checked against the
example tree and a :class:`CheckpointError` with a readable diff is
raised on any mismatch (e.g. a cross-step carry present in the
checkpoint but ``cross_step_pipeline`` off at restore). Callers that
*intend* a partial restore select top-level ``sections`` explicitly --
that is how runtime/elastic.py drops a mesh-shaped carry instead of
`device_put`-ing stale partials.

Async mode snapshots to host then writes on a background thread so the
training loop is not blocked (the paper-style overlap discipline applied
to I/O).
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import ml_dtypes
import numpy as np
from jax.sharding import NamedSharding

from repro.compat import flatten_with_path

# numpy cannot round-trip ml_dtypes (bf16 etc.) through np.save; store the
# raw bits and record the logical dtype in the manifest.
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}

MANIFEST_VERSION = 2


class CheckpointError(ValueError):
    """A checkpoint/restore structure mismatch (never silently truncate,
    reorder, or mis-assign leaves)."""


def _keystr(kp) -> str:
    try:
        return jax.tree_util.keystr(kp)
    except Exception:  # pragma: no cover - ancient jax
        return "".join(str(k) for k in kp)


def _section_of(kp) -> str:
    """Top-level key of one leaf's key path ('params', 'opt', 'carry',
    ...) -- what section-filtered restores select on."""
    if not kp:
        return ""
    k = kp[0]
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _path_diff(expected: Sequence[str], saved: Sequence[str]) -> str:
    """Readable diff between the example tree's leaf paths and the
    checkpoint's: what the error message shows instead of a silent
    truncation or mis-assignment."""
    exp_set, sav_set = set(expected), set(saved)
    lines: List[str] = []
    missing = [p for p in expected if p not in sav_set]
    unexpected = [p for p in saved if p not in exp_set]
    if missing:
        lines.append("  leaves expected by the example tree but absent "
                     "from the checkpoint:")
        lines += [f"    {p}" for p in missing[:8]]
        if len(missing) > 8:
            lines.append(f"    ... and {len(missing) - 8} more")
    if unexpected:
        lines.append("  leaves present in the checkpoint but not in the "
                     "example tree:")
        lines += [f"    {p}" for p in unexpected[:8]]
        if len(unexpected) > 8:
            lines.append(f"    ... and {len(unexpected) - 8} more")
    if not lines:  # same set, different order
        for i, (e, s) in enumerate(zip(expected, saved)):
            if e != s:
                lines.append(f"  first order mismatch at leaf {i}: "
                             f"example {e} vs checkpoint {s}")
                break
    return "\n".join(lines)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True,
             meta: Optional[Dict[str, Any]] = None) -> Path:
        """tree: arbitrary pytree of jax arrays / scalars. ``meta`` is an
        arbitrary JSON-serializable dict recorded in the manifest (the
        restart driver stores the mesh signature and whether a
        cross-step carry section rides along)."""
        path_leaves, treedef = flatten_with_path(tree)
        # snapshot to host memory first (cheap, lets async write proceed
        # while the next step runs; also decouples the write from any
        # donation of the live buffers by the next compiled step)
        host_leaves = [np.asarray(jax.device_get(leaf))
                       for _, leaf in path_leaves]
        leaf_meta = []
        for (kp, _), arr in zip(path_leaves, host_leaves):
            leaf_meta.append({"path": _keystr(kp),
                              "section": _section_of(kp),
                              "shape": list(arr.shape),
                              "dtype": str(arr.dtype)})
        manifest = {"version": MANIFEST_VERSION, "step": step,
                    "treedef": str(treedef), "n_leaves": len(host_leaves),
                    "meta": dict(meta or {}), "leaves": leaf_meta}
        path = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"

        def write():
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, arr in enumerate(host_leaves):
                logical = str(arr.dtype)
                if logical in _BITCAST:
                    arr = arr.view(_BITCAST[logical])
                np.save(tmp / f"leaf_{i:05d}.npy", arr)
            with open(tmp / "manifest.json", "w") as f:
                json.dump(manifest, f)
            if path.exists():
                shutil.rmtree(path)
            tmp.rename(path)          # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._async_thread = threading.Thread(target=write, daemon=True)
            self._async_thread.start()
        return path

    def wait(self):
        if self._async_thread is not None and self._async_thread.is_alive():
            self._async_thread.join()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> Dict[str, Any]:
        """The saved manifest dict (v1 checkpoints lack 'version',
        'meta', and per-leaf 'path'/'section' entries)."""
        with open(self.dir / f"step_{step:08d}" / "manifest.json") as f:
            return json.load(f)

    def _validate(self, manifest: Dict[str, Any], example_tree: Any,
                  sections: Optional[Tuple[str, ...]]) -> List[int]:
        """Check the manifest against the example tree; return the
        manifest leaf indices to load, in example-tree order."""
        version = manifest.get("version", 1)
        saved_leaves = manifest.get("leaves", [])
        n_saved = manifest.get("n_leaves", len(saved_leaves))
        if sections is not None:
            if version < 2:
                raise CheckpointError(
                    "section-filtered restore needs a manifest v2 "
                    f"checkpoint (saved version: {version})")
            idxs = [i for i, l in enumerate(saved_leaves)
                    if l.get("section") in sections]
        else:
            idxs = list(range(n_saved))
        ex_path_leaves, ex_treedef = flatten_with_path(example_tree)
        ex_paths = [_keystr(kp) for kp, _ in ex_path_leaves]
        if version >= 2:
            saved_paths = [saved_leaves[i]["path"] for i in idxs]
            if saved_paths != ex_paths:
                scope = (f"sections {sections}" if sections is not None
                         else "the full tree")
                raise CheckpointError(
                    f"checkpoint structure does not match the example "
                    f"tree for {scope} ({len(saved_paths)} saved vs "
                    f"{len(ex_paths)} expected leaves):\n"
                    + _path_diff(ex_paths, saved_paths))
            if sections is None and manifest.get("treedef") not in (
                    None, str(ex_treedef)):
                raise CheckpointError(
                    "checkpoint treedef does not match the example tree "
                    "(same leaf paths, different container structure):\n"
                    f"  saved:    {manifest['treedef']}\n"
                    f"  expected: {ex_treedef}")
            # logical-shape validation (global shapes are mesh-invariant,
            # so this holds across elastic restores; a mismatch means the
            # leaf is mesh-shaped -- e.g. a cross-step carry partial)
            for p, (_, leaf) in zip(idxs, ex_path_leaves):
                want = getattr(leaf, "shape", None)
                got = tuple(saved_leaves[p]["shape"])
                if want is not None and tuple(want) != got:
                    raise CheckpointError(
                        f"leaf {saved_leaves[p]['path']} shape mismatch: "
                        f"checkpoint {got} vs example {tuple(want)} "
                        "(mesh-shaped leaf restored under a different "
                        "mesh?)")
        else:
            if len(idxs) != len(ex_paths):
                raise CheckpointError(
                    f"checkpoint has {len(idxs)} leaves but the example "
                    f"tree has {len(ex_paths)} -- refusing to truncate "
                    "or pad a v1 restore")
            # v1 manifests have no paths but do record shapes: a
            # same-count, different-shape tree must still fail here with
            # a readable error, not later as an opaque XLA mismatch
            for i, (_, leaf) in zip(idxs, ex_path_leaves):
                want = getattr(leaf, "shape", None)
                got = tuple(saved_leaves[i].get("shape", ())) \
                    if i < len(saved_leaves) else None
                if want is not None and got is not None \
                        and tuple(want) != got:
                    raise CheckpointError(
                        f"v1 checkpoint leaf {i} shape mismatch: "
                        f"checkpoint {got} vs example {tuple(want)}")
        return idxs

    def restore(self, step: int, example_tree: Any,
                shardings: Optional[Any] = None,
                sections: Optional[Tuple[str, ...]] = None) -> Any:
        """Restore into the structure of example_tree (leaves may be
        arrays or ShapeDtypeStructs -- only structure/shape is read).

        ``shardings`` (pytree of NamedSharding, aligned with
        example_tree) places arrays under the current mesh -- the
        elastic-remesh path. ``sections`` selects top-level keys of a
        dict-rooted checkpoint (e.g. ``("params", "opt")`` to drop a
        mesh-shaped carry); the example tree must then contain exactly
        those sections. Raises :class:`CheckpointError` on any
        structural mismatch."""
        path = self.dir / f"step_{step:08d}"
        manifest = self.manifest(step)
        idxs = self._validate(manifest, example_tree, sections)
        _, treedef = jax.tree.flatten(example_tree)
        saved_leaves = manifest.get("leaves", [])
        leaves = []
        for i in idxs:
            arr = np.load(path / f"leaf_{i:05d}.npy")
            logical = saved_leaves[i]["dtype"]
            if logical in _BITCAST:
                arr = arr.view(getattr(ml_dtypes, logical))
            leaves.append(arr)
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
            if len(sh_leaves) != len(leaves):
                raise CheckpointError(
                    f"shardings tree has {len(sh_leaves)} leaves for "
                    f"{len(leaves)} data leaves -- a short shardings "
                    "tree would silently leave trailing leaves on "
                    "default placement; pass one NamedSharding per leaf "
                    "(tree-aligned with the example tree)")
            leaves = [jax.device_put(l, s) for l, s in zip(leaves, sh_leaves)]
        else:
            leaves = [jax.device_put(l) for l in leaves]
        return jax.tree.unflatten(treedef, leaves)
