"""Sharded checkpointing with elastic restore.

Save path writes one .npy per addressable shard per tensor plus a JSON
manifest (step, leaf paths, global shapes, dtypes, shard indices). The
restore path reassembles global arrays and `device_put`s them under the
*current* mesh's shardings -- so a checkpoint written on the 2-pod mesh
restores onto a 1-pod mesh (elastic downscale) or a smoke mesh (debug),
which runtime/elastic.py relies on.

Async mode snapshots to host then writes on a background thread so the
training loop is not blocked (the paper-style overlap discipline applied
to I/O).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np
from jax.sharding import NamedSharding

# numpy cannot round-trip ml_dtypes (bf16 etc.) through np.save; store the
# raw bits and record the logical dtype in the manifest.
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> Path:
        """tree: arbitrary pytree of jax arrays / scalars."""
        leaves, treedef = jax.tree.flatten(tree)
        # snapshot to host memory first (cheap, lets async write proceed
        # while the next step runs)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        path = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"

        def write():
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "treedef": str(treedef),
                        "n_leaves": len(host_leaves), "leaves": []}
            for i, arr in enumerate(host_leaves):
                logical = str(arr.dtype)
                if logical in _BITCAST:
                    arr = arr.view(_BITCAST[logical])
                np.save(tmp / f"leaf_{i:05d}.npy", arr)
                manifest["leaves"].append(
                    {"shape": list(arr.shape), "dtype": logical})
            with open(tmp / "manifest.json", "w") as f:
                json.dump(manifest, f)
            if path.exists():
                shutil.rmtree(path)
            tmp.rename(path)          # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._async_thread = threading.Thread(target=write, daemon=True)
            self._async_thread.start()
        return path

    def wait(self):
        if self._async_thread is not None and self._async_thread.is_alive():
            self._async_thread.join()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, example_tree: Any,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of example_tree. If `shardings`
        (pytree of NamedSharding) is given, arrays are placed under it --
        this is the elastic-remesh path."""
        path = self.dir / f"step_{step:08d}"
        with open(path / "manifest.json") as f:
            manifest = json.load(f)
        _, treedef = jax.tree.flatten(example_tree)
        n = manifest["n_leaves"]
        leaves = []
        for i in range(n):
            arr = np.load(path / f"leaf_{i:05d}.npy")
            logical = manifest["leaves"][i]["dtype"]
            if logical in _BITCAST:
                arr = arr.view(getattr(ml_dtypes, logical))
            leaves.append(arr)
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
            leaves = [jax.device_put(l, s) for l, s in zip(leaves, sh_leaves)]
        else:
            leaves = [jax.device_put(l) for l in leaves]
        return jax.tree.unflatten(treedef, leaves)
