"""Serving launcher: continuous batching over the paged KV cache with
the FCDP-Comm frozen parameter layout (pod-replicated, intra-sharded --
zero DCN bytes per token).

A mixed-length synthetic workload streams through the request scheduler
(``core/serve_schedule.py``): sequences are admitted the moment a batch
slot and their full KV page reservation free up, long prompts prefill in
chunks between decode steps, and finished sequences retire immediately.
``--policy static`` runs the same jitted steps with wait-for-full-batch
admission for comparison.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 16 --seq-len 128 --gen-len 16
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.base import RunConfig, ShapeCell
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.engine import StepBundle
from repro.core.engine.serve import default_paged_kv
from repro.core.kv_cache import PagedKVConfig
from repro.core.serve_schedule import PagedServeEngine, Request, summarize
from repro.launch.cli import add_system_args, system_config_from_args
from repro.launch.mesh import make_production_mesh, make_smoke_mesh


def mixed_requests(n: int, seq_len: int, gen_len: int, vocab: int,
                   seed: int = 0):
    """Mixed-length synthetic workload: prompt lengths spread over
    [gen_len, seq_len - gen_len] so short and long requests interleave."""
    rng = np.random.default_rng(seed)
    lo = min(gen_len, seq_len - gen_len)
    plens = rng.integers(max(lo, 1), seq_len - gen_len, endpoint=True,
                         size=n)
    return [Request(rid=i,
                    prompt=rng.integers(1, vocab, (int(p),)).astype(np.int32),
                    max_new_tokens=gen_len)
            for i, p in enumerate(plens)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    add_system_args(ap)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128,
                    help="max prompt+generation length per request")
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--policy", choices=["continuous", "static"],
                    default="continuous")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size (tokens per scheduler tick)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size (0 = default_paged_kv sizing)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        mesh = make_smoke_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    cell = ShapeCell("serve", "decode", args.seq_len, args.batch)
    run = RunConfig(model=cfg, shape=cell,
                    system=system_config_from_args(args, min_shard_size=8))
    bundle = StepBundle(run, mesh)
    params = bundle.init_all_params(seed=0)

    if args.page_size:
        mpps = -(-args.seq_len // args.page_size)
        from repro.core.engine.serve import paged_replicas
        slots = args.batch // paged_replicas(bundle, cell)
        kv = PagedKVConfig(page_size=args.page_size,
                           pages_per_replica=1 + slots * mpps,
                           max_pages_per_seq=mpps)
    else:
        kv = default_paged_kv(bundle, cell)
    engine = PagedServeEngine(bundle, kv, chunk=args.chunk,
                              policy=args.policy)
    requests = mixed_requests(args.requests, args.seq_len, args.gen_len,
                              cfg.vocab_size, seed=args.seed)

    t0 = time.perf_counter()
    results, wall = engine.serve(params, requests)
    summary = summarize(results, wall)
    summary["policy"] = args.policy
    summary["kv"] = {"page_size": kv.page_size,
                     "pages_per_replica": kv.pages_per_replica,
                     "max_pages_per_seq": kv.max_pages_per_seq}
    print(json.dumps(summary, indent=2))
    done = sorted(results, key=lambda r: r.rid)[0]
    print(f"request 0 (prompt {done.prompt_len}): "
          f"continuation ids[:8] = {done.tokens[:8]}")
    print(f"total (incl. compile): {time.perf_counter() - t0:.2f}s; "
          f"scheduler steps: {engine.steps}")
    return results


if __name__ == "__main__":
    main()
