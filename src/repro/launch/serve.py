"""Serving launcher: batched prefill + decode with the FCDP-Comm frozen
parameter layout (pod-replicated, intra-sharded -- zero DCN bytes per
token).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --prompt-len 64 --gen-len 32 --batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, ShapeCell, SystemConfig, shape_cell
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.engine import StepBundle
from repro.launch.mesh import make_production_mesh, make_smoke_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        mesh = make_smoke_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    max_len = args.prompt_len + args.gen_len
    cell = ShapeCell("serve", "decode", max_len, args.batch)
    run = RunConfig(model=cfg, shape=cell,
                    system=SystemConfig(min_shard_size=8))
    bundle = StepBundle(run, mesh)
    params = bundle.init_all_params(seed=0)

    prefill = bundle.make_prefill_step()
    decode = bundle.make_decode_step()
    state = bundle.init_state(cell)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    t0 = time.time()
    if cfg.num_encoder_layers > 0:
        enc = jnp.asarray(rng.standard_normal(
            (args.batch, max(args.prompt_len // 4, 8), cfg.d_model)),
            jnp.bfloat16)
        logits, state = prefill(params, enc, prompts, state)
    else:
        logits, state = prefill(params, prompts, state)
    t_prefill = time.time() - t0

    # vocab is TP-sharded: argmax across shards via full gather of the
    # (small) per-rank argmax candidates
    def pick(logits_sharded):
        full = jax.jit(lambda x: x, out_shardings=jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()))(logits_sharded)
        return jnp.argmax(full, axis=-1).astype(jnp.int32)

    tok = pick(logits)[:, None]
    generated = [tok]
    t0 = time.time()
    for _ in range(args.gen_len - 1):
        logits, state = decode(params, tok, state)
        tok = pick(logits)[:, None]
        generated.append(tok)
    t_decode = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    toks_per_s = args.batch * (args.gen_len - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.prompt_len} toks x{args.batch}: {t_prefill:.2f}s")
    print(f"decode: {toks_per_s:.1f} tok/s (batch {args.batch})")
    print(f"sample continuation ids[0,:16]: {np.asarray(out[0, :16])}")
    return out


if __name__ == "__main__":
    main()
