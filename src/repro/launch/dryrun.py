import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, print memory/cost analysis, and persist
roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --cell train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --all --mode zero3
"""
import argparse
import gc
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import (RunConfig, SystemConfig, shape_cell,
                                SHAPE_CELLS)
from repro.configs.registry import (ARCH_IDS, cell_supported, get_config)
from repro.core.engine import StepBundle
from repro.core.strategy import DEFAULT_STRATEGY
from repro.launch.cli import add_system_args, system_config_from_args
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (collect_collectives, flops_bytes_from_jaxpr,
                                   fused_overlap_credit,
                                   parse_stablehlo_counts, roofline_report)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results"


def _mesh_sizes(mesh):
    return {a: int(mesh.shape[a]) for a in mesh.axis_names}


def dryrun_cell(arch: str, cell_name: str, multi_pod: bool,
                mode: str = DEFAULT_STRATEGY, system_overrides=None,
                verbose: bool = True, prefetch_depth=None,
                mode_overrides=(), microbatch: int = 0,
                async_grad_reduce: bool = False,
                cross_step: bool = False, param_compress: str = "none",
                fused_matmul: str = "none", system: SystemConfig = None):
    """mode_overrides: per-tensor strategy rules ((path-glob, mode), ...)
    layered on top of ``mode`` -- the dry-run reports the per-group
    byte breakdown whenever the resolution is mixed.

    cross_step lowers the STEADY-STATE (piped) step of the cross-step
    optimizer pipeline (requires async_grad_reduce and microbatch >= 2);
    its per-step DCN volume is byte-identical to the fused step, and the
    JSON additionally carries ``cross_step_buffer_bytes_per_chip``.

    system: a pre-built SystemConfig (the shared launch/cli.py surface)
    used as-is, superseding the individual knob kwargs above; the
    dry-run still pins its loss_chunk=2048 + block_io policy (the
    HBM-fitting defaults every table is defined on) unless
    system_overrides says otherwise."""
    cfg = get_config(arch)
    cell = shape_cell(cell_name)
    if system is not None:
        mode = system.mode
    ok, why = cell_supported(cfg, cell)
    if not ok:
        return {"arch": arch, "cell": cell_name, "multi_pod": multi_pod,
                "mode": mode, "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    # block_io (full activation remat) is the HBM-fitting default on
    # 16 GB v5e at the assigned shapes; the paper-faithful save_all
    # variant is compared in benchmarks/bench_memory.py (see EXPERIMENTS.md)
    if system is None:
        if prefetch_depth is None:
            prefetch_depth = 1      # dry-run's historical overlap-on default
        system = SystemConfig(mode=mode, prefetch_depth=prefetch_depth,
                              async_grad_reduce=async_grad_reduce,
                              cross_step_pipeline=cross_step,
                              param_compress=param_compress,
                              fused_matmul=fused_matmul,
                              mode_overrides=tuple(mode_overrides or ()))
    sysc = system.replace(loss_chunk=2048, activation_policy="block_io")
    if system_overrides:
        sysc = sysc.replace(**system_overrides)
    fused_matmul = sysc.fused_matmul
    run = RunConfig(model=cfg, shape=cell, system=sysc,
                    microbatch=microbatch)
    t0 = time.time()
    bundle = StepBundle(run, mesh)
    # the depth the streaming gather scheduler actually runs at on this
    # (mode x mesh x cell) -- mirrored into the roofline overlap model.
    # The scheduler drives serve scans too; cells whose plans have no
    # stage 1 (serve_frozen fcdp layouts) report ~zero pod-AG bytes and
    # get no credit regardless.
    from repro.core.cache import cache_bytes_per_chip
    kv = None
    if cell.kind == "decode":
        from repro.core.engine.serve import check_paged_plan, default_paged_kv
        try:
            check_paged_plan(bundle.model)
            kv = default_paged_kv(bundle, cell)
        except ValueError:
            kv = None       # paged serving not supported for this plan
    acct = cache_bytes_per_chip(bundle, kv=kv)
    depth_live = acct["prefetch_depth"]
    seq_sharded = (cell.name == "long_500k")
    if cell.kind == "train":
        step = bundle.make_train_step()
        sds = bundle.train_input_sds()
    elif cell.kind == "prefill":
        step = bundle.make_prefill_step()
        sds = bundle.prefill_input_sds()
    else:
        step = bundle.make_decode_step(seq_sharded=seq_sharded)
        sds = bundle.decode_input_sds(seq_sharded=seq_sharded)

    lowered = step.lower(*sds)
    t_lower = time.time() - t0
    slo_counts = parse_stablehlo_counts(lowered.as_text())
    # jaxpr walk for exact collective accounting (axis attribution + scan
    # trip counts; compiled HLO on CPU CSEs remat'd gathers, so the jaxpr
    # is the faithful source -- see DESIGN.md)
    closed = step.trace(*sds).jaxpr
    n_chips = mesh.devices.size
    stats = collect_collectives(closed, _mesh_sizes(mesh))
    flops_exact, bytes_naive = flops_bytes_from_jaxpr(closed, n_chips)

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops_ca = float(ca.get("flops", 0.0))     # lower bound: loops counted 1x
    bytes_ca = float(ca.get("bytes accessed", 0.0))
    fused_credit = fused_overlap_credit(
        bundle.def_leaves, bundle.plan_leaves, _mesh_sizes(mesh), cell,
        tp=bundle.mi.tp)
    rep = roofline_report(
        flops_exact, bytes_naive, stats, cfg, cell, n_chips,
        prefetch=depth_live,
        inflight_bytes=acct["prefetch_buffer_bytes_per_chip"],
        group_bytes=acct["by_group"],
        cross_step=acct["cross_step"],
        cross_step_bytes=acct["cross_step_buffer_bytes_per_chip"],
        fused=fused_credit)
    result = {
        "arch": arch, "cell": cell_name, "multi_pod": multi_pod,
        "mode": mode, "status": "ok",
        "mode_overrides": list(map(list, sysc.mode_overrides)),
        "n_chips": n_chips,
        "prefetch_depth": depth_live,
        "prefetch_buffer_bytes_per_chip":
            acct["prefetch_buffer_bytes_per_chip"],
        "async_buffer_bytes_per_chip":
            acct["async_buffer_bytes_per_chip"],
        "cross_step": acct["cross_step"],
        "cross_step_buffer_bytes_per_chip":
            acct["cross_step_buffer_bytes_per_chip"],
        "param_compress": acct["param_compress"],
        "kv_page_bytes_per_chip": acct["kv_page_bytes_per_chip"],
        "fused_matmul": fused_matmul,
        "fused_n_leaves": fused_credit["n_fused_leaves"],
        "fused_overlap_credit_s": fused_credit["credit_s"],
        "stage1_dcn_gather_bytes_per_chip":
            acct["stage1_dcn_gather_bytes_per_chip"],
        "stage1_dcn_gather_bytes_exact":
            acct["stage1_dcn_gather_bytes_exact"],
        "cache_by_group": acct["by_group"],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_est_bytes": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "flops_per_chip": flops_exact,
        "bytes_per_chip": bytes_naive,
        "flops_cost_analysis": flops_ca,
        "bytes_cost_analysis": bytes_ca,
        "stablehlo_collectives": slo_counts,
        "roofline": rep,
    }
    if verbose:
        mem = result["memory"]
        print(f"[{arch} x {cell_name} x {'2pod' if multi_pod else '1pod'} "
              f"x {mode}] compile={t_compile:.1f}s "
              f"args={mem['argument_bytes']/2**30:.2f}GiB "
              f"temp={mem['temp_bytes']/2**30:.2f}GiB "
              f"flops/chip={flops_exact:.3e} "
              f"dom={rep['dominant']} roofline={rep['roofline_fraction']:.3f}")
        print(f"  memory_analysis: {ma}")
        print(f"  cost_analysis (1x-loop lower bounds): "
              f"flops={flops_ca:.4g} bytes={bytes_ca:.4g}")
    del compiled, lowered, step, bundle
    gc.collect()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--cell", default=None,
                    choices=[c.name for c in SHAPE_CELLS] + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    # the dry-run keeps its historical overlap-on default (depth 1);
    # --prefetch-depth 0 is the old --no-prefetch
    add_system_args(ap, default_prefetch_depth=1)
    ap.add_argument("--microbatch", type=int, default=0,
                    help="gradient-accumulation microbatches for train "
                         "cells (required >= 2 for --cross-step-pipeline)")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x cell) on both meshes")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.cross_step_pipeline and (not args.async_grad_reduce
                                     or args.microbatch < 2):
        # catch flag misuse at the CLI, not as a per-cell "system bug"
        # traceback inside the sweep loop
        ap.error("--cross-step-pipeline requires --async-grad-reduce "
                 "and --microbatch >= 2")

    RESULTS_DIR.mkdir(exist_ok=True)
    results = []
    if args.all:
        combos = [(a, c.name, mp) for a in ARCH_IDS for c in SHAPE_CELLS
                  for mp in (False, True)]
    else:
        archs = [args.arch] if args.arch else list(ARCH_IDS)
        cells = [args.cell] if args.cell else [c.name for c in SHAPE_CELLS]
        pods = []
        if args.multi_pod or not args.single_pod:
            pods.append(True)
        if args.single_pod or not args.multi_pod:
            pods.append(False)
        combos = [(a, c, mp) for a in archs for c in cells for mp in pods]

    sysc = system_config_from_args(args)
    failures = 0
    for arch, cell, mp in combos:
        try:
            r = dryrun_cell(arch, cell, mp, system=sysc,
                            microbatch=args.microbatch)
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            r = {"arch": arch, "cell": cell, "multi_pod": mp,
                 "mode": args.mode, "status": "FAILED",
                 "error": f"{type(e).__name__}: {e}"}
            failures += 1
        results.append(r)
        if r["status"] == "skipped":
            print(f"[{arch} x {cell} x {'2pod' if mp else '1pod'}] "
                  f"SKIP: {r['reason']}")

    out = args.out or (RESULTS_DIR / (
        f"dryrun_{args.mode}"
        f"{'_mixed' if sysc.mode_overrides else ''}.json"))
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"\nwrote {out}; {len(results)} cells, {failures} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
