"""One CLI/config surface for every launcher.

``add_system_args(parser)`` installs the SystemConfig-shaped flags and
``system_config_from_args(args, **overrides)`` builds the config, so
``launch/train.py``, ``launch/dryrun.py``, ``launch/serve.py`` and the
benchmark harness (``benchmarks/harness``) all expose the SAME knobs
with the same spellings and defaults. Before this module each launcher
carried its own argparse block and the flags had drifted (train grew
``--prefetch`` while dryrun spelled it ``--no-prefetch``; dryrun never
learned ``--quant-impl``/``--fused-impl`` at all).

Migration note (one release): the boolean prefetch surface is GONE from
the CLIs -- ``--prefetch``/``--no-prefetch`` are replaced by the single
``--prefetch-depth N`` knob (0 = sequential schedule, k = depth-k
streaming ring). The ``SystemConfig(prefetch=...)`` constructor bool
still works but emits a DeprecationWarning and will be removed next
release; pass ``prefetch_depth`` instead.
"""
from __future__ import annotations

import argparse

from repro.configs.base import ACTIVATION_POLICIES, SystemConfig
from repro.core.strategy import (DEFAULT_STRATEGY, parse_mode_override,
                                 strategy_names)

# flags whose argparse dest maps 1:1 onto a SystemConfig field
_PASSTHROUGH = ("mode", "peft", "lora_rank", "lora_alpha",
                "activation_policy", "loss_chunk",
                "grad_compress", "param_compress", "quant_impl",
                "fused_matmul", "fused_impl", "async_grad_reduce",
                "cross_step_pipeline", "device_cache_fraction")


def add_system_args(parser: argparse.ArgumentParser, *,
                    default_prefetch_depth: int | None = None,
                    ) -> argparse._ArgumentGroup:
    """Install the shared SystemConfig flags on ``parser``.

    default_prefetch_depth: what ``--prefetch-depth`` means when the
    flag is absent (train/serve: None -> SystemConfig's own default of
    0; dryrun keeps its historical overlap-on default of 1).
    """
    g = parser.add_argument_group(
        "system", "distributed-system knobs (shared across launchers)")
    g.add_argument("--mode", default=DEFAULT_STRATEGY,
                   choices=list(strategy_names()),
                   help="sharding strategy for every param not claimed "
                        "by a --mode-override rule")
    g.add_argument("--mode-override", action="append", default=[],
                   metavar="GLOB=MODE",
                   help="per-tensor strategy override rule matched "
                        "against dotted param paths, first match wins; "
                        "repeatable (e.g. --mode-override "
                        "'blocks.*.moe.we_*=mics')")
    g.add_argument("--prefetch-depth", type=int,
                   default=default_prefetch_depth,
                   help="ring depth of the streaming gather scheduler "
                        "(0 = sequential paper-faithful schedule; "
                        f"default {default_prefetch_depth or 0}). "
                        "Replaces the removed --prefetch/--no-prefetch "
                        "booleans.")
    g.add_argument("--async-grad-reduce", action="store_true",
                   help="overlap microbatch i's pod-axis grad reduce "
                        "with microbatch i+1's forward (needs "
                        "microbatch > 1)")
    g.add_argument("--cross-step-pipeline", action="store_true",
                   help="carry step i's optimizer epilogue (last pod "
                        "reduce + update + widened gather) across the "
                        "step boundary and overlap it with step i+1's "
                        "first forward (needs --async-grad-reduce and "
                        "microbatch >= 2; bit-identical results)")
    g.add_argument("--device-cache-fraction", type=float, default=0.0,
                   help="FCDP-Cache tau: fraction of layers allowed to "
                        "keep the cached stage-1 shard on device")
    g.add_argument("--peft", action="store_true",
                   help="FCDP-Comm: freeze the trunk, train LoRA "
                        "adapters, communicate only trainables over DCN")
    g.add_argument("--lora-rank", type=int, default=8,
                   help="LoRA adapter rank r (with --peft)")
    g.add_argument("--lora-alpha", type=float, default=None,
                   help="LoRA alpha; the adapter term is scaled by "
                        "alpha/rank (default: 2*rank, i.e. scale 2.0)")
    g.add_argument("--lora-targets", default=None,
                   metavar="NAME[,NAME...]",
                   help="comma-separated projection names to inject "
                        "adapters into (default: wq,wk,wv,wo)")
    g.add_argument("--activation-policy", default="save_all",
                   choices=ACTIVATION_POLICIES)
    g.add_argument("--loss-chunk", type=int, default=0,
                   help="chunked cross-entropy (0 = unchunked)")
    g.add_argument("--grad-compress", default="none",
                   choices=("none", "int8_pod"),
                   help="qgZ: int8 block-quantized pod-axis gradient "
                        "reduce-scatter")
    g.add_argument("--param-compress", default="none",
                   choices=("none", "int8_pod"),
                   help="qwZ: int8-transported stage-1 weight all-gather")
    g.add_argument("--quant-impl", default="jnp",
                   choices=("jnp", "pallas", "pallas_interpret"),
                   help="codepath for the int8 quantize/dequantize steps")
    g.add_argument("--fused-matmul", default="none",
                   choices=("none", "ag_matmul", "both"),
                   help="gather-fused collective matmul: consume stage-2 "
                        "shards as the ppermute ring delivers them "
                        "(ag_matmul = fused fwd, bit-parity bwd; both = "
                        "bwd ring-fused too)")
    g.add_argument("--fused-impl", default="jnp",
                   choices=("jnp", "pallas", "pallas_interpret"),
                   help="codepath for the per-chunk matmul inside the "
                        "fused ring")
    return g


def system_config_from_args(args: argparse.Namespace,
                            **overrides) -> SystemConfig:
    """Build the SystemConfig from a parser that went through
    add_system_args. ``overrides`` are launcher-supplied fields outside
    the shared surface (min_shard_size, serve_frozen, ...) and win over
    the parsed flags."""
    kw = {f: getattr(args, f) for f in _PASSTHROUGH}
    kw["mode_overrides"] = tuple(parse_mode_override(s)
                                 for s in args.mode_override)
    kw["prefetch_depth"] = args.prefetch_depth
    if getattr(args, "lora_targets", None):
        kw["lora_targets"] = tuple(
            t.strip() for t in args.lora_targets.split(",") if t.strip())
    kw.update(overrides)
    return SystemConfig(**kw)
