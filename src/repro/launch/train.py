"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --mode fcdp

--smoke runs the reduced config of the same family on the local CPU
devices; the full configs target the production meshes (dry-run them
with repro.launch.dryrun). Includes checkpoint/restart, heartbeat,
straggler monitoring, and optional failure injection (--fail-at).
"""
from __future__ import annotations

import argparse
import functools
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import (OptimizerConfig, RunConfig, ShapeCell,
                                shape_cell)
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.engine import StepBundle
from repro.data.pipeline import DataConfig, ShardedLoader, SyntheticPackedLM
from repro.launch.cli import add_system_args, system_config_from_args
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.optim.adamw import init_opt_state
from repro.runtime.elastic import mesh_meta, reshard_state
from repro.runtime.fault_tolerance import (FailureInjector, HeartbeatMonitor,
                                           StragglerMonitor,
                                           run_with_restarts)


def build(args):
    if args.smoke:
        cfg = get_smoke_config(args.arch)
        # --multi-pod with --smoke carves a 2-wide pod axis (>= 8 local
        # devices) so the DCN-facing streams run on the toy mesh too
        mesh = make_smoke_mesh(multi_pod=args.multi_pod)
        cell = ShapeCell("smoke_train", "train", args.seq_len or 128,
                         args.batch or 8)
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cell = shape_cell(args.cell)
    sysc = system_config_from_args(
        args, min_shard_size=8 if args.smoke else 2048)
    run = RunConfig(model=cfg, shape=cell, system=sysc,
                    optimizer=OptimizerConfig(
                        lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 1)),
                    microbatch=args.microbatch)
    return RunState(run, mesh, args)


class RunState:
    def __init__(self, run, mesh, args):
        self.run, self.mesh, self.args = run, mesh, args
        self.bundle = StepBundle(run, mesh)
        self.step_fn = self.bundle.make_train_step()
        # cross-step pipeline (stream 3): the steady-state step carries
        # the previous step's optimizer epilogue; prime fills the
        # pipeline, flush drains it (end of run / before checkpoints)
        self.cross_step = self.bundle.cross_step
        self.carry = None
        self.steps_taken = 0     # steps run since init/restore (lets the
        #                          pre-loop restore of a just-written
        #                          step-0 seed skip the read-back)
        if self.cross_step:
            self.prime_fn = self.bundle.make_train_prime()
            self.flush_fn = self.bundle.make_train_flush()
        params = self.bundle.init_all_params(seed=run.seed)
        self.train_p, self.frozen_p = self.bundle.split(params)
        self.opt = jax.jit(functools.partial(
            init_opt_state, sys=run.system))(self.train_p)
        ds = SyntheticPackedLM(run.model, run.shape, DataConfig(run.seed))
        enc_dim = run.model.d_model if run.model.num_encoder_layers else 0
        self.loader = ShardedLoader(ds, mesh,
                                    self.bundle.batch_spec(run.shape),
                                    enc_embed_dim=enc_dim)
        self.metrics_log = []

    def do_train_step(self, batch):
        """One training step under whichever schedule is live. With the
        cross-step pipeline the first call primes the carry (no update);
        call flush_carry() to drain before reading/persisting state.
        Sets ``last_primed``: a primed step's grad_norm is not known yet
        (the piped step reports the PREVIOUS step's norm, the flush
        reports the last one) -- metric consumers must not read a prime
        row's 0.0 as a real norm."""
        self.last_primed = False
        self.steps_taken += 1
        if not self.cross_step:
            self.train_p, self.opt, m = self.step_fn(
                self.train_p, self.frozen_p, self.opt, batch)
        elif self.carry is None:
            self.last_primed = True
            self.carry, m = self.prime_fn(
                self.train_p, self.frozen_p, self.opt, batch)
        else:
            self.train_p, self.opt, self.carry, m = self.step_fn(
                self.train_p, self.frozen_p, self.opt, self.carry, batch)
        return m

    def flush_carry(self):
        """Finalize the outstanding cross-step epilogue, if any, so
        params/opt reflect every step taken (the next step re-primes).
        The flushed grad_norm -- the last step's, otherwise lost -- is
        appended to metrics_log as a ``flush`` row."""
        if self.carry is not None:
            self.train_p, self.opt, m = self.flush_fn(
                self.train_p, self.opt, self.carry)
            self.carry = None
            self.metrics_log.append(
                {"flush": True, "grad_norm": float(m["grad_norm"])})

    def state_tree(self):
        """The persisted training state. The cross-step carry rides
        along exactly when it is live, so a checkpoint taken
        mid-pipeline round-trips bit-exactly (manifest v2 records the
        carry section; restore validates it against the mesh)."""
        tree = {"params": self.train_p, "opt": self.opt}
        if self.carry is not None:
            tree["carry"] = self.carry
        return tree

    def load_state(self, tree):
        self.train_p, self.opt = tree["params"], tree["opt"]
        # a restored carry resumes the pipeline mid-flight; without one
        # the next do_train_step re-primes
        self.carry = tree.get("carry")
        self.steps_taken = 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--cell", default="train_4k")
    add_system_args(ap)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args(argv)

    st = build(args)
    ckpt = Checkpointer(args.ckpt_dir)
    injector = FailureInjector(fail_at_steps=tuple(args.fail_at))
    monitor = StragglerMonitor()
    hb = HeartbeatMonitor(timeout_s=600).start()

    def do_step(step: int):
        injector.maybe_fail(step)
        batch = st.loader.get(step)
        m = st.do_train_step(batch)
        loss = float(m["loss"])
        row = {"step": step, "loss": loss,
               "grad_norm": float(m["grad_norm"])}
        if st.last_primed:
            # pipeline-fill step: no norm yet (the next piped step
            # reports this step's, the flush reports the last one)
            row["primed"] = True
        st.metrics_log.append(row)
        if step % max(args.steps // 20, 1) == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f}")

    def save(step: int):
        # the checkpoint is taken mid-pipeline: the cross-step carry is
        # persisted as a manifest-v2 carry section (not flushed), so a
        # restart resumes the piped schedule bit-identically to an
        # uninterrupted run; the mesh signature in meta lets an elastic
        # restore detect that a carry never survives a mesh change
        ckpt.save(step, st.state_tree(), blocking=False,
                  meta=mesh_meta(st.mesh))

    def restore() -> int:
        # a crash can land while an async save is still writing: drain
        # it first, or latest_step() would miss the in-flight checkpoint
        # and silently resume a full interval earlier
        ckpt.wait()
        latest = ckpt.latest_step()
        if latest == 0 and st.steps_taken == 0 and st.carry is None:
            # the pre-loop restore of the step-0 seed we just wrote:
            # live state IS the checkpoint, skip the read-back
            return 0
        if latest is None:
            # nothing persisted yet: drain any in-flight epilogue so the
            # live state is post-update, and restart from the top
            st.flush_carry()
            return 0
        state, carry_invalidated = reshard_state(
            ckpt, latest, st.bundle,
            {"params": st.train_p, "opt": st.opt})
        st.load_state(state)
        if carry_invalidated:
            # the saved carry could not be restored (mesh change, or the
            # pipeline is off in this run): resume one step earlier --
            # re-running the last step re-primes the pipeline and
            # rebuilds the identical carry, so its update is re-derived
            # rather than silently lost
            resume = max(latest - 1, 0)
            print(f"restored checkpoint at step {latest}; cross-step "
                  f"carry invalidated -> re-running step {resume} to "
                  "re-prime")
            return resume
        print(f"restored checkpoint at step {latest}")
        return latest

    # persist the initial state before the first step: a failure inside
    # the first checkpoint interval then restores to a well-defined step
    # 0 instead of replaying onto partially-trained live state
    if ckpt.latest_step() is None:
        ckpt.save(0, st.state_tree(), blocking=True,
                  meta=mesh_meta(st.mesh))

    t0 = time.time()
    result = run_with_restarts(
        args.steps, do_step, save, restore,
        checkpoint_every=args.ckpt_every, monitor=monitor, heartbeat=hb,
        flush_fn=st.flush_carry)
    st.flush_carry()
    hb.stop()
    ckpt.wait()
    dt = time.time() - t0
    toks = args.steps * st.run.shape.global_batch * st.run.shape.seq_len
    final_loss = next(m["loss"] for m in reversed(st.metrics_log)
                      if "loss" in m)
    print(f"done: {result} | {dt:.1f}s | {toks/dt:.0f} tok/s | "
          f"final loss {final_loss:.4f}")
    return st


if __name__ == "__main__":
    main()
