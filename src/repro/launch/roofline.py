"""Roofline-term derivation from a lowered/compiled dry-run cell.

Three terms (seconds), per §Roofline of EXPERIMENTS.md:

  compute    = HLO_FLOPs_per_chip / PEAK_FLOPS
  memory     = HLO_bytes_per_chip / HBM_BW
  collective = ICI bytes/chip / ICI_BW  (+ DCN bytes/chip / DCN_BW, reported
               separately -- the 'pod' axis crosses DCN)

Collective bytes come from walking the traced jaxpr (exact axis
attribution, scan trip counts multiplied in); a StableHLO text parse
cross-checks op counts, since compiled HLO on the CPU backend CSEs
remat'd gathers.

Cost models (per-device bytes moved, ring algorithms):
  all_gather     (n-1)/n * result_bytes
  psum_scatter   (n-1)/n * operand_bytes
  psum           2(n-1)/n * operand_bytes
  all_to_all     (n-1)/n * operand_bytes
  ppermute       operand_bytes
Multi-axis collectives are attributed hierarchically: the ICI axes see
the full payload, the DCN ('pod') stage sees payload/prod(ici_sizes).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

# TPU v5e-ish hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (prompt-specified)
DCN_BW = 25e9                # bytes/s per chip across pods (assumed, fixed
                             # across systems so comparisons are fair)

COLLECTIVE_PRIMS = {
    "all_gather": "all_gather",
    "all_gather_invariant": "all_gather",
    "psum": "psum",
    "psum2": "psum",
    "psum_invariant": "psum",
    "psum_scatter": "psum_scatter",
    "reduce_scatter": "psum_scatter",
    "all_to_all": "all_to_all",
    "ppermute": "ppermute",
    # NOT counted: pbroadcast / pvary are replication-type casts inserted
    # by shard_map's rep machinery (pre-VMA rewrite pass resp. VMA
    # typing); the value already lives on every device, so they move
    # zero bytes and lower to nothing.
}


@dataclass
class CollectiveStats:
    """Per-device byte totals by axis-kind and op."""
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    by_op: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    by_axis: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    by_op_axis: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    count: int = 0

    def add(self, op: str, axis: str, nbytes: float, is_dcn: bool):
        if is_dcn:
            self.dcn_bytes += nbytes
        else:
            self.ici_bytes += nbytes
        self.by_op[op] += nbytes
        self.by_axis[axis] += nbytes
        self.by_op_axis[f"{op}/{axis}"] += nbytes
        self.count += 1


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape) * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _axis_tuple(params: Dict[str, Any]) -> Tuple[str, ...]:
    for key in ("axis_name", "axes", "axis_index_groups_axis", "named_axes"):
        v = params.get(key)
        if v is None:
            continue
        if isinstance(v, (tuple, list)):
            names = tuple(a for a in v if isinstance(a, str))
            if names:
                return names
        elif isinstance(v, str):
            return (v,)
        elif isinstance(v, dict):
            names = tuple(a for a in v if isinstance(a, str))
            if names:
                return names
    return ()


def _live_eqns(jx):
    """Equations whose outputs (transitively) reach jx's outputs.

    jaxpr-level DCE does not prune dead `custom_vjp_call_jaxpr` eqns --
    a remat backward recompute whose result was policy-saved (the FCDP
    host cache) leaves the quantized-gather custom vjp behind as a dead
    eqn that XLA later removes. Counting it would double the stage-1
    DCN bytes, so the walker only visits live eqns. (Literals carry a
    ``val`` attribute; Vars do not.)"""
    needed = {v for v in jx.outvars if not hasattr(v, "val")}
    live = []
    for eqn in reversed(jx.eqns):
        if any(v in needed for v in eqn.outvars):
            live.append(eqn)
            needed.update(v for v in eqn.invars if not hasattr(v, "val"))
    live.reverse()
    return live


def collect_collectives(jaxpr, mesh_sizes: Dict[str, int]) -> CollectiveStats:
    """Walk a (closed) jaxpr, summing per-device collective bytes."""
    stats = CollectiveStats()

    def visit(jx, mult: float):
        for eqn in _live_eqns(jx):
            name = eqn.primitive.name
            # recurse into sub-jaxprs
            if name == "scan":
                visit(eqn.params["jaxpr"].jaxpr,
                      mult * eqn.params.get("length", 1))
                continue
            if name == "while":
                body = eqn.params.get("body_jaxpr")
                if body is not None:
                    visit(body.jaxpr, mult)  # unknown trips: count once
                continue
            if name == "cond":
                for br in eqn.params.get("branches", []):
                    visit(br.jaxpr, mult)
                continue
            # custom_vjp_call_jaxpr carries its primal under fun_jaxpr
            # (the quantized-collective custom vjps live there -- without
            # descending, their fwd all_gathers would be invisible)
            sub = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                   or eqn.params.get("fun_jaxpr"))
            if sub is not None:
                visit(sub.jaxpr if hasattr(sub, "jaxpr") else sub, mult)
                continue
            kind = COLLECTIVE_PRIMS.get(name)
            if kind is None:
                continue
            axes = _axis_tuple(eqn.params)
            if not axes:
                continue
            if kind == "all_gather":
                payload = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            else:
                payload = sum(_aval_bytes(v.aval) for v in eqn.invars
                              if hasattr(v, "aval"))
            # hierarchical attribution over the named axes
            ici_axes = [a for a in axes if a != "pod"]
            dcn_axes = [a for a in axes if a == "pod"]
            ici_n = math.prod(mesh_sizes.get(a, 1) for a in ici_axes) or 1
            for a in ici_axes:
                n = mesh_sizes.get(a, 1)
                if n <= 1:
                    continue
                factor = {"all_gather": (n - 1) / n,
                          "psum_scatter": (n - 1) / n,
                          "psum": 2 * (n - 1) / n,
                          "all_to_all": (n - 1) / n,
                          "ppermute": 1.0}[kind]
                stats.add(kind, a, mult * factor * payload, is_dcn=False)
            for a in dcn_axes:
                n = mesh_sizes.get(a, 1)
                if n <= 1:
                    continue
                factor = {"all_gather": (n - 1) / n,
                          "psum_scatter": (n - 1) / n,
                          "psum": 2 * (n - 1) / n,
                          "all_to_all": (n - 1) / n,
                          "ppermute": 1.0}[kind]
                # DCN stage moves the ICI-reduced payload
                stats.add(kind, a, mult * factor * payload / ici_n,
                          is_dcn=True)
    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, 1.0)
    return stats


def flops_bytes_from_jaxpr(jaxpr, n_chips: int) -> Tuple[float, float]:
    """Exact per-device FLOPs (dot_general/conv) and naive HBM bytes from
    the traced jaxpr, with scan trip counts multiplied in.

    XLA's compiled cost_analysis counts while-loop bodies ONCE, so scanned
    layer stacks are undercounted by ~num_layers; this walker is the
    faithful source. Bytes are an upper bound (per-eqn operand+result
    sizes, no fusion credit); cost_analysis 'bytes accessed' is the
    corresponding lower bound. Eqns outside shard_map carry global shapes
    and are scaled by 1/n_chips.
    """
    total_flops = 0.0
    total_bytes = 0.0

    # HBM-traffic model: count operand+result bytes of the ops whose
    # operands genuinely stream from HBM (matmuls, convs, gathers/scatters,
    # cache updates, collectives); elementwise chains are assumed fused
    # into their producers (XLA does this), else the norm upcasts would
    # dominate and every cell would look memory-bound.
    MAJOR_BYTES_PRIMS = {
        "dot_general", "conv_general_dilated", "gather", "scatter",
        "scatter-add", "scatter_add", "dynamic_update_slice",
        "dynamic_slice", "sort", "take", "cumsum", "cumlogsumexp",
        "all_gather", "all_gather_invariant", "psum", "psum2",
        "psum_invariant", "psum_scatter", "all_to_all", "ppermute",
    }

    def eqn_bytes(eqn) -> float:
        b = 0.0
        for v in eqn.invars:
            if hasattr(v, "aval"):
                b += _aval_bytes(v.aval)
        for v in eqn.outvars:
            b += _aval_bytes(v.aval)
        return b

    def visit(jx, mult: float, scale: float):
        nonlocal total_flops, total_bytes
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name == "scan":
                visit(eqn.params["jaxpr"].jaxpr, mult * eqn.params.get("length", 1),
                      scale)
                continue
            if name == "while":
                body = eqn.params.get("body_jaxpr")
                if body is not None:
                    visit(body.jaxpr, mult, scale)
                continue
            if name == "cond":
                brs = eqn.params.get("branches", [])
                if brs:
                    visit(brs[0].jaxpr, mult, scale)  # count one branch
                continue
            if name == "shard_map":
                sub = eqn.params.get("jaxpr")
                visit(sub.jaxpr if hasattr(sub, "jaxpr") else sub, mult, 1.0)
                continue
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                visit(sub.jaxpr if hasattr(sub, "jaxpr") else sub, mult, scale)
                continue
            if name == "dot_general":
                dnums = eqn.params["dimension_numbers"]
                (lc, rc), _ = dnums
                lhs = eqn.invars[0].aval
                out = eqn.outvars[0].aval
                k = 1
                for d in lc:
                    k *= lhs.shape[d]
                total_flops += scale * mult * 2.0 * float(np.prod(out.shape)) * k
                total_bytes += scale * mult * eqn_bytes(eqn)
            elif name == "conv_general_dilated":
                out = eqn.outvars[0].aval
                rhs = eqn.invars[1].aval
                total_flops += scale * mult * 2.0 * float(
                    np.prod(out.shape)) * float(np.prod(rhs.shape[1:]))
                total_bytes += scale * mult * eqn_bytes(eqn)
            elif name in MAJOR_BYTES_PRIMS:
                total_bytes += scale * mult * eqn_bytes(eqn)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, 1.0, 1.0 / n_chips)
    return total_flops, total_bytes


def parse_stablehlo_counts(text: str) -> Dict[str, int]:
    """Cross-check: op counts in the lowered StableHLO."""
    ops = re.findall(
        r"stablehlo\.(all_gather|reduce_scatter|all_reduce|all_to_all|"
        r"collective_permute)", text)
    out: Dict[str, int] = defaultdict(int)
    for o in ops:
        out[o] += 1
    return dict(out)


def model_flops(cfg, cell, n_chips: int) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) tokens rule; decode counts one
    token per sequence."""
    from repro.models.registry import count_params
    n_active = count_params(cfg, active_only=True)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    tokens = cell.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens


def fused_overlap_credit(def_leaves, plan_leaves, mesh_sizes: Dict[str, int],
                         cell, tp: int = 1,
                         dtype_bytes: float = 2.0) -> Dict[str, Any]:
    """Measured per-layer overlap credit of the gather-fused collective
    matmul, derived from the fused kernel's own chunk schedule.

    For every plan flagged ``fused`` the ring replaces the stage-2 intra
    all-gather with (n-1) chunk ``ppermute`` hops issued behind the
    per-chunk matmuls -- byte-neutral on the wire (ring bytes equal the
    tiled all-gather's (n-1)/n factor), but each hop's transfer hides
    under the concurrent chunk matmul. The credit per ring pass is
    ``sum over transfer steps of min(chunk_bytes/ICI_BW,
    chunk_flops/PEAK_FLOPS)`` (kernels/collective_matmul.chunk_schedule)
    times the leaf's stack (layer) count. mode='ag_matmul' runs one ring
    per layer (the backward replays the unfused sequence for bit
    parity); mode='both' runs three identically-shaped rings (forward,
    dx, and the dw matmul->reduce-scatter dual, whose accumulator hops
    match the weight-chunk bytes and flops exactly).
    """
    from repro.kernels.collective_matmul import chunk_schedule
    tokens = (cell.global_batch * cell.seq_len if cell.kind != "decode"
              else cell.global_batch)
    dp = math.prod(s for a, s in mesh_sizes.items() if a != "model") or 1
    m_tokens = tokens / dp
    credit = 0.0
    n_leaves = 0
    modes = set()
    for d, p in zip(def_leaves, plan_leaves):
        if getattr(p, "fused", "none") == "none":
            continue
        n = mesh_sizes.get(p.intra_axes[0], 1)
        if n <= 1:
            continue
        body = [(dim, s) for dim, s in zip(d.dims, d.shape) if dim != "stack"]
        stack = (d.shape[d.dims.index("stack")]
                 if "stack" in d.dims else 1)
        k_local = body[0][1] // (tp if body[0][0] == "tp" else 1)
        n_cols_chunk = body[1][1] // n
        passes = 3 if p.fused == "both" else 1
        sched = chunk_schedule(m_tokens, k_local, n_cols_chunk, n,
                               dtype_bytes)
        per_ring = sum(min(b / ICI_BW, f / PEAK_FLOPS)
                       for b, f in sched if b > 0.0)
        credit += passes * stack * per_ring
        n_leaves += 1
        modes.add(p.fused)
    return {"enabled": n_leaves > 0,
            "mode": (sorted(modes)[0] if len(modes) == 1
                     else ",".join(sorted(modes)) if modes else "none"),
            "n_fused_leaves": n_leaves,
            "credit_s": credit}


def roofline_report(flops_per_chip: float, bytes_per_chip: float,
                    stats: CollectiveStats, cfg, cell,
                    n_chips: int, prefetch: Any = False,
                    inflight_bytes: float = 0.0,
                    group_bytes: Optional[Dict[str, Any]] = None,
                    cross_step: bool = False,
                    cross_step_bytes: float = 0.0,
                    fused: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    """Derive the three roofline terms, plus -- when the streaming
    gather scheduler's prefetch is active -- the overlap credit: the
    stage-1 (pod-axis) parameter all-gathers are issued ahead of the
    compute that consumes them, so their time hides under compute up to
    the compute term itself. The DCN link is shared, so each second of
    compute can hide at most one second of transfer regardless of how
    many gathers are in flight: in this bandwidth-only model the credit
    min(stage-1 DCN time, compute term) is the same for every depth
    >= 1. What depth > 1 buys -- latency/jitter tolerance and
    pipeline-fill slack -- is below this model's resolution; its
    visible side is the ring's HBM cost, passed in as
    ``inflight_bytes`` (core/schedule.py:prefetch_buffer_bytes, which
    DOES scale with depth) so dry-run consumers see the memory price
    next to the credit. ``prefetch`` accepts the resolved ring depth
    (an int; legacy bool means depth 1). ``collective_exposed_s`` is
    the collective time that remains on the critical path after the
    credit; modes with no stage-1 (MiCS/hier, frozen layouts,
    single-pod meshes) have zero pod-axis AG bytes and are reported
    unchanged.

    ``group_bytes`` (optional) is the per-strategy-group cache/buffer
    byte split from ``core.cache.cache_bytes_per_chip``'s ``by_group``;
    under per-tensor mixed sharding it shows which group pays which
    tier (host cache vs ring slots vs regather), echoed verbatim as
    ``groups``.

    ``fused`` (optional) is :func:`fused_overlap_credit`'s dict: the
    gather-fused collective matmul's measured per-layer overlap credit.
    The ring's ppermute hops are byte-neutral with the stage-2
    all-gather they replace (so ``collective_s`` is unchanged), but each
    hop hides under its concurrent chunk matmul; the credit is
    subtracted from the exposed collective time, clamped to the ICI
    term (a ring cannot hide more transfer than it performs).

    ``cross_step``/``cross_step_bytes`` describe scheduler stream 3 (the
    cross-step pipelined optimizer epilogue): the bandwidth model is
    unchanged -- per-step DCN volume is byte-identical, the once-per-step
    epilogue collectives merely move to the top of the next step where
    they overlap its first-microbatch prologue -- so the stream's
    visible side here is its HBM price, the step-boundary carry bytes
    (core/schedule.py:cross_step_buffer_bytes), echoed under
    ``cross_step`` for dry-run consumers.
    """
    depth = int(prefetch)
    compute_t = flops_per_chip / PEAK_FLOPS
    memory_t = bytes_per_chip / HBM_BW
    ici_t = stats.ici_bytes / ICI_BW
    dcn_t = stats.dcn_bytes / DCN_BW
    coll_t = ici_t + dcn_t
    # stage-1 parameter gathers: the overlappable DCN term
    stage1_ag_bytes = stats.by_op_axis.get("all_gather/pod", 0.0)
    overlapped_bytes = stage1_ag_bytes if depth > 0 else 0.0
    overlapped_t = min(overlapped_bytes / DCN_BW, compute_t)
    fused = dict(fused or {})
    fused_credit_t = min(float(fused.get("credit_s", 0.0)), ici_t)
    fused["credit_applied_s"] = fused_credit_t
    coll_exposed_t = max(coll_t - overlapped_t - fused_credit_t, 0.0)
    terms = {"compute": compute_t, "memory": memory_t,
             "collective": coll_exposed_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, cell, n_chips)
    hlo_total = flops_per_chip * n_chips
    return {
        "groups": dict(group_bytes or {}),
        "cross_step": {
            "enabled": bool(cross_step),
            "carry_buffer_bytes_per_chip": float(cross_step_bytes),
        },
        "fused": fused,
        "prefetch": {
            "enabled": depth > 0,
            "depth": depth,
            "inflight_stage1_bytes_per_chip": float(inflight_bytes),
            "stage1_ag_dcn_bytes_per_chip": stage1_ag_bytes,
            "overlapped_dcn_bytes_per_chip": overlapped_bytes,
            "overlapped_s": overlapped_t,
            "collective_exposed_s": coll_exposed_t,
        },
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "ici_s": ici_t,
        "dcn_s": dcn_t,
        "dominant": dominant,
        "step_time_lb_s": max(terms.values()),
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "roofline_fraction": (mf / n_chips / PEAK_FLOPS) / max(
            max(terms.values()), 1e-30),
        "ici_bytes_per_chip": stats.ici_bytes,
        "dcn_bytes_per_chip": stats.dcn_bytes,
        "coll_by_op": dict(stats.by_op),
        "coll_by_axis": dict(stats.by_axis),
        "n_collectives": stats.count,
    }
