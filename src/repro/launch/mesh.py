"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.

Mesh semantics:
  pod   - crosses DCN (slow inter-pod links). FCDP's "inter-node" axis.
  data  - intra-pod ICI; batch / ZeRO sharding. FCDP's "intra-node" axis.
  model - intra-pod ICI; tensor/expert parallelism.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax

from repro.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str], devices=None):
    """Arbitrary mesh with Auto axis types (smoke tests, elastic re-mesh).
    ``devices`` restricts the mesh to an explicit subset -- the elastic
    path passes the surviving devices so a shrunk mesh never spans chips
    the surviving shape does not cover."""
    return _compat_make_mesh(shape, axes, devices=devices)


def make_smoke_mesh(n_devices: Optional[int] = None,
                    multi_pod: bool = False):
    """Tiny mesh over locally available devices for CPU smoke tests.

    multi_pod carves a 2-wide pod axis off the front (needs >= 8
    devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8)
    so the DCN-facing scheduler streams -- stage-1 prefetch, async grad
    reduce, the cross-step pipeline -- are exercisable in smoke runs.
    """
    n = n_devices or len(jax.devices())
    if multi_pod:
        if n < 8:
            # never fall through silently: the pod-less mesh would gate
            # every DCN stream off and the run would pass vacuously
            raise ValueError(
                f"multi_pod smoke mesh needs >= 8 devices, have {n}; "
                "set XLA_FLAGS=--xla_force_host_platform_device_count=8")
        model = math.gcd(n // 2, 2)
        return make_mesh((2, n // 2 // model, model),
                         ("pod", "data", "model"))
    model = math.gcd(n, 2)
    data = n // model
    return make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def fsdp_axes_of(axis_names: Sequence[str]) -> Tuple[str, ...]:
    """ZeRO-3 sharding axes (all non-model axes), tiled INTRA-major
    (pod last): the two-stage gather runs stage 1 (pod) first, then
    stage 2 (data), so storage must be data-major for the staged
    reconstruction to land blocks in true global order. With pod-major
    tiling each stage-2 result would be a consistent block permutation
    of the weight -- invisible while every leaf shares one strategy,
    but wrong the moment per-tensor mixed sharding contracts a
    two-stage-gathered leaf against a single-stage (mics/hier/frozen)
    one. The single source of the ordering invariant: both
    ``fsdp_axes(mesh)`` and ``MeshInfo.fsdp_axes`` delegate here."""
    return (tuple(a for a in axis_names if a not in ("model", "pod"))
            + tuple(a for a in axis_names if a == "pod"))


def fsdp_axes(mesh) -> Tuple[str, ...]:
    """Axes over which ZeRO-3 shards parameters (see fsdp_axes_of)."""
    return fsdp_axes_of(mesh.axis_names)


def inter_axis(mesh) -> Optional[str]:
    """The slow (DCN) axis, if present."""
    return "pod" if "pod" in mesh.axis_names else None


def intra_fsdp_axes(mesh) -> Tuple[str, ...]:
    """Fast (ICI) fsdp axes: what FCDP re-gathers over in the backward."""
    return tuple(a for a in mesh.axis_names if a not in ("model", "pod"))


def dp_degree(mesh) -> int:
    return math.prod(mesh.shape[a] for a in fsdp_axes(mesh))


def tp_degree(mesh) -> int:
    return mesh.shape.get("model", 1)
