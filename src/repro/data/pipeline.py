"""Deterministic synthetic LM data pipeline.

Real-pipeline shape: seeded per (shard, step) so any host can regenerate
any step's data independently (fault-tolerant restart resumes mid-epoch
without coordination), sharded placement onto the mesh, packed sequences
with document boundaries and a loss mask, and a prefetch iterator.
"""
from __future__ import annotations

import threading
import queue
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell


@dataclass
class DataConfig:
    seed: int = 0
    doc_len_mean: int = 512       # packed documents, exponential lengths
    zipf_a: float = 1.2           # token distribution (heavy-tailed)
    eod_token: int = 0


class SyntheticPackedLM:
    """Zipf-token documents packed into fixed-length rows.

    Deterministic: batch(step) depends only on (seed, step), never on
    iteration history -- restarts resume exactly.
    """

    def __init__(self, cfg: ModelConfig, cell: ShapeCell, data: DataConfig):
        self.cfg, self.cell, self.data = cfg, cell, data

    def batch_np(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.cell.global_batch, self.cell.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([self.data.seed, step]))
        v = self.cfg.vocab_size
        toks = rng.zipf(self.data.zipf_a, size=(B, S + 1)) % (v - 1) + 1
        # stamp document boundaries
        n_docs = max(int(S / self.data.doc_len_mean), 1)
        for b in range(B):
            cuts = rng.integers(1, S, size=n_docs)
            toks[b, cuts] = self.data.eod_token
        ids = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        mask = labels != self.data.eod_token
        return {"ids": ids, "labels": labels, "mask": mask}


class ShardedLoader:
    """Places host batches onto the mesh with the step fn's batch specs,
    prefetching ahead on a background thread."""

    def __init__(self, dataset: SyntheticPackedLM, mesh,
                 specs: Dict[str, P], prefetch: int = 2,
                 enc_embed_dim: int = 0):
        self.ds = dataset
        self.mesh = mesh
        self.specs = specs
        self.enc_embed_dim = enc_embed_dim
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread: Optional[threading.Thread] = None

    def _place(self, batch_np: Dict[str, np.ndarray]):
        out = {}
        for k, v in batch_np.items():
            spec = self.specs.get(k, P())
            if k == "mask" and "mask" not in self.specs:
                spec = self.specs.get("labels", P())
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    def get(self, step: int):
        b = self.ds.batch_np(step)
        if self.enc_embed_dim:
            rng = np.random.default_rng(
                np.random.SeedSequence([17, self.ds.data.seed, step]))
            B = self.ds.cell.global_batch
            S = max(self.ds.cell.seq_len // 4, 8)
            b["enc_embeds"] = rng.standard_normal(
                (B, S, self.enc_embed_dim)).astype(np.float32)
            b["enc_embeds"] = b["enc_embeds"].astype(jnp.bfloat16)
        return self._place(b)

    def __iter__(self) -> Iterator:
        step = 0
        while True:
            yield self.get(step)
            step += 1
