"""JAX version compatibility layer.

The repo targets the current JAX API surface (``jax.shard_map`` with
``check_vma``, varying-mesh-axis typing, ``all_gather_invariant``); this
module makes it run unchanged on JAX 0.4.x (0.4.37 is the pinned CI
toolchain). Every versioned import in ``src/`` routes through here:

  shard_map            jax.shard_map | jax.experimental.shard_map, and the
                       check_vma -> check_rep kwarg rename
  all_gather_invariant falls back to jax.lax.all_gather (the invariant
                       gather exists only on VMA-typed JAX; the varying
                       gather is numerically identical, it just loses the
                       replication-typing guarantee)
  pvary / typeof       no-ops on pre-VMA JAX (avals carry no vma there,
                       so there is nothing to lift)
  flatten_with_path    jax.tree.flatten_with_path | jax.tree_util
  make_mesh            drops the axis_types kwarg where unsupported

Feature flags (HAS_VMA, HAS_INVARIANT_GATHER) let callers branch when the
semantic difference matters (it never changes numerics, only typing
strictness and comm-accounting op names).
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional, Sequence, Tuple

import jax

# ---------------------------------------------------------------------------
# shard_map: location + check_vma/check_rep rename
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # JAX <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = set(inspect.signature(_shard_map).parameters)
HAS_VMA = "check_vma" in _SHARD_MAP_PARAMS

if not HAS_VMA:
    # check_rep=True is load-bearing on pre-VMA shard_map: its rewrite
    # pass is what inserts the pbroadcast/psum pairs that make gradients
    # of replicated-in-storage params (MiCS pod-replication, small
    # replicated tensors) correct. The stock 0.4.x registry just lacks a
    # rule for the `name` primitive our remat-policy cache boundaries
    # rely on (checkpoint_name) -- name is a unary pass-through, so the
    # standard rep-preserving rule is exact. setdefault semantics: a
    # future jax that ships its own rule wins.
    try:
        from jax.experimental import shard_map as _shmap_mod
        from jax._src.ad_checkpoint import name_p as _name_p
        _shmap_mod.register_standard_check(_name_p)
        _shmap_mod.register_standard_rewrite(_name_p)
    except Exception:  # pragma: no cover - registry moved/renamed
        pass


def shard_map(f, mesh=None, in_specs=None, out_specs=None,
              check_vma: bool = True, **kw):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename
    papered over. Call with the new-style kwarg; on old JAX the value is
    forwarded as ``check_rep`` (with the `name` rule patched in above)."""
    if HAS_VMA:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma, **kw)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, **kw)


# ---------------------------------------------------------------------------
# Invariant all-gather
# ---------------------------------------------------------------------------

try:
    from jax._src.lax.parallel import all_gather_invariant as _agi
    HAS_INVARIANT_GATHER = True
except ImportError:  # pre-VMA JAX: the varying gather is the only gather
    _agi = None
    HAS_INVARIANT_GATHER = False

# Pre-VMA replication typing for the invariant gather. 0.4.x shard_map
# registers all_gather as a "standard collective" (varying -> varying),
# so an all-gather can never DISCHARGE a replication obligation -- e.g.
# the hier strategy's post-update pod-axis gather of optimizer shards
# back to the pod-replicated param layout fails the out_specs rep check
# even though the gathered value is replicated by construction. The real
# invariant gather types this correctly on VMA JAX; here we recover it
# with a no-op pass-through primitive whose check/rewrite rules add the
# gathered axes to the replication set (semantically exact: every member
# of the gathered axis holds the identical concatenated result).
_rep_assert_p = None
if not HAS_VMA and _agi is None:
    try:
        from jax.experimental import shard_map as _shmap_mod2
        from jax.interpreters import ad as _ad, mlir as _mlir

        _rep_assert_p = jax.core.Primitive("rep_assert")
        _rep_assert_p.def_impl(lambda x, *, axes: x)
        _rep_assert_p.def_abstract_eval(lambda x, *, axes: x)
        _mlir.register_lowering(
            _rep_assert_p, lambda ctx, x, *, axes: [x])
        _ad.deflinear2(_rep_assert_p, lambda ct, x, *, axes: (ct,))

        @_shmap_mod2.register_check(_rep_assert_p)
        def _rep_assert_check(mesh, x_rep, *, axes):
            return x_rep | set(axes) if x_rep is not None else x_rep

        @_shmap_mod2.register_rewrite(_rep_assert_p)
        def _rep_assert_rewrite(mesh, in_reps, x, *, axes):
            (x_rep,) = in_reps
            out_rep = x_rep | set(axes) if x_rep is not None else x_rep
            return [_rep_assert_p.bind(x, axes=axes)], [out_rep]
    except Exception:  # pragma: no cover - registry moved/renamed
        _rep_assert_p = None


def all_gather_invariant(x, axis_name, *, axis: int = 0, tiled: bool = False):
    """Invariant (replicated-typed) all-gather, or the plain all-gather on
    JAX versions without it (typed replicated via the rep_assert shim
    when the 0.4.x registries are available). One axis name per call
    (matching the real invariant gather's signature)."""
    if _agi is not None:
        return _agi(x, axis_name, axis=axis, tiled=tiled)
    y = jax.lax.all_gather(x, axis_name, axis=axis, tiled=tiled)
    if _rep_assert_p is not None:
        axes = (axis_name,) if isinstance(axis_name, str) \
            else tuple(axis_name)
        y = _rep_assert_p.bind(y, axes=axes)
    return y


# ---------------------------------------------------------------------------
# VMA typing helpers
# ---------------------------------------------------------------------------

def typeof(x):
    """jax.typeof, falling back to the abstract value on older JAX (whose
    avals carry no ``vma`` attribute -- callers getattr with a default)."""
    if hasattr(jax, "typeof"):
        return jax.typeof(x)
    return jax.core.get_aval(x)


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis from inside shard_map.
    jax.lax.axis_size where it exists; the axis-env frame on older JAX
    (which returns either a frame object or the size itself)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return getattr(frame, "size", frame)


def pvary(x, axis_names: Tuple[str, ...]):
    """Lift a value to vary over ``axis_names``. On pre-VMA JAX values
    carry no varying type, so this is the identity."""
    if not axis_names:
        return x
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x


# ---------------------------------------------------------------------------
# Pytree path flattening
# ---------------------------------------------------------------------------

def flatten_with_path(tree, is_leaf: Optional[Callable] = None):
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree, is_leaf=is_leaf)
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

_MAKE_MESH_PARAMS = set(inspect.signature(jax.make_mesh).parameters)


def make_mesh(shape: Sequence[int], axes: Sequence[str], devices=None):
    """jax.make_mesh with Auto axis types where the kwarg exists; older
    JAX has no axis-type concept (everything is Auto). ``devices``
    restricts the mesh to an explicit device subset (elastic remesh over
    the survivors); without it jax fills the mesh from all visible
    devices."""
    shape, axes = tuple(shape), tuple(axes)
    kw = {}
    if devices is not None:
        if "devices" in _MAKE_MESH_PARAMS:
            kw["devices"] = tuple(devices)
        else:  # pragma: no cover - very old jax: build the Mesh directly
            import numpy as _np
            return jax.sharding.Mesh(
                _np.asarray(devices, dtype=object).reshape(shape), axes)
    if "axis_types" in _MAKE_MESH_PARAMS and hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)
