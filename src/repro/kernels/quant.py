"""Symmetric per-256-block int8 quantization kernels (Pallas, TPU target).

Every int8 transport path in the repo -- the qwZ stage-1 weight gather,
the qgZ gradient reduce-scatter, and the TP activation all-reduce --
shares this one block layout: tensors are flattened, padded to a whole
number of 256-element blocks, and each block carries one fp32 scale
(max(|x|)/127, clamped to SCALE_EPS). The three kernels here are the hot
loops of those paths:

  quantize_blocks     [nb, BLOCK] f32 -> (int8 [nb, BLOCK], f32 [nb, 1])
  dequantize_blocks   (q, s) -> f32 [nb, BLOCK]
  dequant_accumulate  (q [n, nb, BLOCK], s [n, nb, 1]) -> f32 [nb, BLOCK]
                      (the reduce-scatter inner loop: sequential fold of
                      n dequantized source chunks, in grid order)

Layout: BLOCK=256 spans two 128-wide VPU lanes; the block index maps
onto sublanes in ROW_BLOCK-row tiles. Wrappers pad the row count so the
kernels only ever see full tiles (padded rows quantize to q=0 and are
sliced off). The jnp oracles live in kernels/ref.py; tests assert the
interpret-mode kernels are bit-exact against them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK = 256        # quantization block: elements sharing one fp32 scale
SCALE_EPS = 1e-12  # scale clamp: keeps all-zero blocks finite
ROW_BLOCK = 8      # sublane tile: block-rows processed per grid program
# scale = max(|x|) * (1/127): a plain f32 divide by the constant 127 is
# strength-reduced to a reciprocal multiply in SOME fusion contexts and
# kept exact in others, so kernel and oracle could disagree by 1 ulp --
# both multiply by this shared precomputed reciprocal instead (a python
# float so Pallas kernels can close over it as a scalar literal)
INV_QMAX = float(np.float32(1.0) / np.float32(127.0))


def _pad_rows(x, rows_to: int):
    pad = rows_to - x.shape[-2]
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)]
    return jnp.pad(x, widths)


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                       # [bm, BLOCK]
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) * INV_QMAX,
                    SCALE_EPS)
    q_ref[...] = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    s_ref[...] = s


def quantize_blocks(x, *, interpret: bool = False):
    """x: [nb, BLOCK] float -> (q int8 [nb, BLOCK], scale f32 [nb, 1])."""
    nb, blk = x.shape
    assert blk == BLOCK, (blk, BLOCK)
    nbp = -(-nb // ROW_BLOCK) * ROW_BLOCK
    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=(nbp // ROW_BLOCK,),
        in_specs=[pl.BlockSpec((ROW_BLOCK, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROW_BLOCK, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((ROW_BLOCK, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nbp, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((nbp, 1), jnp.float32)],
        interpret=interpret,
    )(_pad_rows(x, nbp))
    return q[:nb], s[:nb]


def _dequantize_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def dequantize_blocks(q, s, *, interpret: bool = False):
    """(q int8 [nb, BLOCK], s f32 [nb, 1]) -> f32 [nb, BLOCK]."""
    nb, blk = q.shape
    assert blk == BLOCK and s.shape == (nb, 1), (q.shape, s.shape)
    nbp = -(-nb // ROW_BLOCK) * ROW_BLOCK
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(nbp // ROW_BLOCK,),
        in_specs=[pl.BlockSpec((ROW_BLOCK, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((ROW_BLOCK, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_BLOCK, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, BLOCK), jnp.float32),
        interpret=interpret,
    )(_pad_rows(q, nbp), _pad_rows(s, nbp))
    return out[:nb]


def _dequant_acc_kernel(q_ref, s_ref, o_ref):
    # grid: (row_tiles, n) with n innermost -- TPU grids iterate the last
    # dimension sequentially, so the output tile (whose index_map ignores
    # the source index) accumulates the n source chunks in order
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += q_ref[0].astype(jnp.float32) * s_ref[0]


def dequant_accumulate(q, s, *, interpret: bool = False):
    """(q int8 [n, nb, BLOCK], s f32 [n, nb, 1]) -> f32 [nb, BLOCK].

    The reduce-scatter inner loop: dequantize each source rank's chunk
    and fold it into the f32 accumulator, sequentially over sources."""
    n, nb, blk = q.shape
    assert blk == BLOCK and s.shape == (n, nb, 1), (q.shape, s.shape)
    nbp = -(-nb // ROW_BLOCK) * ROW_BLOCK
    out = pl.pallas_call(
        _dequant_acc_kernel,
        grid=(nbp // ROW_BLOCK, n),
        in_specs=[pl.BlockSpec((1, ROW_BLOCK, BLOCK),
                               lambda ri, ni: (ni, ri, 0)),
                  pl.BlockSpec((1, ROW_BLOCK, 1),
                               lambda ri, ni: (ni, ri, 0))],
        out_specs=pl.BlockSpec((ROW_BLOCK, BLOCK), lambda ri, ni: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, BLOCK), jnp.float32),
        interpret=interpret,
    )(_pad_rows(q, nbp), _pad_rows(s, nbp))
    return out[:nb]
