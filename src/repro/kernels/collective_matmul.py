"""Gather-fused collective matmul: consume stage-2 shards as they arrive.

The stage-2 (intra-pod / ICI) all-gather in ``core/fcdp.gather_stage2``
normally completes before the first consuming matmul starts. For
output-dim-sharded weights (w: [K, N] sharded along N over one intra
axis) the product decomposes into disjoint column blocks::

    x @ w_full = concat_j(x @ w_chunk_j)     # no K re-association

so each device multiplies its resident chunk immediately and ring-
``ppermute``s the remaining chunks behind the per-chunk matmuls -- the
transfer of chunk s+1 overlaps the matmul of chunk s, making the
stage-2 overlap a kernel-level property instead of a scan-level one.
Ring wire bytes equal the tiled all-gather's ((n-1)/n of the gathered
payload), so the swap is byte-neutral and the overlap credit is pure
win (see ``chunk_schedule`` and ``launch/roofline.py``).

Two duals live here:
  ring_ag_matmul:  all-gather -> matmul fused ring (forward path)
  ring_matmul_rs:  matmul -> reduce-scatter fused ring (weight-grad path)

Bit-exactness contract (asserted in tests/test_fused_matmul.py):
  * the forward equals ``x @ all_gather(w, tiled=True)`` bit-for-bit
    (column-concat identity; the contraction K is never split);
  * mode='ag_matmul' backward REPLAYS the exact unfused op sequence
    (all_gather + dot_general transposes + psum_scatter, via jax.vjp of
    the baseline expression), so gradients -- and therefore losses and
    params across steps -- are bit-identical to the unfused path;
  * mode='both' additionally ring-fuses the backward (dx accumulation +
    dw matmul-reduce-scatter). That re-associates the dx sum, so 'both'
    is bit-exact vs its own kernels/ref.py oracle, not vs the unfused
    gradient.

The per-chunk matmul is a Pallas kernel (impl='pallas'), tiled over
(block_m, block_n) with the contraction dim kept whole per program --
splitting K would re-associate the accumulation and break the contract.
Non-divisible shapes are padded up to the tile grid and sliced back
(same idiom as kernels/quant.py).
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import axis_size

BLOCK_M = 128
BLOCK_N = 128


def _pad_dim(x, mult: int, axis: int):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...])


def matmul_chunk(x, w, block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                 interpret: bool = False):
    """``x @ w`` as a Pallas blocked matmul. x: [M, K]; w: [K, N].

    The grid tiles M and N only; K stays whole per program, so every
    output element is one un-reassociated dot over the full contraction
    -- the property the bit-exactness contract rests on."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    xp = _pad_dim(x, block_m, 0)
    wp = _pad_dim(w, block_n, 1)
    Mp, Np = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(Mp // block_m, Np // block_n),
        in_specs=[pl.BlockSpec((block_m, K), lambda i, j: (i, 0)),
                  pl.BlockSpec((K, block_n), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:M, :N]


def _chunk_mm(x, w, impl: str, block_m: int, block_n: int, interpret: bool):
    """One per-chunk matmul on arbitrary-rank x ([..., K] @ [K, Nc])."""
    if impl == "jnp":
        return x @ w
    lead = x.shape[:-1]
    out = matmul_chunk(x.reshape(-1, x.shape[-1]), w, block_m, block_n,
                       interpret)
    return out.reshape(lead + (w.shape[1],))


def _ring_perm(n: int) -> List[Tuple[int, int]]:
    """After one hop rank i holds what rank i+1 held: chunk (i+s) % n
    after s hops, matching the ring's owner schedule."""
    return [((j + 1) % n, j) for j in range(n)]


def ring_ag_matmul(x, w_shard, axis_name: str, *, impl: str = "jnp",
                   block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                   interpret: bool = False):
    """Fused all-gather->matmul ring; call inside shard_map.

    x: [..., K] this rank's local activations. w_shard: [K, N/n] this
    rank's column chunk (global column order == rank order along
    ``axis_name``, exactly the tiled all-gather layout). Returns
    ``x @ w_full``: [..., N], bit-identical to gathering first.

    Each step issues the next chunk's ppermute BEFORE the current
    chunk's matmul so the transfer and the compute are concurrently
    ready in program order (XLA overlaps them); chunk results land in
    disjoint column slices of the output."""
    n = axis_size(axis_name)
    Nc = w_shard.shape[1]
    if n == 1:
        return _chunk_mm(x, w_shard, impl, block_m, block_n, interpret)
    idx = jax.lax.axis_index(axis_name)
    out_dtype = jnp.result_type(x.dtype, w_shard.dtype)
    out = jnp.zeros(x.shape[:-1] + (n * Nc,), out_dtype)
    perm = _ring_perm(n)
    chunk = w_shard
    for s in range(n):
        nxt = jax.lax.ppermute(chunk, axis_name, perm) if s < n - 1 else None
        owner = (idx + s) % n
        part = _chunk_mm(x, chunk, impl, block_m, block_n, interpret)
        start = (0,) * (out.ndim - 1) + (owner * Nc,)
        out = jax.lax.dynamic_update_slice(out, part.astype(out_dtype), start)
        chunk = nxt
    return out


def ring_matmul_rs(a, b, axis_name: str, *, impl: str = "jnp",
                   block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                   interpret: bool = False):
    """Fused matmul->reduce-scatter ring; call inside shard_map.

    a: [J, M] and b: [M, N] local operands; returns this rank's column
    chunk of ``sum_ranks(a @ b)``: [J, N/n] -- the fused form of
    ``psum_scatter(a @ b, axis_name, scatter_dimension=1, tiled=True)``.

    Chunk j's partial is born on rank j+1 and accumulates hop by hop
    around the ring (ranks j+2, ..., j-1, finally j), so each hop's
    transfer overlaps the receiver's partial matmul. The accumulation
    order is fixed by that schedule; kernels/ref.py mirrors it."""
    n = axis_size(axis_name)
    N = b.shape[1]
    assert N % n == 0, (b.shape, n)
    Nc = N // n
    if n == 1:
        return _chunk_mm(a, b, impl, block_m, block_n, interpret)
    idx = jax.lax.axis_index(axis_name)
    perm = [(j, (j + 1) % n) for j in range(n)]   # j sends to j+1
    buf = None
    for h in range(n):
        c = (idx + (n - 1 - h)) % n
        cols = jax.lax.dynamic_slice(b, (0, c * Nc), (b.shape[0], Nc))
        part = _chunk_mm(a, cols, impl, block_m, block_n, interpret)
        buf = part if buf is None else (
            jax.lax.ppermute(buf, axis_name, perm) + part)
    return buf


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7))
def fused_matmul(x, w_shard, axis_name: str, mode: str = "ag_matmul",
                 impl: str = "jnp", block_m: int = BLOCK_M,
                 block_n: int = BLOCK_N, interpret: bool = False):
    """Differentiable gather-fused matmul (see module docstring).

    mode='ag_matmul': fused forward, bit-parity baseline-replay
    backward. mode='both': backward ring-fused too (dx ring + dw
    matmul-reduce-scatter; exact vs the ref.py oracle only)."""
    return ring_ag_matmul(x, w_shard, axis_name, impl=impl,
                          block_m=block_m, block_n=block_n,
                          interpret=interpret)


def _fused_fwd(x, w_shard, axis_name, mode, impl, block_m, block_n,
               interpret):
    y = fused_matmul(x, w_shard, axis_name, mode, impl, block_m, block_n,
                     interpret)
    return y, (x, w_shard)


def _fused_bwd(axis_name, mode, impl, block_m, block_n, interpret, res, g):
    x, w_shard = res
    if mode != "both":
        # bit-parity backward: replay the exact op sequence AD emits for
        # the unfused x @ all_gather(w) -- the gather, the two
        # dot_general transposes, and the psum_scatter -- so the
        # cotangents are bit-identical to the unfused path
        def baseline(x_, w_):
            w_full = jax.lax.all_gather(w_, axis_name, axis=1, tiled=True)
            return x_ @ w_full
        _, vjp = jax.vjp(baseline, x, w_shard)
        return tuple(vjp(g))
    # mode='both': ring-fused backward. dx accumulates per-chunk
    # contributions in ring order (re-associated); dw is the fused
    # matmul->reduce-scatter dual.
    n = axis_size(axis_name)
    K = x.shape[-1]
    Nc = w_shard.shape[1]
    x2 = x.reshape(-1, K)
    g2 = g.reshape(-1, g.shape[-1])
    idx = jax.lax.axis_index(axis_name)
    perm = _ring_perm(n)
    chunk = w_shard
    dx2 = jnp.zeros(x2.shape, jnp.result_type(g.dtype, w_shard.dtype))
    for s in range(n):
        nxt = jax.lax.ppermute(chunk, axis_name, perm) if s < n - 1 else None
        owner = (idx + s) % n
        g_cols = jax.lax.dynamic_slice(g2, (0, owner * Nc),
                                       (g2.shape[0], Nc))
        dx2 = dx2 + _chunk_mm(g_cols, chunk.T, impl, block_m, block_n,
                              interpret)
        chunk = nxt
    dw = ring_matmul_rs(x2.T, g2, axis_name, impl=impl, block_m=block_m,
                        block_n=block_n, interpret=interpret)
    return (dx2.reshape(x.shape).astype(x.dtype), dw.astype(w_shard.dtype))


fused_matmul.defvjp(_fused_fwd, _fused_bwd)


def chunk_schedule(m_tokens: int, k: int, n_cols_local: int, n_ranks: int,
                   dtype_bytes: float = 2.0) -> List[Tuple[float, float]]:
    """The ring's per-step (transfer_bytes, matmul_flops) schedule.

    Step s multiplies one [m, k] x [k, n_local] chunk while the next
    chunk's ppermute is in flight; the last step has no concurrent
    transfer. ``launch/roofline.py`` turns this into the fused overlap
    credit: sum over steps of min(transfer_time, matmul_time)."""
    chunk_bytes = float(k) * n_cols_local * dtype_bytes
    chunk_flops = 2.0 * m_tokens * k * n_cols_local
    return [(chunk_bytes if s < n_ranks - 1 else 0.0, chunk_flops)
            for s in range(n_ranks)]
