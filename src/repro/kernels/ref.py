"""Pure-jnp oracles for every Pallas kernel. These are the ground truth
the kernels are validated against (tests sweep shapes/dtypes with
assert_allclose)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.quant import BLOCK, INV_QMAX, SCALE_EPS


def attention_ref(q, k, v, causal: bool = True, softmax_scale=None):
    """Naive full-materialization attention. q/k/v: [B, S, H, hd]."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = softmax_scale or (1.0 / math.sqrt(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        mask = kpos <= qpos
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rwkv6_ref(r, k, v, logw, u):
    """Sequential RWKV-6 WKV recurrence (exact). r/k/v/logw: [B,S,H,hd],
    u: [H,hd]. Returns ([B,S,H,hd], final state [B,H,hd,hd]).

    o_t = r_t @ (S + u*outer(k_t, v_t));  S <- diag(w_t) S + outer(k_t, v_t)
    """
    B, S, H, hd = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, out

    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    sf, outs = jax.lax.scan(
        step, s0, (rf.swapaxes(0, 1), kf.swapaxes(0, 1),
                   vf.swapaxes(0, 1), wf.swapaxes(0, 1)))
    return outs.swapaxes(0, 1).astype(r.dtype), sf


def mamba_scan_ref(a, b, h0=None):
    """Sequential diagonal-SSM scan. a, b: [B, S, D, N] (decay, input);
    h_t = a_t * h_{t-1} + b_t. Returns (all states [B,S,D,N], h_last)."""
    B, S, D, N = a.shape
    h0 = jnp.zeros((B, D, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    hl, hs = jax.lax.scan(
        step, h0, (a.astype(jnp.float32).swapaxes(0, 1),
                   b.astype(jnp.float32).swapaxes(0, 1)))
    return hs.swapaxes(0, 1), hl


def int8_quantize_blocks_ref(x):
    """Symmetric per-block quantization. x: [nb, BLOCK] float.
    Returns (q int8 [nb, BLOCK], scale f32 [nb, 1])."""
    blocks = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
                        * INV_QMAX, SCALE_EPS)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize_blocks_ref(q, s):
    """(q int8 [nb, BLOCK], s f32 [nb, 1]) -> f32 [nb, BLOCK]."""
    return q.astype(jnp.float32) * s


def int8_dequant_acc_ref(q, s):
    """Reduce-scatter inner loop oracle: fold the n dequantized source
    chunks sequentially (same order and f32 adds as the kernel's grid
    loop, so interpret-mode comparisons can be bit-exact).
    q: [n, nb, BLOCK] int8, s: [n, nb, 1] f32 -> f32 [nb, BLOCK]."""
    acc = jnp.zeros(q.shape[1:], jnp.float32)
    for i in range(q.shape[0]):
        acc = acc + q[i].astype(jnp.float32) * s[i]
    return acc


def int8_quant_ref(x, block: int = BLOCK):
    """Blockwise symmetric int8 quantization oracle (flattens + pads)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
                        * INV_QMAX, SCALE_EPS)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    n = x.size
    return q, scale, deq[:n].reshape(x.shape)
