"""Pure-jnp oracles for every Pallas kernel. These are the ground truth
the kernels are validated against (tests sweep shapes/dtypes with
assert_allclose)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, softmax_scale=None):
    """Naive full-materialization attention. q/k/v: [B, S, H, hd]."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = softmax_scale or (1.0 / math.sqrt(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        mask = kpos <= qpos
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rwkv6_ref(r, k, v, logw, u):
    """Sequential RWKV-6 WKV recurrence (exact). r/k/v/logw: [B,S,H,hd],
    u: [H,hd]. Returns ([B,S,H,hd], final state [B,H,hd,hd]).

    o_t = r_t @ (S + u*outer(k_t, v_t));  S <- diag(w_t) S + outer(k_t, v_t)
    """
    B, S, H, hd = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, out

    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    sf, outs = jax.lax.scan(
        step, s0, (rf.swapaxes(0, 1), kf.swapaxes(0, 1),
                   vf.swapaxes(0, 1), wf.swapaxes(0, 1)))
    return outs.swapaxes(0, 1).astype(r.dtype), sf


def mamba_scan_ref(a, b, h0=None):
    """Sequential diagonal-SSM scan. a, b: [B, S, D, N] (decay, input);
    h_t = a_t * h_{t-1} + b_t. Returns (all states [B,S,D,N], h_last)."""
    B, S, D, N = a.shape
    h0 = jnp.zeros((B, D, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    hl, hs = jax.lax.scan(
        step, h0, (a.astype(jnp.float32).swapaxes(0, 1),
                   b.astype(jnp.float32).swapaxes(0, 1)))
    return hs.swapaxes(0, 1), hl


def int8_quant_ref(x, block: int = 256):
    """Blockwise symmetric int8 quantization oracle."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
                        / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    n = x.size
    return q, scale, deq[:n].reshape(x.shape)
