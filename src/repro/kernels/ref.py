"""Pure-jnp oracles for every Pallas kernel. These are the ground truth
the kernels are validated against (tests sweep shapes/dtypes with
assert_allclose)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.quant import BLOCK, INV_QMAX, SCALE_EPS


def attention_ref(q, k, v, causal: bool = True, softmax_scale=None):
    """Naive full-materialization attention. q/k/v: [B, S, H, hd]."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = softmax_scale or (1.0 / math.sqrt(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        mask = kpos <= qpos
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rwkv6_ref(r, k, v, logw, u):
    """Sequential RWKV-6 WKV recurrence (exact). r/k/v/logw: [B,S,H,hd],
    u: [H,hd]. Returns ([B,S,H,hd], final state [B,H,hd,hd]).

    o_t = r_t @ (S + u*outer(k_t, v_t));  S <- diag(w_t) S + outer(k_t, v_t)
    """
    B, S, H, hd = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, out

    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    sf, outs = jax.lax.scan(
        step, s0, (rf.swapaxes(0, 1), kf.swapaxes(0, 1),
                   vf.swapaxes(0, 1), wf.swapaxes(0, 1)))
    return outs.swapaxes(0, 1).astype(r.dtype), sf


def mamba_scan_ref(a, b, h0=None):
    """Sequential diagonal-SSM scan. a, b: [B, S, D, N] (decay, input);
    h_t = a_t * h_{t-1} + b_t. Returns (all states [B,S,D,N], h_last)."""
    B, S, D, N = a.shape
    h0 = jnp.zeros((B, D, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    hl, hs = jax.lax.scan(
        step, h0, (a.astype(jnp.float32).swapaxes(0, 1),
                   b.astype(jnp.float32).swapaxes(0, 1)))
    return hs.swapaxes(0, 1), hl


def int8_quantize_blocks_ref(x):
    """Symmetric per-block quantization. x: [nb, BLOCK] float.
    Returns (q int8 [nb, BLOCK], scale f32 [nb, 1])."""
    blocks = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
                        * INV_QMAX, SCALE_EPS)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize_blocks_ref(q, s):
    """(q int8 [nb, BLOCK], s f32 [nb, 1]) -> f32 [nb, BLOCK]."""
    return q.astype(jnp.float32) * s


def int8_dequant_acc_ref(q, s):
    """Reduce-scatter inner loop oracle: fold the n dequantized source
    chunks sequentially (same order and f32 adds as the kernel's grid
    loop, so interpret-mode comparisons can be bit-exact).
    q: [n, nb, BLOCK] int8, s: [n, nb, 1] f32 -> f32 [nb, BLOCK]."""
    acc = jnp.zeros(q.shape[1:], jnp.float32)
    for i in range(q.shape[0]):
        acc = acc + q[i].astype(jnp.float32) * s[i]
    return acc


def matmul_chunk_ref(x, w, block_m: int = 128, block_n: int = 128):
    """Tile-loop mirror of collective_matmul.matmul_chunk: pad to the
    (block_m, block_n) grid, one jnp.dot per tile with the contraction
    kept whole, slice the pad back off. Interpret-mode Pallas executes
    exactly this per-tile dot, so comparisons can be bit-exact."""
    M, K = x.shape
    N = w.shape[1]
    pm, pn = (-M) % block_m, (-N) % block_n
    xp = jnp.pad(x, ((0, pm), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, pn)))
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    rows = []
    for i in range(xp.shape[0] // block_m):
        tiles = [jnp.dot(xp[i * block_m:(i + 1) * block_m],
                         wp[:, j * block_n:(j + 1) * block_n])
                 for j in range(wp.shape[1] // block_n)]
        rows.append(jnp.concatenate(tiles, axis=1))
    return jnp.concatenate(rows, axis=0)[:M, :N].astype(out_dtype)


def ag_matmul_ref(x, w_chunks):
    """Oracle for the fused all-gather->matmul ring: per-chunk matmuls
    written to disjoint column blocks in global (rank) order. x: [M, K];
    w_chunks: [n, K, Nc] (chunk j = rank j's shard). Chunk results are
    disjoint, so the ring's owner schedule is order-irrelevant here."""
    return jnp.concatenate([x @ w_chunks[j]
                            for j in range(w_chunks.shape[0])], axis=-1)


def matmul_rs_ref(a_chunks, b_chunks, rank: int):
    """Oracle for the fused matmul->reduce-scatter ring, for one rank.

    a_chunks: [n, J, M], b_chunks: [n, M, N] (per-rank local operands).
    Chunk ``rank`` is born on rank+1 and accumulates hop by hop (ranks
    rank+2, ..., rank-1, finally rank) -- mirror that exact left-to-
    right order so interpret-mode comparisons can be bit-exact."""
    n = a_chunks.shape[0]
    Nc = b_chunks.shape[2] // n
    acc = None
    for h in range(n):
        src = (rank + 1 + h) % n
        part = a_chunks[src] @ b_chunks[src][:, rank * Nc:(rank + 1) * Nc]
        acc = part if acc is None else acc + part
    return acc


def fused_bwd_dx_ref(g, w_chunks, rank: int):
    """Oracle for mode='both' dx: per-chunk contributions accumulated in
    ring order (owner = (rank + s) % n at step s). g: [M, N] cotangent;
    w_chunks: [n, K, Nc]. Returns [M, K]."""
    n, _, Nc = w_chunks.shape
    dx = None
    for s in range(n):
        owner = (rank + s) % n
        part = g[:, owner * Nc:(owner + 1) * Nc] @ w_chunks[owner].T
        dx = part if dx is None else dx + part
    return dx


def int8_quant_ref(x, block: int = BLOCK):
    """Blockwise symmetric int8 quantization oracle (flattens + pads)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
                        * INV_QMAX, SCALE_EPS)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    n = x.size
    return q, scale, deq[:n].reshape(x.shape)
