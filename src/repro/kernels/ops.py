"""jit'd dispatch wrappers for the Pallas kernels.

One ``impl`` keyword everywhere: 'jnp' (pure-jnp oracle), 'pallas'
(real lowering), or 'pallas_interpret' (CPU-validated interpret mode).
The legacy ``interpret=True`` boolean is kept as a back-compat shim --
it upgrades impl='pallas' to 'pallas_interpret'. Model code selects via
SystemConfig.attn_impl / quant_impl / fused_impl.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref

IMPLS = ("jnp", "pallas", "pallas_interpret")


def resolve_impl(impl: str, interpret: bool = False):
    """Normalize (impl, legacy interpret flag) -> (impl, interpret).

    'pallas_interpret' and interpret=True both mean interpret-mode
    Pallas; the returned impl is 'jnp' or 'pallas'."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    if impl == "jnp":
        return "jnp", False
    return "pallas", interpret or impl == "pallas_interpret"


@functools.partial(jax.jit, static_argnames=("causal", "softmax_scale",
                                             "block_q", "block_k",
                                             "interpret", "impl"))
def flash_attention(q, k, v, causal: bool = True,
                    softmax_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False, impl: str = "pallas"):
    """q/k/v: [B, S, H, hd] (kv pre-expanded to H heads)."""
    impl, interpret = resolve_impl(impl, interpret)
    if impl == "jnp":
        return kref.attention_ref(q, k, v, causal=causal,
                                  softmax_scale=softmax_scale)
    from repro.kernels.flash_attention import flash_attention_fwd
    return flash_attention_fwd(
        q, k, v, causal=causal, softmax_scale=softmax_scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "impl"))
def wkv6(r, k, v, logw, u, chunk: int = 64, interpret: bool = False,
         impl: str = "pallas"):
    """RWKV-6 WKV. r/k/v/logw: [B,S,H,hd]; u: [H,hd]."""
    impl, interpret = resolve_impl(impl, interpret)
    if impl == "jnp":
        return kref.rwkv6_ref(r, k, v, logw, u)
    from repro.kernels.rwkv6_scan import wkv6_chunked
    return wkv6_chunked(r, k, v, logw, u, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def int8_quantize_blocks(x, interpret: bool = False, impl: str = "pallas"):
    """Symmetric per-block int8 quantize. x: [nb, BLOCK] float.
    Returns (q int8 [nb, BLOCK], scale f32 [nb, 1])."""
    impl, interpret = resolve_impl(impl, interpret)
    if impl == "jnp":
        return kref.int8_quantize_blocks_ref(x)
    from repro.kernels.quant import quantize_blocks
    return quantize_blocks(x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def int8_dequantize_blocks(q, s, interpret: bool = False,
                           impl: str = "pallas"):
    """(q int8 [nb, BLOCK], s f32 [nb, 1]) -> f32 [nb, BLOCK]."""
    impl, interpret = resolve_impl(impl, interpret)
    if impl == "jnp":
        return kref.int8_dequantize_blocks_ref(q, s)
    from repro.kernels.quant import dequantize_blocks
    return dequantize_blocks(q, s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def int8_dequant_accumulate(q, s, interpret: bool = False,
                            impl: str = "pallas"):
    """Reduce-scatter inner loop: sequential dequant-accumulate of the
    n source chunks. q: [n, nb, BLOCK] int8, s: [n, nb, 1] f32."""
    impl, interpret = resolve_impl(impl, interpret)
    if impl == "jnp":
        return kref.int8_dequant_acc_ref(q, s)
    from repro.kernels.quant import dequant_accumulate
    return dequant_accumulate(q, s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "channel_block",
                                             "interpret", "impl"))
def ssm_scan(a, b, chunk: int = 128, channel_block: int = 512,
             interpret: bool = False, impl: str = "pallas"):
    """Diagonal SSM scan h_t = a_t h_{t-1} + b_t over [B,S,C]."""
    impl, interpret = resolve_impl(impl, interpret)
    if impl == "jnp":
        B, S, C = a.shape
        hs, _ = kref.mamba_scan_ref(a.reshape(B, S, C, 1),
                                    b.reshape(B, S, C, 1))
        return hs.reshape(B, S, C)
    from repro.kernels.mamba_scan import mamba_scan
    return mamba_scan(a, b, chunk=chunk, channel_block=channel_block,
                      interpret=interpret)


def collective_ag_matmul(x, w_shard, axis_name: str, mode: str = "ag_matmul",
                         impl: str = "jnp", block_m: int = 128,
                         block_n: int = 128, interpret: bool = False):
    """Gather-fused collective matmul (kernels/collective_matmul.py):
    consumes the stage-2 column chunks as the ring delivers them.

    NOT jit-wrapped like the ops above: it carries named-axis
    collectives (ppermute / psum_scatter) and a custom_vjp, so it must
    trace directly inside the caller's shard_map body."""
    from repro.kernels.collective_matmul import fused_matmul
    impl, interpret = resolve_impl(impl, interpret)
    return fused_matmul(x, w_shard, axis_name, mode, impl, block_m,
                        block_n, interpret)
