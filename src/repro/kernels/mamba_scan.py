"""Mamba diagonal-SSM selective-scan kernel (Pallas, TPU target).

TPU adaptation of the CUDA selective-scan: channels are independent, so
the channel dimension maps onto the 8x128 VPU lanes while the sequence
is walked in chunks with a VMEM-resident carry. Within a chunk the
recurrence h_t = a_t h_{t-1} + b_t is computed by a log2(c)-step
Blelloch-style doubling scan on the VMEM tile (shifted multiplies), which
vectorizes across channels -- the TPU equivalent of the warp-parallel
scan the GPU kernel uses.

Layout: a, b are [B, S, D*N] flattened (channel x state product), grid
programs own (batch, channel-block) pairs and iterate chunks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, h_ref, carry_scr, *, chunk: int):
    """Grid: (B, channel_blocks, num_chunks); chunks sequential."""
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        carry_scr[...] = jnp.zeros_like(carry_scr)

    a = a_ref[0].astype(jnp.float32)          # [chunk, cb]
    b = b_ref[0].astype(jnp.float32)

    # inclusive scan of (a, b) pairs via log-step doubling:
    # (a2,b2) o (a1,b1) = (a1*a2, b2 + a2*b1)
    av, bv = a, b
    shift = 1
    while shift < chunk:
        # identity element is (a=1, b=0): pad the shifted decay with ones
        a_sh = jnp.pad(av, ((shift, 0), (0, 0)),
                       constant_values=1.0)[:chunk]
        b_sh = jnp.pad(bv, ((shift, 0), (0, 0)))[:chunk]
        bv = bv + av * b_sh
        av = av * a_sh
        shift *= 2
    # fold in the carry: h_t = av_t * h0 + bv_t
    h0 = carry_scr[...]                        # [1, cb]
    hs = av * h0 + bv
    h_ref[0] = hs.astype(h_ref.dtype)
    carry_scr[...] = hs[-1:]


def mamba_scan(a, b, *, chunk: int = 128, channel_block: int = 512,
               interpret: bool = False):
    """a, b: [B, S, C] (C = d_inner*d_state flattened).
    Returns all states hs: [B, S, C] (h_t = a_t*h_{t-1} + b_t, h_{-1}=0).
    """
    B, S, C = a.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    cb = min(channel_block, C)
    pad_c = (-C) % cb
    if pad_c:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_c)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_c)))
    Cp = C + pad_c
    n_chunks = S // chunk
    grid = (B, Cp // cb, n_chunks)
    kernel = functools.partial(_scan_kernel, chunk=chunk)
    hs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, cb), lambda bi, cbi, ci: (bi, ci, cbi)),
            pl.BlockSpec((1, chunk, cb), lambda bi, cbi, ci: (bi, ci, cbi)),
        ],
        out_specs=pl.BlockSpec((1, chunk, cb),
                               lambda bi, cbi, ci: (bi, ci, cbi)),
        out_shape=jax.ShapeDtypeStruct((B, S, Cp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, cb), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return hs[:, :, :C]
