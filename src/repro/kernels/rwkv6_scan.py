"""RWKV-6 WKV chunked recurrence kernel (Pallas, TPU target).

TPU adaptation of the CUDA wkv6 kernel: instead of one thread per
channel, each grid program owns one (batch, head) pair and walks the
sequence in VMEM-resident chunks. The per-chunk math is the same
chunked-linear-attention decomposition used by the jnp path
(models/sublayers._wkv_chunked): intra-chunk scores via an MXU matmul
with per-channel decay ratios, inter-chunk via the carried [hd, hd]
state held in VMEM scratch across the sequential chunk grid dimension.

All decay ratios are exponentials of non-positive log sums, so every
factor is <= 1 -- no overflow for any chunk length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_out_ref,
                s_scr, *, chunk: int, hd: int):
    """Grid: (B*H, num_chunks); chunk dim is sequential (carries state)."""
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)       # [c, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)     # log decay, <= 0
    u = u_ref[0, 0].astype(jnp.float32)       # [hd]

    cw = jnp.cumsum(lw, axis=0)               # [c, hd]
    cw_prev = cw - lw
    S0 = s_scr[...]                           # [hd, hd]

    # inter-chunk
    q = r * jnp.exp(cw_prev)
    o_inter = jax.lax.dot_general(q, S0, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # intra-chunk: A[t,i] = sum_ch r[t]k[i] exp(cw_prev[t]-cw[i]), i<t.
    # Exact masked-log-ratio form: exponents are masked to the i<t region
    # BEFORE exponentiation, so every factor is <= 1 for arbitrarily
    # strong decays (the factorized q@k^T form overflows for w -> 0).
    # VMEM cost: one [c, c, hd] f32 tile (1 MiB at c=hd=64).
    ratio_log = cw_prev[:, None, :] - cw[None, :, :]        # [t, i, hd]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, ratio_log.shape, 0)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, ratio_log.shape, 1)
    ratio_log = jnp.where(i_idx < t_idx, ratio_log, -1e30)
    A = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(ratio_log), axis=2)
    o_intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # diagonal u-bonus
    diag = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)
    o = o_inter + o_intra + diag * v
    o_ref[0, 0] = o.astype(o_ref.dtype)

    # state update: S' = diag(exp(cw_c)) S0 + sum_i outer(k_i e^{cw_c-cw_i}, v_i)
    cw_c = cw[-1]                              # [hd]
    kds = k * jnp.exp(cw_c[None, :] - cw)
    s_new = jnp.exp(cw_c)[:, None] * S0 + jax.lax.dot_general(
        kds, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit_state():
        s_out_ref[0] = s_new


def wkv6_chunked(r, k, v, logw, u, *, chunk: int = 64,
                 interpret: bool = False):
    """r/k/v/logw: [B, S, H, hd]; u: [H, hd].
    Returns ([B, S, H, hd], final_state [B, H, hd, hd]).

    Note: the normalized intra-chunk factorization trades one exactness
    property (per-pair decay ratios) for MXU-friendly matmuls; ratios are
    renormalized to the chunk start so all factors stay <= e^{|lw_0|}.
    """
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    # [B,S,H,hd] -> [B*H, n, chunk, hd]
    def rs(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, n, chunk, hd)
    rr, kk, vv, ww = rs(r), rs(k), rs(v), rs(logw)
    uu = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, hd=hd)
    out, s_out = pl.pallas_call(
        kernel,
        grid=(B * H, n),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, n, chunk, hd), r.dtype),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu)
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return out, s_out.reshape(B, H, hd, hd)
