"""Fused causal flash attention for TPU (Pallas).

TPU-native adaptation: the kv loop is the pallas grid's minor dimension;
each (batch*head, q_block) program streams kv blocks HBM->VMEM through
BlockSpec tiling, keeping the running (max, sumexp, acc) in VMEM scratch.
Block shapes default to (128, 128) -- MXU-aligned (128 lanes) and small
enough that q/k/v/acc tiles fit comfortably in ~16 MB VMEM.

Validated in interpret=True mode against kernels/ref.py:attention_ref
(CPU container; real-TPU execution uses the same kernel).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      softmax_scale: float, causal: bool, block_q: int,
                      block_k: int, seq_len: int):
    """Grid: (batch*heads, num_q_blocks, num_k_blocks); k is minor."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    if causal:
        # skip fully-masked kv blocks (upper triangle)
        run = k_start <= q_start + block_q - 1
    else:
        run = ki >= 0  # always true (traced)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # [block_q, hd]
        k = k_ref[0].astype(jnp.float32)            # [block_k, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * softmax_scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len
        if causal:
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        softmax_scale=None, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q/k/v: [B, S, H, hd] with identical H (kv pre-expanded).
    Returns [B, S, H, hd]."""
    B, S, H, hd = q.shape
    scale = softmax_scale or (1.0 / math.sqrt(hd))
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad_s = (-S) % block_q
    pad_k = (-S) % block_k
    pad = max(pad_s, pad_k)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    # [B,S,H,hd] -> [B*H, S, hd]
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    grid = (B * H, Sp // block_q, Sp // block_k)

    kernel = functools.partial(
        _flash_fwd_kernel, softmax_scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            # VMEM scratch: running max / sumexp / accumulator
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, H, Sp, hd).transpose(0, 2, 1, 3)
    return out[:, :S]
