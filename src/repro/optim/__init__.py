from repro.optim.adamw import (adamw_update, init_opt_state, lr_at_step,
                               clip_by_global_norm)
