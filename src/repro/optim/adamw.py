"""AdamW with ZeRO-sharded states and mixed-precision master weights.

Optimizer state leaves carry exactly the parameter's storage sharding, so
updates are purely local (ZeRO-3: each device updates only its shard).
State dtypes are configurable (fp32 default; bf16 m/v for HBM-tight
configs such as kimi-k2 at 512 chips, see EXPERIMENTS.md).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.compat import pvary, typeof
from repro.configs.base import OptimizerConfig, SystemConfig


def lr_at_step(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - t
    else:  # cosine
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * decay


def init_opt_state(train_params: List[jax.Array], sys: SystemConfig):
    """m, v (opt dtype) + fp32 master copies, all sharded like params."""
    od = jnp.dtype(sys.opt_state_dtype)
    md = jnp.dtype(sys.master_dtype)
    return {
        "m": [jnp.zeros(p.shape, od) for p in train_params],
        "v": [jnp.zeros(p.shape, od) for p in train_params],
        "master": [p.astype(md) for p in train_params],
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads: List[jax.Array], rep_factors: Sequence[float],
                        max_norm: float, dp_axes, tp_present: bool = True):
    """Global-norm clip aware of sharding: each leaf's local sum-of-squares
    is divided by its replication factor, then psum'd over every mesh axis
    so each element counts exactly once. The psum always includes 'model'
    (even at tp degree 1) for VMA type correctness."""
    local = jnp.float32(0)
    for g, rep in zip(grads, rep_factors):
        local = local + jnp.sum(jnp.square(g.astype(jnp.float32))) / rep
    axes = tuple(dp_axes) + ("model",)
    if axes:
        # lift to varying over every axis (identical copies psum-corrected
        # by the replication factors above), then reduce over all
        have = set(getattr(typeof(local), "vma", ()) or ())
        missing = tuple(a for a in axes if a not in have)
        if missing:
            local = pvary(local, missing)
        total = jax.lax.psum(local, axes)
    else:
        total = local
    gnorm = jnp.sqrt(total)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return [g * scale.astype(g.dtype) for g in grads], gnorm


def adamw_update(grads: List[jax.Array], opt_state: Dict[str, Any],
                 opt_cfg: OptimizerConfig, sys: SystemConfig,
                 wd_mask: Optional[Sequence[bool]] = None):
    """Returns (new_params_bf16, new_opt_state). Purely elementwise."""
    step = opt_state["step"] + 1
    lr = lr_at_step(opt_cfg, step)
    b1, b2, eps = opt_cfg.b1, opt_cfg.b2, opt_cfg.eps
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    od = jnp.dtype(sys.opt_state_dtype)
    pd = jnp.dtype(sys.param_dtype)
    new_m, new_v, new_master, new_params = [], [], [], []
    for i, (g, m, v, master) in enumerate(zip(
            grads, opt_state["m"], opt_state["v"], opt_state["master"])):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        upd = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
        wd = opt_cfg.weight_decay if (wd_mask is None or wd_mask[i]) else 0.0
        mastf = master.astype(jnp.float32)
        mastf = mastf - lr * (upd + wd * mastf)
        new_m.append(mf.astype(od))
        new_v.append(vf.astype(od))
        new_master.append(mastf.astype(jnp.dtype(sys.master_dtype)))
        new_params.append(mastf.astype(pd))
    return new_params, {"m": new_m, "v": new_v, "master": new_master,
                        "step": step}
