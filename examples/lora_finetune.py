"""FCDP-Comm in action: LoRA fine-tune with frozen base weights.

The frozen base (99%+ of params) lives in the FCDP-Comm cached layout --
pod-replicated, intra-sharded, `frozen_cached` in residency terms -- so
per-iteration DCN traffic collapses to the adapters (the paper's 100x
headline). Prints the measured collective-volume comparison alongside
the training run.

All system knobs ride the shared launcher surface (launch/cli.py), so
the same spellings work here as on train/dryrun/serve/bench:

  PYTHONPATH=src python examples/lora_finetune.py
  PYTHONPATH=src python examples/lora_finetune.py \\
      --lora-rank 4 --lora-alpha 8 --lora-targets wq,wv \\
      --mode-override '*lora*=zero3'
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import functools

import jax

from repro.configs.base import OptimizerConfig, RunConfig, ShapeCell
from repro.configs.registry import get_smoke_config
from repro.core.engine import StepBundle
from repro.data.pipeline import DataConfig, ShardedLoader, SyntheticPackedLM
from repro.launch.cli import add_system_args, system_config_from_args
from repro.launch.mesh import make_mesh
from repro.launch.roofline import collect_collectives
from repro.optim.adamw import init_opt_state


def measure_dcn(bundle):
    step = bundle.make_train_step()
    closed = step.trace(*bundle.train_input_sds()).jaxpr
    sizes = {a: bundle.mi.size(a) for a in bundle.mi.axis_names}
    return collect_collectives(closed, sizes)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    add_system_args(ap)
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config(args.arch)
    cell = ShapeCell("lora", "train", 64, 8)
    sysc = system_config_from_args(args, min_shard_size=8)
    base = RunConfig(model=cfg, shape=cell,
                     system=sysc.replace(peft=False),
                     optimizer=OptimizerConfig(lr=1e-3,
                                               total_steps=args.steps,
                                               warmup_steps=2))
    full = StepBundle(base, mesh)
    # --peft is implied here: this example IS the PEFT path
    lora = StepBundle(base.replace(system=sysc.replace(peft=True)), mesh)
    s_full, s_lora = measure_dcn(full), measure_dcn(lora)
    print(f"full-FT  DCN bytes/step/chip: {s_full.dcn_bytes:.0f}")
    print(f"LoRA r={lora.run.system.lora_rank:<3d} "
          f"DCN bytes/step/chip: {s_lora.dcn_bytes:.0f} "
          f"({100 * (1 - s_lora.dcn_bytes / s_full.dcn_bytes):.1f}% reduction)")
    n_t = sum(lora.def_leaves[i].size() for i in lora.train_idx)
    n_all = sum(d.size() for d in lora.def_leaves)
    print(f"trainable params: {n_t}/{n_all} ({100 * n_t / n_all:.2f}%)")

    params = lora.init_all_params(seed=0)
    tp, fp = lora.split(params)
    opt = jax.jit(functools.partial(init_opt_state, sys=lora.run.system))(tp)
    step = lora.make_train_step()
    loader = ShardedLoader(SyntheticPackedLM(cfg, cell, DataConfig(0)), mesh,
                           lora.batch_spec(cell))
    for i in range(args.steps):
        tp, opt, m = step(tp, fp, opt, loader.get(i))
        if i % 5 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.4f}")
    print("LoRA fine-tune OK")


if __name__ == "__main__":
    main()
