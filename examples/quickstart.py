"""Quickstart: train a reduced qwen2.5-family model with FCDP on the
local CPU devices, with checkpointing and an injected failure to
demonstrate restart.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train


def main():
    st = train.main([
        "--arch", "qwen2.5-3b", "--smoke",
        "--steps", "30", "--mode", "fcdp",
        "--ckpt-every", "10", "--fail-at", "15",
        "--ckpt-dir", "/tmp/repro_quickstart_ckpt",
    ])
    losses = [m["loss"] for m in st.metrics_log]
    assert losses[-1] < losses[0], "training did not make progress"
    print(f"\nquickstart OK: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(survived 1 injected failure)")


if __name__ == "__main__":
    main()
