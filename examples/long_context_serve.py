"""Long-context serving: sequence-sharded KV cache (the long_500k path)
on a hybrid (jamba-family) model -- mamba state is O(1), attention layers
use flash-decoding-style partial-softmax reconstruction over the 'data'
axis.

  PYTHONPATH=src python examples/long_context_serve.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, ShapeCell, SystemConfig
from repro.configs.registry import get_smoke_config
from repro.core.engine import StepBundle
from repro.launch.mesh import make_mesh


def main():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("jamba-v0.1-52b")
    cell = ShapeCell("long", "decode", 256, 2)   # 256-token cache, batch 2
    run = RunConfig(model=cfg, shape=cell,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    bundle = StepBundle(run, mesh)
    params = bundle.init_all_params(seed=0)
    state = bundle.init_state(cell, seq_sharded=True)
    dec = bundle.make_decode_step(seq_sharded=True)

    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 1)), jnp.int32)
    t0 = time.time()
    n = 48
    for i in range(n):
        logits, state = dec(params, tok, state)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None] % (
            cfg.vocab_size // 2) + 1
    dt = time.time() - t0
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"decoded {n} tokens x batch 2 with a sequence-sharded cache "
          f"in {dt:.1f}s ({2 * n / dt:.1f} tok/s on CPU interpret)")
    print("long-context serve OK")


if __name__ == "__main__":
    main()
