"""Long-context serving, two paths:

1. Continuous batching (core/serve_schedule.py): ONE long prompt is
   chunk-prefilled -- a chunk per scheduler tick -- while short requests
   stream through the other batch slots of the same paged KV pool. The
   long prompt never stalls the short ones: the demo asserts every short
   request COMPLETES before the long one emits its first token.

2. Sequence-sharded contiguous KV (the long_500k path) on a hybrid
   (jamba-family) model -- mamba state is O(1), attention layers use
   flash-decoding-style partial-softmax reconstruction over the 'data'
   axis. Recurrent mixers are exactly what the paged path gates out
   (engine/serve.py::check_paged_plan), so this stays contiguous.

  PYTHONPATH=src python examples/long_context_serve.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, ShapeCell, SystemConfig
from repro.configs.registry import get_smoke_config
from repro.core.engine import StepBundle
from repro.launch.mesh import make_mesh


def continuous_long_prefill(mesh):
    from repro.core.engine.serve import default_paged_kv
    from repro.core.serve_schedule import PagedServeEngine, Request

    cfg = get_smoke_config("qwen2.5-3b")
    cell = ShapeCell("long", "decode", 256, 8)
    run = RunConfig(model=cfg, shape=cell,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    bundle = StepBundle(run, mesh)
    params = bundle.init_all_params(seed=0)
    kv = default_paged_kv(bundle, cell)

    rng = np.random.default_rng(0)
    long_req = Request(rid=0,
                       prompt=rng.integers(1, cfg.vocab_size,
                                           (240,)).astype(np.int32),
                       max_new_tokens=8)
    shorts = [Request(rid=i,
                      prompt=rng.integers(1, cfg.vocab_size,
                                          (8,)).astype(np.int32),
                      max_new_tokens=4)
              for i in range(1, 11)]
    # chunk 16: the long prompt needs 15 scheduler ticks of prefill;
    # every short request finishes (1 chunk + 3 decodes) well inside that
    eng = PagedServeEngine(bundle, kv, chunk=16, policy="continuous")
    results, wall = eng.serve(params, [long_req] + shorts)

    by_rid = {r.rid: r for r in results}
    long_r = by_rid[0]
    for r in results:
        if r.rid == 0:
            continue
        assert r.t_done < long_r.t_first, (
            f"short {r.rid} should have completed while the long prompt "
            f"was still prefilling")
    last_short = max(r.t_done for r in results if r.rid != 0)
    print(f"served 1x240-token + 10x8-token prompts in {wall:.1f}s; "
          f"all shorts done {long_r.t_first - last_short:.2f}s before the "
          f"long prompt's first token (TTFT {long_r.ttft:.2f}s)")
    print("continuous-batching long prefill OK")


def seq_sharded_hybrid(mesh):
    cfg = get_smoke_config("jamba-v0.1-52b")
    cell = ShapeCell("long", "decode", 256, 2)   # 256-token cache, batch 2
    run = RunConfig(model=cfg, shape=cell,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    bundle = StepBundle(run, mesh)
    params = bundle.init_all_params(seed=0)
    state = bundle.init_state(cell, seq_sharded=True)
    dec = bundle.make_decode_step(seq_sharded=True)

    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 1)), jnp.int32)
    t0 = time.time()
    n = 48
    for i in range(n):
        logits, state = dec(params, tok, state)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None] % (
            cfg.vocab_size // 2) + 1
    dt = time.time() - t0
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"decoded {n} tokens x batch 2 with a sequence-sharded cache "
          f"in {dt:.1f}s ({2 * n / dt:.1f} tok/s on CPU interpret)")
    print("long-context serve OK")


def main():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    continuous_long_prefill(mesh)
    seq_sharded_hybrid(mesh)


if __name__ == "__main__":
    main()
