"""Elastic downscale: train on the 3-axis (multi-pod-style) mesh,
checkpoint, lose a 'pod', and resume on the smaller 2-axis mesh -- the
checkpoint reshards automatically.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import functools
import tempfile
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import (OptimizerConfig, RunConfig, ShapeCell,
                                SystemConfig)
from repro.configs.registry import get_smoke_config
from repro.core.engine import StepBundle
from repro.data.pipeline import DataConfig, ShardedLoader, SyntheticPackedLM
from repro.launch.mesh import make_mesh
from repro.optim.adamw import init_opt_state
from repro.runtime.elastic import mesh_meta, reshard_state


def run_steps(bundle, tp, fp, opt, loader, start, n):
    step = bundle.make_train_step()
    losses = []
    for i in range(start, start + n):
        tp, opt, m = step(tp, fp, opt, loader.get(i))
        losses.append(float(m["loss"]))
    return tp, opt, losses


def main():
    cfg = get_smoke_config("granite-3-8b")
    cell = ShapeCell("el", "train", 64, 8)
    run = RunConfig(model=cfg, shape=cell,
                    system=SystemConfig(mode="fcdp", min_shard_size=8),
                    optimizer=OptimizerConfig(lr=1e-3, total_steps=20,
                                              warmup_steps=2))
    big = make_mesh((2, 2, 2), ("pod", "data", "model"))     # "2 pods"
    b1 = StepBundle(run, big)
    loader1 = ShardedLoader(SyntheticPackedLM(cfg, cell, DataConfig(0)),
                            big, b1.batch_spec(cell))
    params = b1.init_all_params(seed=0)
    tp, fp = b1.split(params)
    opt = jax.jit(functools.partial(init_opt_state, sys=run.system))(tp)
    tp, opt, l1 = run_steps(b1, tp, fp, opt, loader1, 0, 6)
    print(f"phase 1 (2x2x2 'two pods'): losses {l1[0]:.3f} -> {l1[-1]:.3f}")

    ckpt = Checkpointer(tempfile.mkdtemp())
    ckpt.save(6, {"params": tp, "opt": opt}, blocking=True,
              meta=mesh_meta(big))
    print("checkpoint saved; simulating pod loss...")

    small = make_mesh((2, 2), ("data", "model"))             # one "pod"
    b2 = StepBundle(run, small)
    # carry-aware restore under the new bundle's shardings (a cross-step
    # carry, were one saved, would be invalidated here: mesh change)
    restored, carry_invalidated = reshard_state(
        ckpt, 6, b2, {"params": tp, "opt": opt})
    assert not carry_invalidated                 # fused run: no carry
    loader2 = ShardedLoader(SyntheticPackedLM(cfg, cell, DataConfig(0)),
                            small, b2.batch_spec(cell))
    tp2, fp2 = restored["params"], []
    tp2, opt2, l2 = run_steps(b2, tp2, fp2, restored["opt"], loader2, 6, 6)
    print(f"phase 2 (2x2 'one pod'):   losses {l2[0]:.3f} -> {l2[-1]:.3f}")
    assert l2[0] < l1[0] + 0.2, "loss regressed after elastic restart"
    print("elastic restart OK (state resharded 3-axis -> 2-axis mesh)")


if __name__ == "__main__":
    main()
