"""Streaming gather scheduler tests: SystemConfig depth validation and
back-compat, strategy stream capabilities, serve-path depth-k prefetch
parity, the async pod-axis gradient-reduce stream, and the
prefetch-aware FCDP-Cache planner."""
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                ShapeCell, SystemConfig)
from repro.core.engine import StepBundle
from repro.core.strategy import get_strategy

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=3, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                    qkv_bias=True)
CELL = ShapeCell("t", "train", 64, 8)
PREFILL = ShapeCell("p", "prefill", 32, 8)
DECODE = ShapeCell("d", "decode", 32, 8)


def make_bundle(mesh, cell=CELL, mode="fcdp", microbatch=0, **sys_kw):
    sysd = dict(mode=mode, min_shard_size=8)
    sysd.update(sys_kw)
    run = RunConfig(model=DENSE, shape=cell, system=SystemConfig(**sysd),
                    optimizer=OptimizerConfig(total_steps=8, warmup_steps=2,
                                              lr=1e-3),
                    microbatch=microbatch)
    return StepBundle(run, mesh)


def make_batch(seed=0):
    rng = np.random.default_rng(seed)
    b = {"ids": jnp.asarray(
            rng.integers(1, DENSE.vocab_size,
                         (CELL.global_batch, CELL.seq_len)), jnp.int32),
         "labels": jnp.asarray(
            rng.integers(1, DENSE.vocab_size,
                         (CELL.global_batch, CELL.seq_len)), jnp.int32)}
    b["mask"] = jnp.ones_like(b["labels"], bool)
    return b


def run_one_step(bundle):
    from repro.optim.adamw import init_opt_state
    params = bundle.init_all_params(seed=0)
    tp, fp = bundle.split(params)
    opt = jax.jit(functools.partial(
        init_opt_state, sys=bundle.run.system))(tp)
    step = bundle.make_train_step()
    tp, opt, m = step(tp, fp, opt, make_batch())
    return ({k: float(v) for k, v in m.items()},
            [np.asarray(x, np.float32) for x in tp])


# ---------------------------------------------------------------------------
# SystemConfig validation + prefetch_depth back-compat shim
# ---------------------------------------------------------------------------

def test_systemconfig_validation():
    with pytest.raises(ValueError, match="device_cache_fraction"):
        SystemConfig(device_cache_fraction=1.5)
    with pytest.raises(ValueError, match="device_cache_fraction"):
        SystemConfig(device_cache_fraction=-0.1)
    with pytest.raises(ValueError, match="activation_policy"):
        SystemConfig(activation_policy="bogus")
    with pytest.raises(ValueError, match="prefetch_depth"):
        SystemConfig(prefetch_depth=-1)


def test_prefetch_depth_legacy_shim():
    """The legacy bool maps to depth 1 WITH a DeprecationWarning (the
    one-release migration path before the InitVar is removed); the
    `prefetch` read view stays in sync (== prefetch_depth > 0); and
    because the bool is init-only (never carried by replace()), an
    explicit prefetch=False reliably disables the schedule even when a
    depth rides along."""
    assert SystemConfig().prefetch_depth == 0
    with pytest.warns(DeprecationWarning, match="prefetch_depth"):
        s = SystemConfig(prefetch=True)
    assert s.prefetch_depth == 1 and s.prefetch
    # the depth knob itself never warns
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        s = SystemConfig(prefetch_depth=3)
        assert s.replace(prefetch_depth=0).prefetch_depth == 0
    assert s.prefetch_depth == 3 and s.prefetch
    assert not s.replace(prefetch_depth=0).prefetch
    # the legacy-writer trap: toggling the bool off must actually
    # disable, not be overridden by the carried depth
    with pytest.warns(DeprecationWarning):
        off = s.replace(prefetch=False)
    assert off.prefetch_depth == 0 and not off.prefetch
    with pytest.warns(DeprecationWarning):
        on = SystemConfig().replace(prefetch=True)
    assert on.prefetch_depth == 1 and on.prefetch
    # an explicit bool wins over an explicit depth in one construction
    with pytest.warns(DeprecationWarning):
        assert SystemConfig(prefetch=False,
                            prefetch_depth=2).prefetch_depth == 0
    with pytest.warns(DeprecationWarning):
        assert SystemConfig(prefetch=True,
                            prefetch_depth=2).prefetch_depth == 2


def test_strategy_stream_capabilities():
    """max_prefetch_depth replaces the bare supports_prefetch flag (kept
    as a derived view); the resolved depth clamps to the capability and
    needs a pod axis; the async stream is gated the same way."""
    class M3:
        axis_names = ("pod", "data", "model")

    class M2:
        axis_names = ("data", "model")

    deep = SystemConfig(prefetch_depth=64)
    on = SystemConfig(async_grad_reduce=True)
    for mode in ("zero3", "zeropp", "fcdp"):
        s = get_strategy(mode)
        assert s.supports_prefetch
        assert s.prefetch_depth(deep, M3()) == s.max_prefetch_depth
        assert s.prefetch_depth(deep, M2()) == 0
        assert s.async_grad_reduce_active(on, M3())
        assert not s.async_grad_reduce_active(on, M2())
    for mode in ("mics", "hier"):
        s = get_strategy(mode)
        assert not s.supports_prefetch
        assert s.max_prefetch_depth == 0
        assert s.prefetch_depth(deep, M3()) == 0
        assert not s.async_grad_reduce_active(on, M3())


# ---------------------------------------------------------------------------
# Serve-path prefetch: prefill/decode parity sequential vs depth-k
# ---------------------------------------------------------------------------

def _serve_logits(mesh3, depth):
    """zeropp serving keeps frozen params pod-sharded, so the stateful
    scan has a non-empty stage 1 to prefetch (fcdp's serve_frozen layout
    is structurally sequential)."""
    b = make_bundle(mesh3, cell=PREFILL, mode="zeropp",
                    prefetch_depth=depth)
    params = b.init_all_params(seed=0)
    state = b.init_state(PREFILL)
    rng = np.random.default_rng(1)
    ids = jnp.asarray(rng.integers(1, DENSE.vocab_size, (8, 32)), jnp.int32)
    logits, state = b.make_prefill_step()(params, ids, state)
    bd = make_bundle(mesh3, cell=DECODE, mode="zeropp",
                     prefetch_depth=depth)
    tok = jnp.asarray(rng.integers(1, DENSE.vocab_size, (8, 1)), jnp.int32)
    dec_logits, _ = bd.make_decode_step()(params, tok, state)
    return (np.asarray(logits, np.float32),
            np.asarray(dec_logits, np.float32))


def test_serve_prefetch_parity(mesh3):
    """Prefill and decode logits on a multi-pod mesh match between the
    sequential and depth-k schedules (bf16 forward: tolerances absorb
    fusion/reduction-order noise; top-1 tokens must agree)."""
    seq_p, seq_d = _serve_logits(mesh3, depth=0)
    pf_p, pf_d = _serve_logits(mesh3, depth=2)
    for a, b in ((seq_p, pf_p), (seq_d, pf_d)):
        np.testing.assert_allclose(a, b, atol=0.06, rtol=0.06)
        assert (np.argmax(a, -1) == np.argmax(b, -1)).mean() > 0.95


# ---------------------------------------------------------------------------
# Async pod-axis gradient reduce (scheduler stream 2)
# ---------------------------------------------------------------------------

def test_async_grad_reduce_equivalence(mesh3):
    """The pipelined reduce must not change the math: a microbatched
    training step with the async stream on/off produces identical loss,
    grad norm, and updated parameters."""
    m_off, p_off = run_one_step(make_bundle(mesh3, microbatch=2))
    m_on, p_on = run_one_step(make_bundle(mesh3, microbatch=2,
                                          async_grad_reduce=True))
    np.testing.assert_allclose(m_on["loss"], m_off["loss"], rtol=1e-4)
    np.testing.assert_allclose(m_on["grad_norm"], m_off["grad_norm"],
                               rtol=1e-3)
    for a, b in zip(p_off, p_on):
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-3)


def _collect(bundle):
    from repro.launch.roofline import collect_collectives
    step = bundle.make_train_step()
    closed = step.trace(*bundle.train_input_sds()).jaxpr
    sizes = {a: bundle.mi.size(a) for a in bundle.mi.axis_names}
    return collect_collectives(closed, sizes)


def test_async_grad_reduce_comm_structure(mesh3):
    """The async stream moves the pod-axis reduce, it does not add any
    traffic: per-step DCN all-gather and reduce-scatter volumes are
    identical with the stream on/off under fcdp."""
    c_off = _collect(make_bundle(mesh3, microbatch=2))
    c_on = _collect(make_bundle(mesh3, microbatch=2,
                                async_grad_reduce=True))
    for key in ("all_gather/pod", "psum_scatter/pod"):
        np.testing.assert_allclose(c_on.by_op_axis.get(key, 0),
                                   c_off.by_op_axis.get(key, 0), rtol=1e-6)
    np.testing.assert_allclose(c_on.by_op.get("psum_scatter", 0),
                               c_off.by_op.get("psum_scatter", 0),
                               rtol=1e-6)
    np.testing.assert_allclose(c_on.dcn_bytes, c_off.dcn_bytes, rtol=1e-6)


# ---------------------------------------------------------------------------
# Prefetch-aware FCDP-Cache planner + analytic buffer accounting
# ---------------------------------------------------------------------------

def test_prefetch_buffer_accounting(mesh3):
    """The analytic ring-buffer cost scales linearly with depth, and a
    bundle whose plans have no stage 1 (serve_frozen fcdp) resolves to
    depth 0 with zero buffer bytes."""
    from repro.core.cache import cache_bytes_per_chip
    a1 = cache_bytes_per_chip(make_bundle(mesh3, prefetch_depth=1))
    a2 = cache_bytes_per_chip(make_bundle(mesh3, prefetch_depth=2))
    assert a1["prefetch_depth"] == 1 and a2["prefetch_depth"] == 2
    assert a1["prefetch_buffer_bytes_per_chip"] > 0
    np.testing.assert_allclose(a2["prefetch_buffer_bytes_per_chip"],
                               2 * a1["prefetch_buffer_bytes_per_chip"])
    frozen = cache_bytes_per_chip(
        make_bundle(mesh3, cell=DECODE, prefetch_depth=2))
    assert frozen["prefetch_depth"] == 0
    assert frozen["prefetch_buffer_bytes_per_chip"] == 0.0


def test_async_buffer_accounting(mesh3):
    """The async stream's resident stage-1 buffers (leaf-level gathered
    param view + carried grad buffer) are reported only when the stream
    is actually live for the run."""
    from repro.core.cache import cache_bytes_per_chip
    live = cache_bytes_per_chip(
        make_bundle(mesh3, microbatch=2, async_grad_reduce=True))
    assert live["async_buffer_bytes_per_chip"] > 0
    # flag off, no accumulation, or an unwilling strategy -> 0
    for b in (make_bundle(mesh3, microbatch=2),
              make_bundle(mesh3, async_grad_reduce=True),
              make_bundle(mesh3, mode="mics", microbatch=2,
                          async_grad_reduce=True)):
        assert cache_bytes_per_chip(b)["async_buffer_bytes_per_chip"] == 0.0


def test_planner_demotes_depth_before_device_cache(mesh3):
    """Over budget, the planner walks prefetch depth k -> 0 at the
    fastest device fraction before touching the fraction itself (a
    synthetic peak stands in for the compile measurement)."""
    from repro.core.cache import MemoryPlanner
    run = RunConfig(model=DENSE, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8,
                                        prefetch_depth=2),
                    optimizer=OptimizerConfig(total_steps=4, warmup_steps=1))

    class FakePeak(MemoryPlanner):
        def __init__(self, fit_at, **kw):
            super().__init__(**kw)
            self.fit_at = fit_at

        def _peak(self, bundle):
            s = bundle.run.system
            fits = (s.device_cache_fraction, s.prefetch_depth) == self.fit_at
            return 0 if fits else (1 << 50)

    plan = FakePeak(fit_at=(1.0, 0)).plan(run, mesh3, fractions=(1.0, 0.0))
    assert plan.fits and plan.device_fraction == 1.0
    assert plan.prefetch_depth == 0
    assert [(i["device_fraction"], i["prefetch_depth"])
            for i in plan.iterations] == [(1.0, 2), (1.0, 1), (1.0, 0)]
    assert all("prefetch_buffer_bytes" in i for i in plan.iterations)

    # a budget that fits at full depth keeps the ring
    plan2 = FakePeak(fit_at=(1.0, 2)).plan(run, mesh3, fractions=(1.0, 0.0))
    assert plan2.fits and plan2.prefetch_depth == 2

    # with no prefetch configured the search degenerates to the old
    # fraction walk (depth column pinned at 0)
    run0 = run.replace(system=run.system.replace(prefetch_depth=0))
    plan3 = FakePeak(fit_at=(0.0, 0)).plan(run0, mesh3,
                                           fractions=(1.0, 0.0))
    assert plan3.fits and plan3.device_fraction == 0.0
    assert [i["prefetch_depth"] for i in plan3.iterations] == [0, 0]


def test_roofline_per_depth_credit():
    """The overlap credit is min(stage-1 DCN time, total compute) for
    any depth >= 1 -- the shared DCN link can never hide more transfer
    time than the step has compute, so the bandwidth model is
    depth-invariant; what scales with depth is the ring's in-flight
    byte accounting riding along in the report."""
    from repro.launch.roofline import CollectiveStats, roofline_report

    def rep(depth, flops):
        stats = CollectiveStats()
        stats.add("all_gather", "pod", 4e9, is_dcn=True)
        stats.add("all_gather", "data", 8e9, is_dcn=False)
        return roofline_report(flops, 1e12, stats, DENSE, CELL, 8,
                               prefetch=depth, inflight_bytes=depth * 1e6)

    stage1_t = 4e9 / 25e9
    # comm-bound regime: credit saturates at the total compute term for
    # every depth >= 1
    lo = {d: rep(d, 1e13) for d in (0, 1, 2, 8)}
    assert lo[0]["prefetch"]["depth"] == 0
    assert lo[0]["prefetch"]["overlapped_s"] == 0
    for d in (1, 2, 8):
        assert lo[d]["prefetch"]["overlapped_s"] == pytest.approx(
            lo[d]["compute_s"])
        assert lo[d]["prefetch"]["overlapped_s"] <= lo[d]["compute_s"]
        assert lo[d]["prefetch"]["inflight_stage1_bytes_per_chip"] == \
            d * 1e6
        assert (lo[d]["prefetch"]["collective_exposed_s"]
                < lo[0]["collective_s"])
    # compute-rich regime: the full stage-1 time hides at any depth
    hi = rep(2, 1e15)
    assert hi["prefetch"]["overlapped_s"] == pytest.approx(stage1_t)
