"""Quantized collectives (qwZ stage-1 weight gather + int8 kernel paths).

Three layers of coverage:
  * kernel: Pallas quant kernels (interpret mode) bit-exact against the
    kernels/ref.py jnp oracles across shapes/dtypes, incl. tensors that
    are not a multiple of the 256 block;
  * plan: the strategy-level qwZ gates (param_compress config, per-group
    supports_quantized_gather, the sub-block small-leaf gate);
  * e2e: training under param_compress='int8_pod' tracks the exact run
    within a bounded loss drift, stacks with FCDP host caching (single
    quantized fwd stage-1 gather; backward stays gather-free), and
    composes with the async grad-reduce stream.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.quant import BLOCK

pytestmark = pytest.mark.pallas_interpret

# ---------------------------------------------------------------------------
# kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb", [1, 3, 8, 17])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_blocks_bit_exact(nb, dtype, rng):
    x = jnp.asarray(rng.normal(0, 3, (nb, BLOCK)), dtype).astype(jnp.float32)
    qk, sk = ops.int8_quantize_blocks(x, impl="pallas", interpret=True)
    qr, sr = ref.int8_quantize_blocks_ref(x)
    assert qk.dtype == jnp.int8 and sk.shape == (nb, 1)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


def test_quantize_blocks_zero_and_const_blocks(rng):
    """All-zero blocks hit the scale floor; constant blocks hit +-127."""
    x = jnp.concatenate([jnp.zeros((1, BLOCK)),
                         jnp.full((1, BLOCK), 7.5),
                         jnp.full((1, BLOCK), -0.25)]).astype(jnp.float32)
    qk, sk = ops.int8_quantize_blocks(x, impl="pallas", interpret=True)
    qr, sr = ref.int8_quantize_blocks_ref(x)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
    assert np.all(np.asarray(qk[0]) == 0)
    assert np.all(np.abs(np.asarray(qk[1:])) == 127)


@pytest.mark.parametrize("nb", [1, 5, 16])
def test_dequantize_blocks_bit_exact(nb, rng):
    q = jnp.asarray(rng.integers(-127, 128, (nb, BLOCK)), jnp.int8)
    s = jnp.asarray(2.0 ** rng.integers(-8, 3, (nb, 1)), jnp.float32)
    out = ops.int8_dequantize_blocks(q, s, impl="pallas", interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.int8_dequantize_blocks_ref(q, s)))


@pytest.mark.parametrize("n,nb", [(2, 5), (4, 8), (3, 1), (8, 17)])
def test_dequant_accumulate_bit_exact_pow2(n, nb, rng):
    """Power-of-two scales make every product and sum exactly
    representable, so kernel-vs-oracle must agree to the bit."""
    q = jnp.asarray(rng.integers(-127, 128, (n, nb, BLOCK)), jnp.int8)
    s = jnp.asarray(2.0 ** rng.integers(-8, 2, (n, nb, 1)), jnp.float32)
    out = ops.int8_dequant_accumulate(q, s, impl="pallas", interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.int8_dequant_acc_ref(q, s)))


def test_dequant_accumulate_random_scales_close(rng):
    """Arbitrary scales: FMA fusion differences bound the comparison to
    last-ulp (the accumulate order itself is identical)."""
    q = jnp.asarray(rng.integers(-127, 128, (4, 8, BLOCK)), jnp.int8)
    s = jnp.asarray(np.abs(rng.normal(0, 0.05, (4, 8, 1))) + 1e-4,
                    jnp.float32)
    out = ops.int8_dequant_accumulate(q, s, impl="pallas", interpret=True)
    # atol covers near-cancelling sums where relative error is unbounded
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.int8_dequant_acc_ref(q, s)),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(100,), (256,), (300, 7), (31, 33)])
def test_quantize_pad_path_impl_agreement(shape, rng):
    """Non-multiple-of-256 tensors take the shared pad path in
    grad_compress._quantize: jnp and interpret-Pallas must agree."""
    from repro.core.grad_compress import _quantize
    g = jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
    qj, sj = _quantize(g, impl="jnp")
    qp, sp = _quantize(g, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(qj), np.asarray(qp))
    np.testing.assert_array_equal(np.asarray(sj), np.asarray(sp))
    # round-trip error bounded by half an lsb per element
    deq = ops.int8_dequantize_blocks(qj, sj, impl="jnp").reshape(-1)
    flat = np.asarray(g, np.float32).reshape(-1)
    lsb = np.asarray(sj)[:, 0].repeat(BLOCK)[: flat.size]
    assert np.all(np.abs(np.asarray(deq)[: flat.size] - flat) <= 0.5 * lsb)


# ---------------------------------------------------------------------------
# plan-level gating
# ---------------------------------------------------------------------------


def _plan(pdef, mesh3, **kw):
    from repro.core.strategy import get_strategy
    return get_strategy("fcdp").gather_plan(pdef, mesh3, min_shard_size=8,
                                            **kw)


def test_param_compress_gate_big_vs_small_leaf(mesh3):
    from repro.core.partition import ParamDef
    big = ParamDef((4, 64, 64), ("stack", "fsdp", "tp"))
    small = ParamDef((4, 64), ("stack", "fsdp"))   # 16 elems/slice shard
    p_big = _plan(big, mesh3, param_compress=True, compress_bwd=True)
    p_small = _plan(small, mesh3, param_compress=True, compress_bwd=True)
    assert p_big.compress_fwd and p_big.compress_bwd
    # sub-block shards would pay MORE wire bytes quantized than exact
    assert not p_small.compress_fwd and not p_small.compress_bwd
    # and the knob itself defaults off
    p_off = _plan(big, mesh3)
    assert not p_off.compress_fwd and not p_off.compress_bwd


def test_frozen_leaves_never_quantize(mesh3):
    from repro.core.partition import ParamDef
    frozen = ParamDef((4, 64, 64), ("stack", "fsdp", "tp"), frozen=True)
    p = _plan(frozen, mesh3, param_compress=True, compress_bwd=True)
    assert not p.compress_fwd and not p.compress_bwd


def test_config_validation():
    from repro.configs.base import SystemConfig
    with pytest.raises(ValueError):
        SystemConfig(param_compress="int4")
    with pytest.raises(ValueError):
        SystemConfig(quant_impl="triton")
    s = SystemConfig(param_compress="int8_pod", quant_impl="pallas_interpret")
    assert s.param_compress == "int8_pod"


def test_composite_group_gating(mesh3):
    """A declining group inside a quantized bundle keeps its exact bf16
    stage-1 gather; the fcdp trunk quantizes."""
    from repro.configs.base import ModelConfig, SystemConfig
    from repro.core.partition import label_tree
    from repro.core.strategy import FCDP, register_strategy, resolve_strategies
    from repro.models.lm import LM

    class FCDPNoQuant(FCDP):
        name = "fcdp_nq"
        supports_quantized_gather = False

    register_strategy(FCDPNoQuant)
    cfg = ModelConfig(name="smoke-dense", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256)
    sysc = SystemConfig(mode="fcdp", min_shard_size=8,
                        param_compress="int8_pod",
                        mode_overrides=(("head", "fcdp_nq"),))
    model = LM(cfg, sysc, mesh3)
    assert not model.plans["head"].compress_fwd        # declining group
    assert model.plans["embed"].compress_fwd           # fcdp trunk
    assert model.plans["blocks"]["pos0"]["attn"]["wq"].compress_fwd
    # sub-block norm leaves stay exact inside the quantizing trunk too
    assert not model.plans["blocks"]["pos0"]["attn"]["norm"].compress_fwd


# ---------------------------------------------------------------------------
# e2e: loss drift, caching, async composability
# ---------------------------------------------------------------------------

_CFG = dict(name="smoke-dense", family="dense", num_layers=2, d_model=64,
            num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)


def _train(mesh3, rng, n_steps=3, microbatch=0, **sys_kw):
    from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                    ShapeCell, SystemConfig)
    from repro.core.engine import StepBundle
    from repro.optim.adamw import init_opt_state
    sysc = SystemConfig(mode="fcdp", min_shard_size=8, **sys_kw)
    run = RunConfig(model=ModelConfig(**_CFG), shape=ShapeCell(
        "t", "train", 64, 8), system=sysc,
        optimizer=OptimizerConfig(total_steps=8, warmup_steps=1),
        microbatch=microbatch)
    b = StepBundle(run, mesh3)
    step = b.make_train_step()
    params = b.init_all_params(seed=0)
    tp, fp = b.split(params)
    opt = jax.jit(functools.partial(init_opt_state, sys=sysc))(tp)
    losses = []
    r = np.random.default_rng(7)
    for _ in range(n_steps):
        batch = {"ids": jnp.asarray(r.integers(1, 256, (8, 64)), jnp.int32),
                 "labels": jnp.asarray(r.integers(1, 256, (8, 64)),
                                       jnp.int32),
                 "mask": jnp.ones((8, 64), bool)}
        tp, opt, m = step(tp, fp, opt, batch)
        losses.append(float(m["loss"]))
    return losses, b


def test_e2e_quantized_gather_loss_drift(mesh3, rng):
    exact, _ = _train(mesh3, rng)
    quant, b = _train(mesh3, rng, param_compress="int8_pod")
    drift = max(abs(a - e) / abs(e) for a, e in zip(quant, exact))
    assert drift < 1e-2, (quant, exact)
    # and the step still pays only ONE (quantized) stage-1 gather per
    # leaf per step: pod-axis AG bytes shrink vs the exact run
    from repro.launch.roofline import collect_collectives
    sizes = {a: b.mi.size(a) for a in b.mi.axis_names}
    s_q = collect_collectives(
        b.make_train_step().trace(*b.train_input_sds()).jaxpr, sizes)
    _, b_e = _train(mesh3, rng, n_steps=1)
    s_e = collect_collectives(
        b_e.make_train_step().trace(*b_e.train_input_sds()).jaxpr, sizes)
    assert s_q.by_op_axis["all_gather/pod"] \
        < 0.55 * s_e.by_op_axis["all_gather/pod"]


def test_async_reduce_composes_with_int8(mesh3, rng):
    """Satellite: async_grad_reduce no longer requires
    grad_compress='none' -- the int8 reduce rides the async stream.
    Block boundaries differ (leaf-level vs per-layer quantization), so
    the comparison is tolerance-based, not bit-exact."""
    from repro.core.schedule import async_reduce_enabled
    sync, _ = _train(mesh3, rng, microbatch=2, grad_compress="int8_pod",
                     param_compress="int8_pod")
    async_, b = _train(mesh3, rng, microbatch=2, grad_compress="int8_pod",
                       param_compress="int8_pod", async_grad_reduce=True)
    assert async_reduce_enabled(b.run, b.strategy, b.mi)
    for a, s in zip(async_, sync):
        assert abs(a - s) / abs(s) < 5e-2, (async_, sync)


def test_quantized_gather_shard_map_impl_agreement(mesh3, rng):
    """quantized_stage1_gather under shard_map: the pallas_interpret
    kernel path must match the jnp path bit-for-bit (same quant grid)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.grad_compress import quantized_stage1_gather
    w = jnp.asarray(rng.normal(0, 1, (16, 64)), jnp.float32)

    def run(impl):
        f = shard_map(
            lambda x: quantized_stage1_gather(x, "pod", 0, False, impl),
            mesh=mesh3, in_specs=P("pod"), out_specs=P(),
            check_rep=False)    # all_gather output is VMA-varying
        return np.asarray(jax.jit(f)(w))

    out_jnp = run("jnp")
    np.testing.assert_array_equal(out_jnp, run("pallas_interpret"))
    # the gather is lossy-but-bounded: within half an lsb per block
    assert np.max(np.abs(out_jnp - np.asarray(w))) <= 0.5 * np.max(
        np.abs(np.asarray(w))) / 127.0 + 1e-6
