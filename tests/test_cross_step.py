"""Cross-step pipelined optimizer stream (scheduler stream 3) tests:
construction-time validation, the strategy capability surface, bit
parity of the prime/piped/flush schedule against the fused step on
uniform AND mixed-mode bundles, byte-identical steady-state DCN volume,
carry-buffer accounting, planner demotion order (cross-step before
prefetch depth before device fraction), and the dry-run/roofline JSON
schema carrying ``cross_step_buffer_bytes``."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ModelConfig, MoEConfig, OptimizerConfig,
                                RunConfig, ShapeCell, SystemConfig)
from repro.core.engine import StepBundle
from repro.core.strategy import CompositeStrategy, get_strategy

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=3, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                    qkv_bias=True)
MOE = ModelConfig(name="t-moe", family="moe", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=256,
                  moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64))
CELL = ShapeCell("t", "train", 64, 8)
MIXED_RULES = (("blocks.*.moe.we_*", "mics"), ("embed", "hier"))


def make_bundle(mesh, cfg=DENSE, microbatch=2, **sys_kw):
    sysd = dict(mode="fcdp", min_shard_size=8, async_grad_reduce=True)
    sysd.update(sys_kw)
    run = RunConfig(model=cfg, shape=CELL, system=SystemConfig(**sysd),
                    optimizer=OptimizerConfig(total_steps=8, warmup_steps=2,
                                              lr=1e-3),
                    microbatch=microbatch)
    return StepBundle(run, mesh)


def make_batches(n, vocab=256):
    out = []
    for s in range(n):
        rng = np.random.default_rng(s)
        out.append({"ids": jnp.asarray(
                        rng.integers(1, vocab, (CELL.global_batch,
                                                CELL.seq_len)), jnp.int32),
                    "labels": jnp.asarray(
                        rng.integers(1, vocab, (CELL.global_batch,
                                                CELL.seq_len)), jnp.int32),
                    "mask": jnp.ones((CELL.global_batch, CELL.seq_len),
                                     bool)})
    return out


def _init(bundle):
    from repro.optim.adamw import init_opt_state
    params = bundle.init_all_params(seed=0)
    tp, fp = bundle.split(params)
    opt = jax.jit(functools.partial(
        init_opt_state, sys=bundle.run.system))(tp)
    return tp, fp, opt


def run_fused(bundle, batches):
    tp, fp, opt = _init(bundle)
    step = bundle.make_train_step()
    losses, gnorms = [], []
    for b in batches:
        tp, opt, m = step(tp, fp, opt, b)
        losses.append(float(m["loss"]))
        gnorms.append(float(m["grad_norm"]))
    return losses, gnorms, [np.asarray(x, np.float32) for x in tp]


def run_piped(bundle, batches):
    tp, fp, opt = _init(bundle)
    prime, piped = bundle.make_train_prime(), bundle.make_train_step()
    flush = bundle.make_train_flush()
    losses, gnorms = [], []
    carry, m = prime(tp, fp, opt, batches[0])
    losses.append(float(m["loss"]))
    for b in batches[1:]:
        tp, opt, carry, m = piped(tp, fp, opt, carry, b)
        losses.append(float(m["loss"]))
        gnorms.append(float(m["grad_norm"]))
    tp, opt, m = flush(tp, opt, carry)
    gnorms.append(float(m["grad_norm"]))
    return losses, gnorms, [np.asarray(x, np.float32) for x in tp]


# ---------------------------------------------------------------------------
# Construction-time validation + capability surface
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="async_grad_reduce"):
        SystemConfig(cross_step_pipeline=True)
    ok = SystemConfig(cross_step_pipeline=True, async_grad_reduce=True)
    assert ok.cross_step_pipeline
    with pytest.raises(ValueError, match="microbatch"):
        RunConfig(model=DENSE, shape=CELL, system=ok)
    with pytest.raises(ValueError, match="microbatch"):
        RunConfig(model=DENSE, shape=CELL, system=ok, microbatch=1)
    run = RunConfig(model=DENSE, shape=CELL, system=ok, microbatch=2)
    # replace() re-validates: dropping accumulation must be rejected too
    with pytest.raises(ValueError, match="microbatch"):
        run.replace(microbatch=0)


def test_strategy_capability():
    class M3:
        axis_names = ("pod", "data", "model")

    class M2:
        axis_names = ("data", "model")

    on = SystemConfig(async_grad_reduce=True, cross_step_pipeline=True)
    off = SystemConfig(async_grad_reduce=True)
    for mode in ("zero3", "zeropp", "fcdp"):
        s = get_strategy(mode)
        assert s.supports_cross_step
        assert s.cross_step_active(on, M3())
        assert not s.cross_step_active(on, M2())      # no slow tier
        assert not s.cross_step_active(off, M3())     # flag off
    for mode in ("mics", "hier"):
        s = get_strategy(mode)
        assert not s.supports_cross_step
        assert not s.cross_step_active(on, M3())
    # composite: any streaming group enables the carry (the deferred
    # epilogue then covers the single-stage groups' collectives too)
    mixed = CompositeStrategy(get_strategy("fcdp"),
                              {"fcdp": get_strategy("fcdp"),
                               "mics": get_strategy("mics")})
    assert mixed.supports_cross_step and mixed.cross_step_active(on, M3())
    pure_rep = CompositeStrategy(get_strategy("mics"),
                                 {"mics": get_strategy("mics"),
                                  "hier": get_strategy("hier")})
    assert not pure_rep.supports_cross_step


# ---------------------------------------------------------------------------
# Bit parity: fused vs prime/piped/flush
# ---------------------------------------------------------------------------

def test_cross_step_bit_parity_uniform(mesh3):
    """The pipeline only moves the epilogue's latency: losses, shifted
    grad norms, and post-update shards are bit-identical to the fused
    schedule over a 3-step run (the acceptance criterion)."""
    batches = make_batches(3)
    l_off, g_off, p_off = run_fused(make_bundle(mesh3), batches)
    l_on, g_on, p_on = run_piped(
        make_bundle(mesh3, cross_step_pipeline=True), batches)
    assert l_on == l_off
    assert g_on == g_off       # piped reports step i's norm at step i+1,
    #                            flush reports the last: same sequence
    for a, b in zip(p_off, p_on):
        np.testing.assert_array_equal(a, b)


def test_cross_step_bit_parity_mixed(mesh3):
    """Same parity on a mixed-mode bundle (fcdp trunk + mics experts +
    hier embedding): the deferred epilogue covers the widened hier
    reduce-scatter/all-gather and the pre-VMA replicated-grad psums of
    the single-stage groups."""
    batches = make_batches(2)
    l_off, _, p_off = run_fused(
        make_bundle(mesh3, cfg=MOE, mode_overrides=MIXED_RULES), batches)
    on = make_bundle(mesh3, cfg=MOE, mode_overrides=MIXED_RULES,
                     cross_step_pipeline=True)
    assert on.cross_step
    l_on, _, p_on = run_piped(on, batches)
    assert l_on == l_off
    for a, b in zip(p_off, p_on):
        np.testing.assert_array_equal(a, b)


def test_cross_step_comm_structure(mesh3):
    """The steady-state piped step's per-step DCN volume is
    byte-identical to the fused step: prime defers one reduce + one
    epilogue, every piped step retires exactly one while deferring its
    own."""
    from repro.launch.roofline import collect_collectives

    def collect(bundle):
        closed = bundle.make_train_step().trace(
            *bundle.train_input_sds()).jaxpr
        sizes = {a: bundle.mi.size(a) for a in bundle.mi.axis_names}
        return collect_collectives(closed, sizes)

    c_off = collect(make_bundle(mesh3))
    c_on = collect(make_bundle(mesh3, cross_step_pipeline=True))
    for key in ("all_gather/pod", "psum_scatter/pod"):
        np.testing.assert_allclose(c_on.by_op_axis.get(key, 0),
                                   c_off.by_op_axis.get(key, 0), rtol=1e-6)
    np.testing.assert_allclose(c_on.dcn_bytes, c_off.dcn_bytes, rtol=1e-6)


# ---------------------------------------------------------------------------
# Accounting + planner demotion order + report schema
# ---------------------------------------------------------------------------

def test_cross_step_buffer_accounting(mesh3):
    """The step-boundary carry (storage-level g_acc + stage-1-level
    pending) is accounted only when the stream is live, and the
    per-group split sums to the total."""
    from repro.core.cache import cache_bytes_per_chip
    live = cache_bytes_per_chip(make_bundle(mesh3,
                                            cross_step_pipeline=True))
    assert live["cross_step"]
    assert live["cross_step_buffer_bytes_per_chip"] > 0
    np.testing.assert_allclose(
        sum(g["cross_step_buffer_bytes_per_chip"]
            for g in live["by_group"].values()),
        live["cross_step_buffer_bytes_per_chip"])
    # the carry strictly contains the async stream's grad buffer story:
    # stage-1 pending + a storage-level accumulator per trainable leaf
    for b in (make_bundle(mesh3),                       # flag off
              make_bundle(mesh3, mode="mics",           # unwilling strategy
                          cross_step_pipeline=True)):
        acct = cache_bytes_per_chip(b)
        assert not acct["cross_step"]
        assert acct["cross_step_buffer_bytes_per_chip"] == 0.0


def test_planner_demotes_cross_step_first(mesh3):
    """Over budget, the planner drops the cross-step carry before
    walking prefetch depth, before touching the device fraction."""
    from repro.core.cache import MemoryPlanner
    run = RunConfig(model=DENSE, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8,
                                        prefetch_depth=2,
                                        async_grad_reduce=True,
                                        cross_step_pipeline=True),
                    optimizer=OptimizerConfig(total_steps=4,
                                              warmup_steps=1),
                    microbatch=2)

    class FakePeak(MemoryPlanner):
        def __init__(self, fit_at, **kw):
            super().__init__(**kw)
            self.fit_at = fit_at

        def _peak(self, bundle):
            s = bundle.run.system
            fits = (s.device_cache_fraction, s.prefetch_depth,
                    s.cross_step_pipeline) == self.fit_at
            return 0 if fits else (1 << 50)

    plan = FakePeak(fit_at=(1.0, 2, False)).plan(run, mesh3,
                                                 fractions=(1.0, 0.0))
    assert plan.fits and plan.prefetch_depth == 2 and not plan.cross_step
    assert [(i["device_fraction"], i["prefetch_depth"], i["cross_step"])
            for i in plan.iterations] == [(1.0, 2, True), (1.0, 2, False)]
    assert plan.iterations[0]["cross_step_buffer_bytes"] > 0
    assert plan.iterations[1]["cross_step_buffer_bytes"] == 0.0

    # a budget that fits immediately keeps the pipeline
    plan2 = FakePeak(fit_at=(1.0, 2, True)).plan(run, mesh3,
                                                 fractions=(1.0, 0.0))
    assert plan2.fits and plan2.cross_step and plan2.prefetch_depth == 2
    assert len(plan2.iterations) == 1

    # without the flag the search is exactly the old depth/fraction walk
    run0 = run.replace(system=run.system.replace(
        cross_step_pipeline=False))
    plan3 = FakePeak(fit_at=(1.0, 1, False)).plan(run0, mesh3,
                                                  fractions=(1.0, 0.0))
    assert plan3.fits and not plan3.cross_step
    assert [i["prefetch_depth"] for i in plan3.iterations] == [2, 1]


def test_roofline_report_cross_step_schema():
    """The dry-run JSON path carries the carry-buffer bytes: the report
    echoes (enabled, carry_buffer_bytes_per_chip) without touching the
    bandwidth terms -- per-step DCN volume is byte-identical, so stream
    3's only visible side here is its HBM price."""
    from repro.launch.roofline import CollectiveStats, roofline_report
    stats = CollectiveStats()
    stats.add("all_gather", "pod", 4e9, is_dcn=True)
    base = roofline_report(1e13, 1e12, stats, DENSE, CELL, 8)
    on = roofline_report(1e13, 1e12, stats, DENSE, CELL, 8,
                         cross_step=True, cross_step_bytes=123.0)
    assert base["cross_step"] == {"enabled": False,
                                  "carry_buffer_bytes_per_chip": 0.0}
    assert on["cross_step"] == {"enabled": True,
                                "carry_buffer_bytes_per_chip": 123.0}
    for key in ("compute_s", "memory_s", "collective_s", "dcn_s", "ici_s"):
        assert on[key] == base[key]


def test_dryrun_json_carries_cross_step(monkeypatch):
    """dryrun_cell's JSON row reports the live cross-step flag, a
    nonzero carry-buffer size, and the roofline echo (toy mesh via the
    production-mesh builder, as in test_composite)."""
    import dataclasses

    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_mesh
    monkeypatch.setattr(
        dr, "make_production_mesh",
        lambda multi_pod=False: make_mesh((2, 2, 2),
                                          ("pod", "data", "model")))
    monkeypatch.setattr(
        dr, "get_config", lambda arch: dataclasses.replace(DENSE, name=arch))
    monkeypatch.setattr(dr, "cell_supported", lambda cfg, cell: (True, ""))
    monkeypatch.setattr(dr, "shape_cell", lambda name: CELL)
    r = dr.dryrun_cell("toy", "train_4k", True, "fcdp",
                       system_overrides={"min_shard_size": 8,
                                         "loss_chunk": 0},
                       verbose=False, microbatch=2,
                       async_grad_reduce=True, cross_step=True)
    assert r["status"] == "ok"
    assert r["cross_step"]
    assert r["cross_step_buffer_bytes_per_chip"] > 0
    assert r["roofline"]["cross_step"] == {
        "enabled": True,
        "carry_buffer_bytes_per_chip": r["cross_step_buffer_bytes_per_chip"]}
    # and off by default
    r0 = dr.dryrun_cell("toy", "train_4k", True, "fcdp",
                        system_overrides={"min_shard_size": 8,
                                          "loss_chunk": 0},
                        verbose=False)
    assert not r0["cross_step"]
    assert r0["cross_step_buffer_bytes_per_chip"] == 0.0


def test_train_input_sds_carries_cross_step(mesh3):
    """StepBundle.train_input_sds grows the carry argument exactly when
    the pipeline is live, and the piped step lowers against it (the
    planner/dry-run path)."""
    b = make_bundle(mesh3, cross_step_pipeline=True)
    sds = b.train_input_sds()
    assert len(sds) == 5
    carry = sds[3]
    assert set(carry) == {"g_acc", "pending"}
    assert len(carry["g_acc"]) == len(b.train_idx)
    b.make_train_step().lower(*sds)      # must not raise
    assert len(make_bundle(mesh3).train_input_sds()) == 4
