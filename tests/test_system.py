"""End-to-end behaviour tests for the FCDP system.

Covers: numerical equivalence of fcdp/zeropp/mics against the zero3
baseline (the paper's correctness claim -- caching must not change
math), comm-schedule structure (backward re-gather axes per mode),
PEFT classification, and training convergence per family.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (MambaConfig, ModelConfig, MoEConfig,
                                OptimizerConfig, RWKVConfig, RunConfig,
                                ShapeCell, SystemConfig)
from repro.core.engine import StepBundle
from repro.optim.adamw import init_opt_state

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                    qkv_bias=True)
CELL = ShapeCell("t", "train", 64, 8)


def make_bundle(mesh, cfg=DENSE, mode="fcdp", cell=CELL, **sys_kw):
    sysd = dict(mode=mode, min_shard_size=8)
    sysd.update(sys_kw)
    run = RunConfig(model=cfg, shape=cell, system=SystemConfig(**sysd),
                    optimizer=OptimizerConfig(total_steps=8, warmup_steps=2,
                                              lr=1e-3))
    return StepBundle(run, mesh)


def make_batch(cfg, cell, seed=0):
    rng = np.random.default_rng(seed)
    b = {"ids": jnp.asarray(
            rng.integers(1, cfg.vocab_size, (cell.global_batch, cell.seq_len)),
            jnp.int32),
         "labels": jnp.asarray(
            rng.integers(1, cfg.vocab_size, (cell.global_batch, cell.seq_len)),
            jnp.int32)}
    b["mask"] = jnp.ones_like(b["labels"], bool)
    if cfg.num_encoder_layers > 0:
        b["enc_embeds"] = jnp.asarray(
            rng.standard_normal((cell.global_batch,
                                 max(cell.seq_len // 4, 8), cfg.d_model)),
            jnp.bfloat16)
    return b


def run_steps(bundle, n=2, seed=0):
    params = bundle.init_all_params(seed=0)
    tp, fp = bundle.split(params)
    opt = jax.jit(functools.partial(
        init_opt_state, sys=bundle.run.system))(tp)
    step = bundle.make_train_step()
    batch = make_batch(bundle.run.model, bundle.run.shape, seed)
    ms = []
    for _ in range(n):
        tp, opt, m = step(tp, fp, opt, batch)
        ms.append({k: float(v) for k, v in m.items()})
    return tp, ms


# ---------------------------------------------------------------------------
# The paper's correctness invariant: the caching schedule must not change
# the math. All four systems produce identical losses and gradients.
# ---------------------------------------------------------------------------

def test_modes_numerically_equivalent(mesh3):
    """One training step must produce the same loss, grad norm, and
    updated parameters in every mode (caching cannot change the math).
    Tolerances absorb f32 collective reduction-order nondeterminism."""
    out = {}
    for mode in ("zero3", "zeropp", "fcdp", "mics"):
        tp, ms = run_steps(make_bundle(mesh3, mode=mode), n=1)
        out[mode] = (ms[0]["loss"], ms[0]["grad_norm"],
                     [np.asarray(x, np.float32) for x in tp])
    base_loss, base_gnorm, base_params = out["zero3"]
    for mode in ("zeropp", "fcdp", "mics"):
        loss, gnorm, params = out[mode]
        np.testing.assert_allclose(loss, base_loss, rtol=1e-4,
                                   err_msg=f"{mode} loss != zero3")
        np.testing.assert_allclose(gnorm, base_gnorm, rtol=1e-3)
        for a, b in zip(base_params, params):
            np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-3,
                                       err_msg=f"{mode} params != zero3")


def test_loss_decreases_all_families(mesh3):
    cfgs = {
        "dense": DENSE,
        "moe": ModelConfig(name="t-moe", family="moe", num_layers=2,
                           d_model=64, num_heads=4, num_kv_heads=2, d_ff=64,
                           vocab_size=256,
                           moe=MoEConfig(num_experts=4, top_k=2,
                                         d_ff_expert=64)),
        "ssm": ModelConfig(name="t-rwkv", family="ssm", num_layers=2,
                           d_model=64, num_heads=0, num_kv_heads=0, d_ff=128,
                           vocab_size=256,
                           rwkv=RWKVConfig(head_dim=16, decay_lora=8)),
        "hybrid": ModelConfig(
            name="t-jamba", family="hybrid", num_layers=4, d_model=64,
            num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
            mamba=MambaConfig(d_state=8, dt_rank=8),
            moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                          moe_period=2, moe_offset=1),
            hybrid_period=2, hybrid_attn_positions=(0,)),
        "encdec": ModelConfig(
            name="t-encdec", family="encdec", num_layers=2, d_model=64,
            num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
            num_encoder_layers=2, act="gelu", frontend="audio_frames"),
    }
    for fam, cfg in cfgs.items():
        _, ms = run_steps(make_bundle(mesh3, cfg=cfg), n=4)
        losses = [m["loss"] for m in ms]
        assert all(np.isfinite(losses)), f"{fam}: non-finite loss"
        assert losses[-1] < losses[0], f"{fam}: loss not decreasing {losses}"


# ---------------------------------------------------------------------------
# Comm schedule structure: the jaxpr must contain exactly the collective
# pattern Table VII is built on.
# ---------------------------------------------------------------------------

def _collect(bundle):
    from repro.launch.roofline import collect_collectives
    step = bundle.make_train_step()
    closed = step.trace(*bundle.train_input_sds()).jaxpr
    sizes = {a: bundle.mi.size(a) for a in bundle.mi.axis_names}
    return collect_collectives(closed, sizes)


def test_fcdp_halves_backward_pod_allgather(mesh3):
    z3 = _collect(make_bundle(mesh3, mode="zero3"))
    fc = _collect(make_bundle(mesh3, mode="fcdp"))
    # fcdp eliminates the backward pod-stage all-gather: pod-axis AG bytes
    # must drop by ~half (fwd-only), reduce-scatter unchanged.
    z3_ag = z3.by_op.get("all_gather", 0)
    fc_ag = fc.by_op.get("all_gather", 0)
    assert fc.dcn_bytes < z3.dcn_bytes * 0.8, (fc.dcn_bytes, z3.dcn_bytes)
    assert fc_ag < z3_ag
    np.testing.assert_allclose(fc.by_op.get("psum_scatter", 0),
                               z3.by_op.get("psum_scatter", 0), rtol=1e-6)


def test_mics_has_zero_dcn_allgather(mesh3):
    mi = _collect(make_bundle(mesh3, mode="mics"))
    # MiCS shards within the pod: all parameter all-gathers are ICI-only;
    # only gradient reduction (psum) crosses pods.
    assert mi.by_op.get("all_gather", 0) > 0
    assert mi.by_op_axis.get("all_gather/pod", 0) == 0
    assert mi.by_op_axis.get("psum_scatter/pod", 0) == 0
    assert mi.by_op_axis.get("psum/pod", 0) > 0   # grad all-reduce


def test_peft_eliminates_dcn_traffic(mesh3):
    """FCDP-Comm: frozen weights never cross DCN -- the pod-axis
    all-gather volume must collapse to the (tiny) LoRA adapters. At this
    toy scale replicated-bias gradient psums keep total DCN non-zero,
    so the assertion targets the all-gather/reduce-scatter components
    the paper's Table VII measures."""
    full = _collect(make_bundle(mesh3, mode="fcdp"))
    peft = _collect(make_bundle(mesh3, mode="fcdp", peft=True))
    full_ag = full.by_op_axis.get("all_gather/pod", 0)
    peft_ag = peft.by_op_axis.get("all_gather/pod", 0)
    assert peft_ag < full_ag * 0.12, (peft_ag, full_ag)
    full_rs = full.by_op_axis.get("psum_scatter/pod", 0)
    peft_rs = peft.by_op_axis.get("psum_scatter/pod", 0)
    assert peft_rs < full_rs * 0.12, (peft_rs, full_rs)
    assert peft.dcn_bytes < full.dcn_bytes * 0.25


def test_peft_classification(mesh3):
    b = make_bundle(mesh3, mode="fcdp", peft=True)
    n_train = len(b.train_idx)
    n_frozen = len(b.frozen_idx)
    assert n_train > 0 and n_frozen > 0
    # trainable = lora adapters only
    for i in b.train_idx:
        assert "_lora_" in b.def_leaves[i].label
    # trainable params are a small fraction
    train_sz = sum(b.def_leaves[i].size() for i in b.train_idx)
    total_sz = sum(d.size() for d in b.def_leaves)
    assert train_sz / total_sz < 0.2


def test_peft_training_updates_only_adapters(mesh3):
    b = make_bundle(mesh3, mode="fcdp", peft=True)
    params = b.init_all_params(seed=0)
    tp0, fp = b.split(params)
    # snapshot before the step: inputs are donated
    tp0_np = [np.asarray(x, np.float32) for x in tp0]
    opt = jax.jit(functools.partial(init_opt_state, sys=b.run.system))(tp0)
    step = b.make_train_step()
    batch = make_batch(b.run.model, b.run.shape)
    tp1, opt, m = step(tp0, fp, opt, batch)
    assert np.isfinite(m["loss"])
    changed = any(
        not np.allclose(a, np.asarray(bb, np.float32))
        for a, bb in zip(tp0_np, tp1))
    assert changed, "lora adapters did not update"


# ---------------------------------------------------------------------------
# Gradient correctness vs single-device reference (the sharded system
# computes the same gradients as unsharded jax).
# ---------------------------------------------------------------------------

def test_grads_match_unsharded_reference(mesh2):
    # tiny single-layer dense model, fcdp mode, compare loss trajectory
    cfg = ModelConfig(name="t-ref", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=64)
    cell = ShapeCell("t", "train", 32, 4)
    b = make_bundle(mesh2, cfg=cfg, cell=cell, mode="fcdp")
    _, ms = run_steps(b, n=3)
    losses = [m["loss"] for m in ms]
    assert losses[-1] < losses[0]
    # grad norm finite and stable
    assert all(0 < m["grad_norm"] < 1e4 for m in ms)


def test_grad_accumulation_matches_full_batch(mesh3):
    cfg = DENSE
    cell = ShapeCell("t", "train", 64, 8)
    run_full = RunConfig(model=cfg, shape=cell,
                         system=SystemConfig(mode="fcdp", min_shard_size=8),
                         optimizer=OptimizerConfig(lr=1e-3, total_steps=8,
                                                   warmup_steps=2))
    from repro.launch.mesh import make_mesh
    b_full = StepBundle(run_full, mesh3)
    b_acc = StepBundle(run_full.replace(microbatch=2), mesh3)
    batch = make_batch(cfg, cell)
    out = {}
    for name, b in (("full", b_full), ("acc", b_acc)):
        params = b.init_all_params(seed=0)
        tp, fp = b.split(params)
        opt = jax.jit(functools.partial(init_opt_state, sys=b.run.system))(tp)
        tp, opt, m = b.make_train_step()(tp, fp, opt, batch)
        out[name] = [np.asarray(x, np.float32) for x in tp]
    for a, c in zip(out["full"], out["acc"]):
        np.testing.assert_allclose(a, c, rtol=5e-2, atol=5e-3)
