"""Per-kernel validation: shape/dtype sweeps against the ref.py pure-jnp
oracles, in Pallas interpret mode (kernel body executed on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.pallas_interpret

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 128, 1, 64), (2, 256, 4, 64),
                                   (1, 192, 2, 128), (2, 64, 2, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shape, dtype, causal, rng):
    B, S, H, hd = shape
    q, k, v = (jnp.asarray(rng.normal(0, 1, shape), dtype) for _ in range(3))
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True,
                              block_q=64, block_k=64)
    expect = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shapes(rng):
    """Different BlockSpec tilings must agree."""
    q, k, v = (jnp.asarray(rng.normal(0, 1, (1, 256, 2, 64)), jnp.float32)
               for _ in range(3))
    o1 = ops.flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    o2 = ops.flash_attention(q, k, v, interpret=True, block_q=128,
                             block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 64, 1, 16), (2, 128, 2, 32),
                                   (1, 128, 4, 64)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_sweep(shape, chunk, rng):
    B, S, H, hd = shape
    r, k, v = (jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
               for _ in range(3))
    logw = -jnp.exp(jnp.asarray(rng.normal(-0.5, 1.0, shape), jnp.float32))
    u = jnp.asarray(rng.normal(0, 1, (H, hd)), jnp.float32)
    out, sf = ops.wkv6(r, k, v, logw, u, chunk=chunk, interpret=True)
    eo, es = ref.rwkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eo),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(es),
                               rtol=2e-3, atol=2e-3)


def test_wkv6_strong_decay_no_overflow(rng):
    """The masked-log-ratio form must survive w -> 0 (|logw| large)."""
    shape = (1, 64, 1, 16)
    r, k, v = (jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
               for _ in range(3))
    logw = jnp.full(shape, -20.0, jnp.float32)   # extremely fast decay
    u = jnp.zeros((1, 16), jnp.float32)
    out, sf = ops.wkv6(r, k, v, logw, u, chunk=32, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    eo, _ = ref.rwkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eo),
                               rtol=1e-3, atol=1e-3)


def test_wkv6_matches_model_chunked_path(rng):
    """kernel vs the model's jnp chunked implementation."""
    from repro.models.sublayers import _wkv_chunked
    shape = (2, 128, 2, 16)
    r, k, v = (jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
               for _ in range(3))
    logw = -jnp.exp(jnp.asarray(rng.normal(-0.5, 1.0, shape), jnp.float32))
    u = jnp.asarray(rng.normal(0, 1, (2, 16)), jnp.float32)
    o_model, s_model = _wkv_chunked(r, k, v, logw, u, chunk=64)
    o_kern, s_kern = ops.wkv6(r, k, v, logw, u, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_model, np.float32),
                               np.asarray(o_kern, np.float32),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# mamba ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 64, 32), (2, 256, 64), (1, 128, 48)])
@pytest.mark.parametrize("chunk", [32, 64])
def test_ssm_scan_sweep(shape, chunk, rng):
    B, S, C = shape
    a = jnp.asarray(rng.uniform(0.2, 0.999, shape), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
    hs = ops.ssm_scan(a, b, chunk=chunk, channel_block=32, interpret=True)
    eh, _ = ref.mamba_scan_ref(a[..., None], b[..., None])
    np.testing.assert_allclose(np.asarray(hs), np.asarray(eh[..., 0]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# int8 gradient compression (beyond-paper)
# ---------------------------------------------------------------------------

def test_int8_quant_roundtrip_error_bounded(rng):
    from repro.core.grad_compress import _quantize
    x = jnp.asarray(rng.normal(0, 1, (1000,)), jnp.float32)
    q, scale = _quantize(x)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:1000]
    # symmetric int8: error bounded by scale/2 per block
    err = np.abs(np.asarray(deq - x))
    bound = np.repeat(np.asarray(scale).ravel(),
                      256)[:1000] * 0.5 + 1e-7
    assert (err <= bound).all()


if HAVE_HYP:
    @given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 64))
    @settings(max_examples=20, deadline=None)
    def test_int8_quant_property(b, n, tail):
        """Quantize-dequantize never increases magnitude beyond one scale
        step, for arbitrary shapes (hypothesis)."""
        from repro.core.grad_compress import _quantize
        rng = np.random.default_rng(b * 100 + n * 10 + tail)
        x = jnp.asarray(rng.normal(0, 2.0, (b, n * 256 + tail)), jnp.float32)
        q, scale = _quantize(x)
        assert int(np.abs(np.asarray(q)).max()) <= 127
        deq = (np.asarray(q, np.float32)
               * np.asarray(scale)).reshape(-1)[: x.size]
        rel = np.abs(deq - np.asarray(x).ravel())
        blocks = np.asarray(scale).ravel()
        assert rel.max() <= blocks.max() * 0.5 + 1e-6

    @given(st.integers(2, 64), st.floats(0.05, 0.999))
    @settings(max_examples=15, deadline=None)
    def test_ssm_scan_property(seq, decay):
        """h_t of a constant-decay scan equals the closed form
        sum_i a^(t-i) b_i (hypothesis over seq length and decay)."""
        a = jnp.full((1, seq, 4), decay, jnp.float32)
        rng = np.random.default_rng(seq)
        b = jnp.asarray(rng.normal(0, 1, (1, seq, 4)), jnp.float32)
        hs, _ = ref.mamba_scan_ref(a[..., None], b[..., None])
        t = seq - 1
        closed = sum(decay ** (t - i) * np.asarray(b)[0, i] for i in range(seq))
        np.testing.assert_allclose(np.asarray(hs)[0, -1, :, 0], closed,
                                   rtol=1e-4, atol=1e-4)
