"""Parameter-residency layer tests: the one lifecycle model for
frozen/cached/trainable leaves.

Pins: ParamResidency construction invariants and the frozen gating
matrix (non-trainable leaves decline compress_fwd / compress_bwd /
fused across EVERY registered strategy and across composite groups),
split stability under LoRA injection + re-resolution, ring-slot
exclusion for leaves with no DCN residency, the deferred zero-match
validation of adapter-targeting mode_overrides, mixed composite PEFT
training, serve-side adapter hot-swap, and -- statically, via ast --
that no consumer outside core/strategy.py + core/residency.py reads
``ParamDef.frozen`` or ``GatherPlan.placement`` directly."""
import ast
import dataclasses
import functools
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                ShapeCell, SystemConfig)
from repro.core.engine import StepBundle
from repro.core.partition import ParamDef, is_def, label_tree
from repro.core.residency import (ParamResidency, as_stage1_resident,
                                  residency_of, split_frozen_indices,
                                  update_class)
from repro.core.strategy import (get_strategy, leaf_group,
                                 resolve_strategies, strategy_names)

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
CELL = ShapeCell("t", "train", 64, 8)
DEC_CELL = ShapeCell("t", "decode", 128, 8)

# big enough that every strategy shards it and qwZ would apply to the
# trainable twin (shard >= QUANT_MIN_SHARD_ELEMS)
BIG = dict(shape=(4096, 64), dims=("fsdp", "tp"))


def peft_bundle(mesh, mode="fcdp", cell=CELL, overrides=(), defs_fn=None,
                **sys_kw):
    sysd = dict(mode=mode, min_shard_size=8, peft=True, lora_rank=2,
                mode_overrides=overrides)
    sysd.update(sys_kw)
    run = RunConfig(model=DENSE, shape=cell, system=SystemConfig(**sysd),
                    optimizer=OptimizerConfig(total_steps=8, warmup_steps=2,
                                              lr=1e-3))
    return StepBundle(run, mesh, defs_fn=defs_fn)


# ---------------------------------------------------------------------------
# ParamResidency construction invariants
# ---------------------------------------------------------------------------

def test_construction_rejects_unknown_enums():
    with pytest.raises(ValueError, match="storage tier"):
        ParamResidency("gpu", "regather", "trainable")
    with pytest.raises(ValueError, match="cache tier"):
        ParamResidency("replicated", "ssd", "trainable")
    with pytest.raises(ValueError, match="update class"):
        ParamResidency("replicated", "regather", "thawed")
    with pytest.raises(ValueError, match="cache_after"):
        ParamResidency("dcn_sharded", "regather", "trainable",
                       fsdp_dim=0, stage1_axes=("pod",), cache_after=3)


def test_construction_tier_axes_consistency():
    # stage-1 axes demand the dcn_sharded tier and vice versa
    with pytest.raises(ValueError, match="stage-1"):
        ParamResidency("pod_replicated", "regather", "trainable",
                       fsdp_dim=0, stage1_axes=("pod",),
                       stage2_axes=("data",))
    with pytest.raises(ValueError, match="stage1_axes"):
        ParamResidency("dcn_sharded", "regather", "trainable", fsdp_dim=0)
    with pytest.raises(ValueError, match="stage2_axes"):
        ParamResidency("pod_replicated", "regather", "trainable",
                       fsdp_dim=0)


def test_construction_frozen_gating():
    """The gating matrix at the type level: any non-trainable update
    class rejects per-step transport optimizations outright."""
    for upd in ("frozen", "frozen_cached"):
        with pytest.raises(ValueError, match="compress_fwd"):
            ParamResidency("dcn_sharded", "regather", upd, fsdp_dim=0,
                           stage1_axes=("pod",), quantized_gather=True)
        with pytest.raises(ValueError, match="compress_bwd"):
            ParamResidency("dcn_sharded", "regather", upd, fsdp_dim=0,
                           stage1_axes=("pod",), quantized_reduce=True)
        with pytest.raises(ValueError, match="fuse"):
            ParamResidency("pod_replicated", "host", upd, fsdp_dim=0,
                           stage2_axes=("data",), fused="ag_matmul")


def test_stage1_resident_view():
    res = ParamResidency("dcn_sharded", "host", "trainable", fsdp_dim=0,
                         stage1_axes=("pod",), stage2_axes=("data",),
                         cache_after=1, quantized_gather=True)
    s1 = as_stage1_resident(res)
    assert s1.stage1_axes == ()
    assert s1.tier == "pod_replicated"
    assert not s1.quantized_gather            # nothing left to quantize
    assert not s1.occupies_ring_slot
    assert as_stage1_resident(s1) is s1       # idempotent
    # no stage 2 at all -> the stage-1 product is the full weight
    res2 = ParamResidency("dcn_sharded", "regather", "trainable",
                          fsdp_dim=0, stage1_axes=("pod",), cache_after=1)
    assert as_stage1_resident(res2).tier == "replicated"


def test_residency_of_rejects_bare_objects():
    with pytest.raises(TypeError, match="ParamResidency"):
        residency_of(object())


# ---------------------------------------------------------------------------
# The frozen gating matrix across every registered strategy + composite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", strategy_names())
def test_frozen_declines_transport_optimizations(name, mesh3):
    """A frozen leaf emitted by ANY strategy declines qwZ (compress_fwd),
    qgZ (compress_bwd) and the fused collective matmul, even when the
    config asks for all three; its trainable twin under a DCN-crossing
    strategy accepts qwZ/qgZ."""
    s = get_strategy(name)
    frozen = ParamDef(frozen=True, **BIG)
    r = s.residency(frozen, mesh3, 8, compress_bwd=True,
                    param_compress=True, fused_matmul="ag_matmul")
    assert r.frozen and not r.trainable
    assert not r.quantized_gather
    assert not r.quantized_reduce
    assert r.fused == "none"
    assert not r.receives_gradient and not r.has_optimizer_state
    twin = s.residency(ParamDef(frozen=False, **BIG), mesh3, 8,
                       compress_bwd=True, param_compress=True)
    assert twin.trainable
    if twin.crosses_dcn and s.supports_quantized_gather:
        assert twin.quantized_gather and twin.quantized_reduce


def test_frozen_gating_across_composite_groups(mesh3):
    """CompositeStrategy dispatches residency per leaf group; frozen
    leaves decline the optimizations inside every group."""
    defs = {
        "a": ParamDef(strategy="fcdp", frozen=True, **BIG),
        "b": ParamDef(strategy="zero3", frozen=True, **BIG),
        "c": ParamDef(strategy="zero3", frozen=False, **BIG),
    }
    sys = SystemConfig(mode="fcdp", min_shard_size=8)
    defs, strat = resolve_strategies(sys, label_tree(defs))
    for k in ("a", "b"):
        r = strat.residency(defs[k], mesh3, 8, compress_bwd=True,
                            param_compress=True, fused_matmul="ag_matmul")
        assert r.frozen and not r.quantized_gather
        assert not r.quantized_reduce and r.fused == "none"
    # the trainable zero3 leaf in the same bundle still quantizes
    r = strat.residency(defs["c"], mesh3, 8, compress_bwd=True,
                        param_compress=True)
    assert r.trainable and r.quantized_gather and r.quantized_reduce


def test_residency_emission_matrix(mesh3):
    """Tier x cache x update per strategy for the frozen leaf -- the
    asymmetry the PEFT DCN-reduction claim rests on: zero3 keeps a
    frozen trunk dcn_sharded (re-gathered over DCN every step, the
    DeepSpeed baseline), fcdp parks it pod-replicated/host-cached with
    an empty stage 1."""
    frozen = ParamDef(frozen=True, **BIG)
    z = get_strategy("zero3").residency(frozen, mesh3, 8)
    assert (z.tier, z.update) == ("dcn_sharded", "frozen")
    assert z.crosses_dcn and z.occupies_ring_slot
    f = get_strategy("fcdp").residency(frozen, mesh3, 8)
    assert (f.tier, f.cache, f.update) == ("pod_replicated", "host",
                                           "frozen_cached")
    assert f.stage1_axes == () and not f.crosses_dcn
    assert not f.occupies_ring_slot
    assert f.backward_source == "host_cache"


# ---------------------------------------------------------------------------
# Split stability under LoRA injection + re-resolution
# ---------------------------------------------------------------------------

def test_split_stable_under_lora_and_reresolution(mesh3):
    b = peft_bundle(mesh3)
    labels = [d.label for d in b.def_leaves]
    assert all("_lora_" in labels[i] for i in b.train_idx)
    assert not any("_lora_" in labels[i] for i in b.frozen_idx)
    assert sorted(b.train_idx + b.frozen_idx) == list(range(len(labels)))
    # def-level classification (peft split) agrees with the
    # residency-level split the engine uses
    assert split_frozen_indices(b.defs) == (b.train_idx, b.frozen_idx)
    # re-resolving the already-tagged tree must not move a single leaf
    defs2, strat2 = resolve_strategies(b.run.system, label_tree(b.defs))
    assert split_frozen_indices(defs2) == (b.train_idx, b.frozen_idx)
    leaves2 = jax.tree.leaves(defs2, is_leaf=is_def)
    assert [d.label for d in leaves2] == labels


def test_update_class_resolution():
    d = ParamDef((8, 8), (None, None))
    assert update_class(d) == "trainable"
    f = dataclasses.replace(d, frozen=True)
    assert update_class(f) == "frozen"
    assert update_class(f, frozen_cached_layout=True) == "frozen_cached"


# ---------------------------------------------------------------------------
# Ring-slot exclusion: no DCN residency -> no ring slot
# ---------------------------------------------------------------------------

def test_frozen_cached_leaves_leave_the_ring(mesh3):
    """fcdp's frozen trunk has no stage-1 gather to overlap, so the
    streaming scheduler must not spend ring slots (or depth) on it;
    zero3's frozen trunk stays in the ring -- it still crosses DCN."""
    bf = peft_bundle(mesh3, "fcdp", prefetch_depth=1)
    for i in bf.frozen_idx:
        assert not residency_of(bf.plan_leaves[i]).occupies_ring_slot
    bz = peft_bundle(mesh3, "zero3", prefetch_depth=1)
    assert any(residency_of(bz.plan_leaves[i]).occupies_ring_slot
               for i in bz.frozen_idx)


# ---------------------------------------------------------------------------
# Adapter-targeting mode_overrides: deferred zero-match validation
# ---------------------------------------------------------------------------

def test_lora_override_rule_resolves_after_injection(mesh3):
    """'*lora*' matches nothing on the base tree (pre-injection) --
    construction must NOT reject it under peft=True; after apply_lora
    the adapters land in their own group."""
    b = peft_bundle(mesh3, overrides=(("*lora*", "zero3"),))
    groups = {leaf_group(b.strategy, d) for d in b.def_leaves}
    assert groups == {"fcdp", "zero3"}
    for i in b.train_idx:
        assert leaf_group(b.strategy, b.def_leaves[i]) == "zero3"
    for i in b.frozen_idx:
        assert leaf_group(b.strategy, b.def_leaves[i]) == "fcdp"


def test_dead_rule_still_raises_under_peft(mesh3):
    # a rule that matches nothing even after injection is a typo'd glob
    with pytest.raises(ValueError, match="matched zero"):
        peft_bundle(mesh3, overrides=(("*no_such_param*", "zero3"),))


def test_lora_rule_without_peft_raises_at_construction(mesh3):
    run = RunConfig(model=DENSE, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8,
                                        mode_overrides=(("*lora*",
                                                         "zero3"),)))
    with pytest.raises(ValueError, match="matched zero"):
        StepBundle(run, mesh3)


# ---------------------------------------------------------------------------
# apply_lora keying + lora_scale source of truth
# ---------------------------------------------------------------------------

def test_apply_lora_keys_on_configured_targets():
    from repro.core.peft import apply_lora
    d = ParamDef((64, 64), ("fsdp", "tp"))
    defs = {"attn": {"w_out": d, "gate": ParamDef((64,), (None,))}}
    sys = SystemConfig(peft=True, lora_rank=2, lora_targets=("w_out",))
    out = apply_lora(defs, DENSE, sys)
    assert set(out["attn"]) == {"w_out", "w_out_lora_a", "w_out_lora_b",
                                "gate"}
    assert out["attn"]["w_out"].frozen
    assert not out["attn"]["w_out_lora_a"].frozen
    assert out["attn"]["w_out_lora_b"].init == "zeros"
    # 1-D leaves are never injection sites even when named as a target
    sys1 = SystemConfig(peft=True, lora_rank=2,
                        lora_targets=("w_out", "gate"))
    out1 = apply_lora(defs, DENSE, sys1)
    assert "gate_lora_a" not in out1["attn"]


def test_apply_lora_zero_sites_raises_readably():
    from repro.core.peft import apply_lora
    defs = {"attn": {"wq": ParamDef((64, 64), ("fsdp", "tp"))}}
    sys = SystemConfig(peft=True, lora_rank=2,
                       lora_targets=("proj_q", "proj_k"))
    with pytest.raises(ValueError, match="lora_targets"):
        apply_lora(defs, DENSE, sys)


def test_lora_scale_single_source_of_truth():
    from repro.core.peft import lora_scale
    assert lora_scale(SystemConfig(peft=True, lora_rank=8)) == 2.0
    assert lora_scale(SystemConfig(peft=True, lora_rank=4)) == 2.0
    assert lora_scale(SystemConfig(peft=True, lora_rank=8,
                                   lora_alpha=16.0)) == 2.0
    assert lora_scale(SystemConfig(peft=True, lora_rank=8,
                                   lora_alpha=4.0)) == 0.5


# ---------------------------------------------------------------------------
# Mixed composite PEFT bundle trains
# ---------------------------------------------------------------------------

def test_mixed_composite_peft_trains(mesh3):
    from repro.core.cache import cache_bytes_per_chip
    from repro.optim.adamw import init_opt_state
    b = peft_bundle(mesh3, overrides=(("*lora*", "zero3"),))
    acct = cache_bytes_per_chip(b)
    assert set(acct["by_group"]) == {"fcdp", "zero3"}
    rng = np.random.default_rng(0)
    batch = {"ids": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(1, 256, (8, 64)),
                                   jnp.int32),
             "mask": jnp.ones((8, 64), bool)}
    params = b.init_all_params(seed=0)
    tp, fp = b.split(params)
    opt = jax.jit(functools.partial(init_opt_state,
                                    sys=b.run.system))(tp)
    step = b.make_train_step()
    tp, opt, m = step(tp, fp, opt, batch)
    assert np.isfinite(float(m["loss"]))
    # the frozen arm really carries no optimizer state
    assert len(opt["m"]) == len(b.train_idx)


# ---------------------------------------------------------------------------
# Serve-side adapter hot-swap over the cached trunk
# ---------------------------------------------------------------------------

def test_serve_adapter_hot_swap(mesh3):
    from jax.sharding import NamedSharding
    from repro.core.engine.serve import swap_adapters
    b = peft_bundle(mesh3, cell=DEC_CELL)
    params = b.init_all_params(seed=0)

    def adapter_set(seed):
        rng_ = np.random.default_rng(seed)
        out = []
        for i in b.train_idx:
            d, ref = b.def_leaves[i], params[i]
            # nonzero lora_b too, so the adapters actually shape logits
            v = jnp.asarray(rng_.normal(0, 0.05, d.shape), ref.dtype)
            out.append(jax.device_put(
                v, NamedSharding(b.mesh, b.leaf_specs[i])))
        return out

    v1, v2 = adapter_set(1), adapter_set(2)
    dec = b.make_decode_step()
    tok = jnp.ones((DEC_CELL.global_batch, 1), jnp.int32)

    def logits_with(adapters):
        p = swap_adapters(b, params, adapters)
        # the cached trunk is untouched: same buffers, no re-gather
        for i in b.frozen_idx:
            assert p[i] is params[i]
        state = b.init_state(DEC_CELL)
        out, _ = dec(p, tok, state)
        return np.asarray(out)

    l1, l2, l1_again = (logits_with(v1), logits_with(v2),
                        logits_with(v1))
    assert np.isfinite(l1).all() and np.isfinite(l2).all()
    # different adapters -> different logits (the swap is live)
    assert not np.array_equal(l1, l2)
    # swapping back is exact: serving state fully determined by
    # (cached trunk, adapter set)
    np.testing.assert_array_equal(l1, l1_again)
    with pytest.raises(ValueError, match="hot-swap"):
        swap_adapters(b, params, v1[:-1])


# ---------------------------------------------------------------------------
# Static enforcement: residency is the only classification surface
# ---------------------------------------------------------------------------

def test_no_consumer_reads_frozen_or_placement_directly():
    """Outside core/strategy.py + core/residency.py, no module under
    src/repro reads ``.frozen`` or ``.placement`` as an attribute --
    the residency object is the one classification surface. (ast-based:
    comments/strings don't count, keyword writes like
    ``replace(d, frozen=True)`` don't count.)"""
    src = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
    allowed = {src / "core" / "strategy.py", src / "core" / "residency.py"}
    offenders = []
    for path in sorted(src.rglob("*.py")):
        if path in allowed:
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if (isinstance(node, ast.Attribute)
                    and node.attr in ("frozen", "placement")):
                offenders.append(f"{path.relative_to(src)}:{node.lineno}")
    assert not offenders, offenders
