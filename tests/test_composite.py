"""Per-tensor mixed sharding (composite strategy) tests: mode_overrides
validation, per-leaf resolution order, uniform-override parity against
the pure mode, mixed-layout numerical goldens, the group-keyed prefetch
ring, and per-group planner byte accounting."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ModelConfig, MoEConfig, OptimizerConfig,
                                RunConfig, ShapeCell, SystemConfig)
from repro.core.engine import StepBundle
from repro.core.partition import ParamDef, is_def, label_tree
from repro.core.strategy import (CompositeStrategy, get_strategy,
                                 leaf_group, parse_mode_override,
                                 resolve_strategies)

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                    qkv_bias=True)
MOE = ModelConfig(name="t-moe", family="moe", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=256,
                  moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64))
CELL = ShapeCell("t", "train", 64, 8)

# the headline mixed layout: dense trunk fcdp, MoE experts mics,
# embeddings hier
MIXED_RULES = (("blocks.*.moe.we_*", "mics"), ("embed", "hier"))


def make_bundle(mesh, cfg=DENSE, mode="fcdp", microbatch=0, **sys_kw):
    sysd = dict(mode=mode, min_shard_size=8)
    sysd.update(sys_kw)
    run = RunConfig(model=cfg, shape=CELL, system=SystemConfig(**sysd),
                    optimizer=OptimizerConfig(total_steps=8, warmup_steps=2,
                                              lr=1e-3),
                    microbatch=microbatch)
    return StepBundle(run, mesh)


def make_batch(cfg=DENSE, seed=0):
    rng = np.random.default_rng(seed)
    b = {"ids": jnp.asarray(
            rng.integers(1, cfg.vocab_size,
                         (CELL.global_batch, CELL.seq_len)), jnp.int32),
         "labels": jnp.asarray(
            rng.integers(1, cfg.vocab_size,
                         (CELL.global_batch, CELL.seq_len)), jnp.int32)}
    b["mask"] = jnp.ones_like(b["labels"], bool)
    return b


def run_one_step(bundle):
    from repro.optim.adamw import init_opt_state
    params = bundle.init_all_params(seed=0)
    tp, fp = bundle.split(params)
    opt = jax.jit(functools.partial(
        init_opt_state, sys=bundle.run.system))(tp)
    step = bundle.make_train_step()
    tp, opt, m = step(tp, fp, opt, make_batch(bundle.run.model))
    return ({k: float(v) for k, v in m.items()},
            [np.asarray(x, np.float32) for x in tp])


# ---------------------------------------------------------------------------
# mode_overrides validation (construction-time + resolution-time)
# ---------------------------------------------------------------------------

def test_mode_overrides_construction_validation():
    with pytest.raises(ValueError, match="unknown strategy"):
        SystemConfig(mode_overrides=(("embed", "zero17"),))
    with pytest.raises(ValueError, match="malformed"):
        SystemConfig(mode_overrides=("noequals",))
    with pytest.raises(ValueError, match="malformed"):
        SystemConfig(mode_overrides=(("embed",),))
    with pytest.raises(ValueError, match="malformed"):
        SystemConfig(mode_overrides=((" ", "fcdp"),))
    # string rules canonicalize to pairs (the CLI form)
    s = SystemConfig(mode_overrides=("embed=hier", ("head", "mics")))
    assert s.mode_overrides == (("embed", "hier"), ("head", "mics"))
    assert parse_mode_override(" blocks.* = mics ") == ("blocks.*", "mics")
    with pytest.raises(ValueError, match="malformed"):
        parse_mode_override("=mics")


def test_mode_overrides_zero_match_raises(mesh3):
    sysc = SystemConfig(mode_overrides=(("experts.*", "mics"),),
                        min_shard_size=8)
    with pytest.raises(ValueError, match="experts.*matched zero"):
        StepBundle(RunConfig(model=MOE, shape=CELL, system=sysc), mesh3)


# ---------------------------------------------------------------------------
# Resolution order: explicit ParamDef tag > first matching rule > mode
# ---------------------------------------------------------------------------

def test_resolution_order():
    defs = label_tree({
        "a": ParamDef((8, 8), ("fsdp", None)),
        "b": ParamDef((8, 8), ("fsdp", None), strategy="zeropp"),
        "c": ParamDef((8, 8), ("fsdp", None)),
    })
    sysc = SystemConfig(mode="fcdp",
                        mode_overrides=(("b", "mics"), ("c", "mics"),
                                        ("*", "zero3")))
    tagged, strat = resolve_strategies(sysc, defs)
    assert isinstance(strat, CompositeStrategy)
    names = {d.label: d.strategy
             for d in jax.tree.leaves(tagged, is_leaf=is_def)}
    # 'b' keeps its explicit tag even though a rule matches it; 'a'
    # falls to the first matching rule ('*'), 'c' to its earlier rule
    assert names == {"a": "zero3", "b": "zeropp", "c": "mics"}
    assert strat.group_names() == ("mics", "zero3", "zeropp")
    assert leaf_group(strat, jax.tree.leaves(
        tagged, is_leaf=is_def)[0]) in names.values()


def test_uniform_resolution_returns_singleton():
    defs = label_tree({"a": ParamDef((8, 8), ("fsdp", None))})
    out, strat = resolve_strategies(SystemConfig(mode="zeropp"), defs)
    assert strat is get_strategy("zeropp")
    assert out is defs


def test_composite_capability_intersection():
    mk = lambda shape=(8, 8): ParamDef(shape, ("fsdp", None))  # noqa: E731
    comp = CompositeStrategy(get_strategy("fcdp"),
                             {"fcdp": get_strategy("fcdp"),
                              "mics": get_strategy("mics")})
    # mics (no stage 1) does not veto the fcdp trunk's streams
    assert comp.max_prefetch_depth == get_strategy("fcdp").max_prefetch_depth
    assert comp.supports_async_grad_reduce
    assert comp.supports_device_cache
    assert comp.device_cache_groups(8, 0.5) == 4
    only_single = CompositeStrategy(get_strategy("mics"),
                                    {"mics": get_strategy("mics"),
                                     "hier": get_strategy("hier")})
    assert only_single.max_prefetch_depth == 0
    assert not only_single.supports_async_grad_reduce
    assert only_single.device_cache_groups(8, 0.5) == 0
    # per-leaf dispatch goes through the tag
    d = dataclasses.replace(mk(), strategy="mics", label="x")
    assert comp._for(d) is get_strategy("mics")
    assert comp._for(mk()) is get_strategy("fcdp")


# ---------------------------------------------------------------------------
# Uniform-override parity: every leaf overridden to mode X must be
# bit-identical to pure mode=X (same specs, plans, and step numerics).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("target", ["zero3", "mics"])
def test_uniform_override_parity(mesh3, target):
    pure = make_bundle(mesh3, mode=target)
    over = make_bundle(mesh3, mode="fcdp",
                       mode_overrides=(("*", target),))
    assert isinstance(over.strategy, CompositeStrategy)
    assert over.strategy.group_names() == (target,)
    assert over.leaf_specs == pure.leaf_specs
    assert over.full_specs == pure.full_specs
    assert over.plan_leaves == pure.plan_leaves
    m_p, p_p = run_one_step(pure)
    m_o, p_o = run_one_step(over)
    assert m_o["loss"] == m_p["loss"]
    assert m_o["grad_norm"] == m_p["grad_norm"]
    for a, b in zip(p_p, p_o):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Mixed-layout goldens: experts-on-mics / embed-on-hier must not change
# the math vs the all-fcdp baseline (the paper's correctness invariant,
# extended to per-tensor assignments).
# ---------------------------------------------------------------------------

def test_mixed_moe_golden(mesh3):
    m_f, p_f = run_one_step(make_bundle(mesh3, cfg=MOE, mode="fcdp"))
    b = make_bundle(mesh3, cfg=MOE, mode="fcdp", mode_overrides=MIXED_RULES)
    assert isinstance(b.strategy, CompositeStrategy)
    assert b.strategy.group_names() == ("fcdp", "hier", "mics")
    m_x, p_x = run_one_step(b)
    np.testing.assert_allclose(m_x["loss"], m_f["loss"], rtol=1e-4)
    np.testing.assert_allclose(m_x["grad_norm"], m_f["grad_norm"],
                               rtol=1e-3)
    for a, c in zip(p_f, p_x):
        np.testing.assert_allclose(a, c, rtol=2e-2, atol=2e-3)


def test_mixed_prefetch_and_async_equivalence(mesh3):
    """The group-keyed ring (only the fcdp trunk streams; mics/hier
    leaves are sliced at the consuming step) and the async reduce
    stream must leave the mixed math unchanged."""
    m_0, p_0 = run_one_step(make_bundle(mesh3, cfg=MOE, mode="fcdp",
                                        mode_overrides=MIXED_RULES,
                                        prefetch_depth=0))
    m_k, p_k = run_one_step(make_bundle(mesh3, cfg=MOE, mode="fcdp",
                                        mode_overrides=MIXED_RULES,
                                        prefetch_depth=2))
    np.testing.assert_allclose(m_k["loss"], m_0["loss"], rtol=1e-4)
    for a, c in zip(p_0, p_k):
        np.testing.assert_allclose(a, c, rtol=2e-2, atol=2e-3)
    m_a, p_a = run_one_step(make_bundle(mesh3, cfg=MOE, mode="fcdp",
                                        mode_overrides=MIXED_RULES,
                                        microbatch=2,
                                        async_grad_reduce=True))
    m_s, p_s = run_one_step(make_bundle(mesh3, cfg=MOE, mode="fcdp",
                                        mode_overrides=MIXED_RULES,
                                        microbatch=2))
    np.testing.assert_allclose(m_a["loss"], m_s["loss"], rtol=1e-4)
    for a, c in zip(p_s, p_a):
        np.testing.assert_allclose(a, c, rtol=2e-2, atol=2e-3)


def test_mixed_comm_structure(mesh3):
    """Experts-on-mics removes exactly the experts' DCN all-gathers:
    pod-axis AG volume strictly shrinks vs all-fcdp, and the mics
    group's gradient reduction crosses pods as a psum instead."""
    from repro.launch.roofline import collect_collectives

    def collect(b):
        closed = b.make_train_step().trace(*b.train_input_sds()).jaxpr
        sizes = {a: b.mi.size(a) for a in b.mi.axis_names}
        return collect_collectives(closed, sizes)

    full = collect(make_bundle(mesh3, cfg=MOE, mode="fcdp"))
    mixed = collect(make_bundle(mesh3, cfg=MOE, mode="fcdp",
                                mode_overrides=MIXED_RULES))
    assert mixed.by_op_axis.get("all_gather/pod", 0) < \
        full.by_op_axis.get("all_gather/pod", 0)
    assert mixed.by_op_axis.get("all_gather/data", 0) > 0


# ---------------------------------------------------------------------------
# Per-group planner byte accounting
# ---------------------------------------------------------------------------

def test_cache_accounting_per_group_sums(mesh3):
    """by_group must reproduce the flat totals, match the analytic
    per-leaf sums group by group, and put host bytes only where a
    host-placed group exists."""
    from repro.core.cache import cache_bytes_per_chip
    b = make_bundle(mesh3, cfg=MOE, mode="fcdp",
                    mode_overrides=MIXED_RULES, prefetch_depth=2)
    acct = cache_bytes_per_chip(b)
    groups = acct["by_group"]
    assert set(groups) == {"fcdp", "mics", "hier"}
    # analytic per-leaf sums, recomputed independently per group
    expect = {}
    for d, p in zip(b.def_leaves, b.plan_leaves):
        g = leaf_group(b.strategy, d)
        expect[g] = expect.get(g, 0.0) + b.strategy.cached_bytes_for(
            d, p, b.mi)
    for g, gb in groups.items():
        np.testing.assert_allclose(gb["cached_bytes_per_chip"], expect[g])
        assert gb["placement"] == get_strategy(g).cache_placement
    np.testing.assert_allclose(
        acct["cached_bytes_per_chip"], sum(expect.values()))
    # host tier counts host-placed groups only (the fcdp trunk)
    np.testing.assert_allclose(acct["host_cache_bytes_per_chip"],
                               expect["fcdp"])
    # the ring belongs to the streaming group alone
    assert groups["fcdp"]["prefetch_buffer_bytes_per_chip"] > 0
    assert groups["mics"]["prefetch_buffer_bytes_per_chip"] == 0
    assert groups["hier"]["prefetch_buffer_bytes_per_chip"] == 0
    np.testing.assert_allclose(
        acct["prefetch_buffer_bytes_per_chip"],
        sum(g["prefetch_buffer_bytes_per_chip"] for g in groups.values()))


def test_memory_planner_records_groups(mesh3):
    from repro.core.cache import MemoryPlanner
    run = RunConfig(model=MOE, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8,
                                        mode_overrides=MIXED_RULES),
                    optimizer=OptimizerConfig(total_steps=4, warmup_steps=1))
    plan = MemoryPlanner(hbm_budget=1 << 40).plan(run, mesh3,
                                                 fractions=(1.0,))
    assert plan.fits
    for it in plan.iterations:
        assert set(it["by_group"]) == {"fcdp", "mics", "hier"}


def test_dryrun_json_reports_groups(monkeypatch):
    """The dry-run cell carries the per-group breakdown and the
    override spec into its JSON row (smoke config, single pod +
    multi-pod toy meshes are exercised elsewhere; here we go through
    dryrun_cell's real code path on the production mesh builder)."""
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_mesh
    monkeypatch.setattr(
        dr, "make_production_mesh",
        lambda multi_pod=False: make_mesh((2, 2, 2),
                                          ("pod", "data", "model")))
    monkeypatch.setattr(
        dr, "get_config",
        lambda arch: dataclasses.replace(MOE, name=arch))
    monkeypatch.setattr(dr, "cell_supported", lambda cfg, cell: (True, ""))
    monkeypatch.setattr(dr, "shape_cell", lambda name: CELL)
    r = dr.dryrun_cell("toy", "train_4k", True, "fcdp",
                       system_overrides={"min_shard_size": 8,
                                         "loss_chunk": 0},
                       verbose=False, mode_overrides=MIXED_RULES)
    assert r["status"] == "ok"
    assert r["mode_overrides"] == [list(x) for x in MIXED_RULES]
    assert set(r["cache_by_group"]) == {"fcdp", "mics", "hier"}
    assert r["roofline"]["groups"] == r["cache_by_group"]
