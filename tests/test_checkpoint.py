"""Checkpointer tests (its first dedicated coverage): manifest-v2
schema, bf16/fp8 bitcast round-trip, async save / wait / GC interaction,
validation failures with readable diffs (treedef, leaf paths, shapes,
shardings alignment), section-filtered restore, v1 manifest
back-compat, and meta round-trip."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (MANIFEST_VERSION, CheckpointError,
                                           Checkpointer)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": [jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32),
                   jnp.asarray(rng.normal(0, 1, (4,)), jnp.bfloat16)],
        "opt": {"m": [jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32)],
                "step": jnp.int32(7)},
    }


# ---------------------------------------------------------------------------
# manifest v2 schema + round-trips
# ---------------------------------------------------------------------------

def test_manifest_v2_schema(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _tree(), blocking=True, meta={"note": "hello"})
    man = ck.manifest(3)
    assert man["version"] == MANIFEST_VERSION
    assert man["step"] == 3
    assert man["meta"] == {"note": "hello"}
    assert man["n_leaves"] == len(man["leaves"]) == 4
    # leaves carry path/section/logical shape+dtype, in flatten order
    # (dict keys sort: opt before params)
    assert [l["section"] for l in man["leaves"]] == \
        ["opt", "opt", "params", "params"]
    assert man["leaves"][2]["path"] == "['params'][0]"
    assert man["leaves"][2]["shape"] == [8, 4]
    assert man["leaves"][3]["dtype"] == "bfloat16"


def test_roundtrip_preserves_values(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(1, tree, blocking=True)
    restored = ck.restore(1, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@pytest.mark.parametrize("dtype", ["bfloat16", "float8_e4m3fn",
                                   "float8_e5m2"])
def test_bitcast_dtypes_roundtrip_bit_exact(tmp_path, dtype):
    """numpy cannot np.save ml_dtypes; the manifest records the logical
    dtype and the bits are stored raw -- the round-trip must be
    bit-exact, not merely close."""
    ck = Checkpointer(str(tmp_path))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (16, 3)), jnp.float32).astype(dtype)
    ck.save(1, {"w": x}, blocking=True)
    man = ck.manifest(1)
    assert man["leaves"][0]["dtype"] == dtype
    assert man["leaves"][0]["shape"] == [16, 3]
    r = ck.restore(1, {"w": x})["w"]
    assert str(r.dtype) == dtype
    width = np.uint16 if dtype == "bfloat16" else np.uint8
    np.testing.assert_array_equal(np.asarray(x).view(width),
                                  np.asarray(r).view(width))


def test_async_save_wait_and_gc(tmp_path):
    """Back-to-back async saves serialize (each waits out the previous
    writer), wait() drains the last one, and GC keeps `keep` newest."""
    ck = Checkpointer(str(tmp_path), keep=2)
    trees = {s: _tree(seed=s) for s in (1, 2, 3, 4)}
    for s, t in trees.items():
        ck.save(s, t, blocking=False)
    ck.wait()
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4
    # both survivors are complete and readable (atomic publish): the
    # manifest parses and the values round-trip
    for s in (3, 4):
        assert ck.manifest(s)["version"] == MANIFEST_VERSION
        r = ck.restore(s, trees[s])
        for a, b in zip(jax.tree.leaves(trees[s]), jax.tree.leaves(r)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
    # no temp dirs left behind
    assert not list(tmp_path.glob(".tmp_step_*"))


# ---------------------------------------------------------------------------
# validation failures (never silently truncate / mis-assign)
# ---------------------------------------------------------------------------

def test_restore_into_wrong_structure_raises_readable(tmp_path):
    """The satellite bug: restoring into a structurally different tree
    (e.g. carry present in the checkpoint but cross_step_pipeline off at
    restore) used to silently mis-assign leaves by position."""
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    tree["carry"] = {"g_acc": [jnp.zeros((2, 8, 4))]}
    ck.save(1, tree, blocking=True)
    with pytest.raises(CheckpointError) as ei:
        ck.restore(1, _tree())   # no carry in the example
    msg = str(ei.value)
    assert "['carry']['g_acc'][0]" in msg
    assert "not in the example tree" in msg
    # the reverse direction (example expects more than was saved)
    ck.save(2, _tree(), blocking=True)
    with pytest.raises(CheckpointError) as ei:
        ck.restore(2, tree)
    assert "absent from the checkpoint" in str(ei.value)


def test_restore_treedef_mismatch_same_paths(tmp_path):
    """Same leaf paths, different container type (tuple vs list) still
    fails the treedef check rather than unflattening into the wrong
    structure silently."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": [jnp.zeros(3), jnp.ones(3)]}, blocking=True)
    with pytest.raises(CheckpointError, match="treedef"):
        ck.restore(1, {"a": (jnp.zeros(3), jnp.ones(3))})


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros((4, 4))}, blocking=True)
    with pytest.raises(CheckpointError, match="shape mismatch"):
        ck.restore(1, {"w": jnp.zeros((2, 4))})


def test_short_shardings_tree_raises(tmp_path, mesh3):
    """The satellite bug: zip() against a shorter shardings tree used to
    silently truncate and leave trailing leaves on default placement."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    tree = {"params": [jnp.zeros((8, 4)), jnp.ones((8, 4))]}
    ck.save(1, tree, blocking=True)
    sh = NamedSharding(mesh3, P())
    with pytest.raises(CheckpointError, match="shardings"):
        ck.restore(1, tree, shardings={"params": [sh]})     # one short
    ok = ck.restore(1, tree, shardings={"params": [sh, sh]})
    assert all(x.sharding == sh for x in ok["params"])


def test_section_filtered_restore(tmp_path):
    """sections= selects top-level keys explicitly -- the mechanism the
    elastic path uses to drop a mesh-shaped carry."""
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    tree["carry"] = {"g_acc": [jnp.full((2, 8, 4), 3.0)]}
    ck.save(1, tree, blocking=True)
    partial = ck.restore(1, _tree(), sections=("params", "opt"))
    assert set(partial) == {"params", "opt"}
    np.testing.assert_array_equal(np.asarray(partial["params"][0]),
                                  np.asarray(tree["params"][0]))
    # a wrong example for the selected sections still raises
    with pytest.raises(CheckpointError, match="sections"):
        ck.restore(1, {"params": _tree()["params"]},
                   sections=("params", "opt"))


def test_v1_manifest_back_compat(tmp_path):
    """Checkpoints written before the versioned manifest (no version /
    path / section fields) still restore; sections= on them raises
    instead of guessing."""
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(1, tree, blocking=True)
    # rewrite the manifest as v1 (what the old writer produced)
    mpath = tmp_path / "step_00000001" / "manifest.json"
    man = json.loads(mpath.read_text())
    v1 = {"step": man["step"], "treedef": man["treedef"],
          "n_leaves": man["n_leaves"],
          "leaves": [{"shape": l["shape"], "dtype": l["dtype"]}
                     for l in man["leaves"]]}
    mpath.write_text(json.dumps(v1))
    restored = ck.restore(1, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # v1 still refuses a leaf-count mismatch...
    with pytest.raises(CheckpointError, match="refusing"):
        ck.restore(1, {"params": tree["params"]})
    # ...and a same-count shape mismatch (v1 manifests do record shapes)
    wrong = jax.tree.map(lambda x: jnp.zeros((3, 3)), tree)
    with pytest.raises(CheckpointError, match="shape mismatch"):
        ck.restore(1, wrong)
    # ...and cannot be section-filtered (no section records)
    with pytest.raises(CheckpointError, match="manifest v2"):
        ck.restore(1, tree, sections=("params",))


def test_restore_accepts_shapedtypestruct_example(tmp_path):
    """Example leaves may be ShapeDtypeStructs (the restart driver
    builds the carry example from the bundle's sds tree)."""
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(6.0).reshape(2, 3)}
    ck.save(1, tree, blocking=True)
    ex = {"w": jax.ShapeDtypeStruct((2, 3), jnp.float32)}
    out = ck.restore(1, ex)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
