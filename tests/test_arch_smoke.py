"""Per-architecture smoke tests: every assigned arch's REDUCED config
runs one forward/train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised via the dry-run (ShapeDtypeStruct only).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (OptimizerConfig, RunConfig, ShapeCell,
                                SystemConfig)
from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.core.engine import StepBundle
from repro.optim.adamw import init_opt_state

CELL = ShapeCell("smoke", "train", 64, 8)
DEC_CELL = ShapeCell("smoke_dec", "decode", 64, 8)


def _batch(cfg, cell, rng):
    b = {"ids": jnp.asarray(
            rng.integers(1, cfg.vocab_size, (cell.global_batch, cell.seq_len)),
            jnp.int32)}
    b["labels"] = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (cell.global_batch, cell.seq_len)),
        jnp.int32)
    b["mask"] = jnp.ones_like(b["labels"], bool)
    if cfg.num_encoder_layers > 0:
        b["enc_embeds"] = jnp.asarray(
            rng.standard_normal((cell.global_batch,
                                 max(cell.seq_len // 4, 8), cfg.d_model)),
            jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step(arch, mesh3, rng):
    cfg = get_smoke_config(arch)
    run = RunConfig(model=cfg, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8),
                    optimizer=OptimizerConfig(total_steps=4, warmup_steps=1))
    b = StepBundle(run, mesh3)
    params = b.init_all_params(seed=0)
    tp, fp = b.split(params)
    opt = jax.jit(functools.partial(init_opt_state, sys=run.system))(tp)
    step = b.make_train_step()
    batch = _batch(cfg, CELL, rng)
    tp, opt, m = step(tp, fp, opt, batch)
    loss = float(m["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert 0 < loss < 3 * np.log(cfg.vocab_size)
    for x in tp:
        assert np.isfinite(np.asarray(x, np.float32)).all(), f"{arch}: NaN params"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_step(arch, mesh3, rng):
    cfg = get_smoke_config(arch)
    run = RunConfig(model=cfg, shape=DEC_CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    b = StepBundle(run, mesh3)
    params = b.init_all_params(seed=0)
    state = b.init_state(DEC_CELL)
    dec = b.make_decode_step()
    tok = jnp.ones((DEC_CELL.global_batch, 1), jnp.int32)
    logits, state = dec(params, tok, state)
    logits, state = dec(params, tok, state)
    assert logits.shape[0] == DEC_CELL.global_batch
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-v0.1-52b"])
def test_long_context_seq_sharded_decode(arch, mesh3, rng):
    """The long_500k machinery at smoke scale: sequence-sharded KV."""
    cfg = get_smoke_config(arch)
    cell = ShapeCell("smoke_long", "decode", 64, 2)
    run = RunConfig(model=cfg, shape=cell,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    b = StepBundle(run, mesh3)
    params = b.init_all_params(seed=0)
    state = b.init_state(cell, seq_sharded=True)
    dec = b.make_decode_step(seq_sharded=True)
    tok = jnp.ones((2, 1), jnp.int32)
    for _ in range(3):
        logits, state = dec(params, tok, state)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_seq_sharded_decode_matches_dense(mesh3, rng):
    """Distributed long-context attention == unsharded decode attention."""
    cfg = get_smoke_config("jamba-v0.1-52b")
    cell = ShapeCell("t", "decode", 64, 2)
    run = RunConfig(model=cfg, shape=cell,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    b1 = StepBundle(run, mesh3)
    params = b1.init_all_params(seed=0)
    s_plain = b1.init_state(cell, seq_sharded=False)
    s_shard = b1.init_state(cell, seq_sharded=True)
    d_plain = b1.make_decode_step(seq_sharded=False)
    d_shard = b1.make_decode_step(seq_sharded=True)
    tok = jnp.ones((2, 1), jnp.int32)
    for _ in range(3):
        lp, s_plain = d_plain(params, tok, s_plain)
        ls, s_shard = d_shard(params, tok, s_shard)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(ls, np.float32),
                               rtol=5e-2, atol=5e-2)
