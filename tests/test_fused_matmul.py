"""Gather-fused collective matmul (kernels/collective_matmul.py).

Three layers of coverage, matching the module's bit-exactness contract:

  * kernel vs oracle: the Pallas per-chunk matmul (interpret mode) and
    both rings against the kernels/ref.py mirrors, bit-exact, including
    non-divisible block shapes;
  * plan-level gating: which (strategy, ParamDef, mesh) combinations
    the eligibility rule in core/strategy.gather_plan admits;
  * end-to-end: a real train step fused vs unfused is bit-identical
    (losses AND updated params), and mode='both' matches its own ring
    oracles exactly while staying close to the unfused trajectory.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import shard_map
from repro.kernels import collective_matmul as cm
from repro.kernels import ref

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# per-chunk Pallas matmul vs the tile-loop oracle (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.pallas_interpret
@pytest.mark.parametrize("shape", [(128, 64, 128),   # exact grid
                                   (7, 96, 100),     # both dims ragged
                                   (130, 32, 257),   # spills one tile
                                   (1, 16, 1)])      # degenerate
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_chunk_bit_exact(shape, dtype, rng):
    M, K, N = shape
    x = jnp.asarray(rng.normal(0, 1, (M, K)), dtype)
    w = jnp.asarray(rng.normal(0, 1, (K, N)), dtype)
    got = cm.matmul_chunk(x, w, interpret=True)
    want = ref.matmul_chunk_ref(x, w)
    assert got.dtype == want.dtype
    assert jnp.array_equal(got, want), "pallas chunk != tile-loop oracle"


@pytest.mark.pallas_interpret
def test_matmul_chunk_block_shapes(rng):
    """Different tilings agree bit-for-bit: K is whole per program, so
    the tiling never re-associates the contraction."""
    x = jnp.asarray(rng.normal(0, 1, (100, 48)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (48, 200)), jnp.float32)
    o1 = cm.matmul_chunk(x, w, block_m=128, block_n=128, interpret=True)
    o2 = cm.matmul_chunk(x, w, block_m=32, block_n=64, interpret=True)
    assert jnp.array_equal(o1, o2)


# ---------------------------------------------------------------------------
# the rings, inside shard_map on real device meshes
# ---------------------------------------------------------------------------

def _ring_ag(mesh, axis, x, w, **kw):
    """ring_ag_matmul with x replicated and w column-sharded over axis."""
    fn = lambda x_, w_: cm.ring_ag_matmul(x_, w_, axis, **kw)
    return jax.jit(shard_map(fn, mesh=mesh,
                             in_specs=(P(), P(None, axis)),
                             out_specs=P(), check_vma=False))(x, w)


@pytest.mark.parametrize("axis,n", [("data", 4), ("model", 2)])
def test_ring_ag_matmul_vs_unfused(mesh2, rng, axis, n):
    """The fused forward == gather-then-matmul, bit-for-bit (the
    column-concat identity the whole feature rests on)."""
    x = jnp.asarray(rng.normal(0, 1, (16, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (24, 8 * n)), jnp.float32)
    base = lambda x_, w_: x_ @ jax.lax.all_gather(w_, axis, axis=1,
                                                  tiled=True)
    want = jax.jit(shard_map(base, mesh=mesh2,
                             in_specs=(P(), P(None, axis)),
                             out_specs=P(), check_vma=False))(x, w)
    got = _ring_ag(mesh2, axis, x, w)
    assert jnp.array_equal(got, want)


def test_ring_ag_matmul_vs_oracle(mesh2, rng):
    """Ring output == the per-chunk oracle laid out in rank order."""
    n = 4
    x = jnp.asarray(rng.normal(0, 1, (8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (16, 12 * n)), jnp.float32)
    w_chunks = jnp.stack(jnp.split(w, n, axis=1))       # [n, K, Nc]
    got = _ring_ag(mesh2, "data", x, w)
    assert jnp.array_equal(got, ref.ag_matmul_ref(x, w_chunks))


@pytest.mark.pallas_interpret
def test_ring_ag_matmul_pallas_impl(mesh2, rng):
    """impl='pallas' (interpret) through the ring == the tile-loop
    oracle per chunk -- ragged Nc exercises the pad-and-slice path."""
    n = 2
    x = jnp.asarray(rng.normal(0, 1, (10, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (16, 18 * n)), jnp.float32)
    got = _ring_ag(mesh2, "model", x, w, impl="pallas", interpret=True,
                   block_m=8, block_n=16)
    w_chunks = jnp.split(w, n, axis=1)
    want = jnp.concatenate(
        [ref.matmul_chunk_ref(x, c, block_m=8, block_n=16)
         for c in w_chunks], axis=1)
    assert jnp.array_equal(got, want)


def test_ring_ag_matmul_batched_x(mesh2, rng):
    """Arbitrary-rank activations ([B, S, K]) flow through the ring."""
    x = jnp.asarray(rng.normal(0, 1, (2, 6, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (16, 8 * 4)), jnp.float32)
    base = lambda x_, w_: x_ @ jax.lax.all_gather(w_, "data", axis=1,
                                                  tiled=True)
    want = jax.jit(shard_map(base, mesh=mesh2,
                             in_specs=(P(), P(None, "data")),
                             out_specs=P(), check_vma=False))(x, w)
    assert jnp.array_equal(_ring_ag(mesh2, "data", x, w), want)


def test_ring_matmul_rs_vs_ref(mesh2, rng):
    """Per-rank reduce-scatter chunks match the oracle's exact
    hop-by-hop accumulation order (bit-exact, not allclose)."""
    n = 4
    a = jnp.asarray(rng.normal(0, 1, (n, 6, 10)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (n, 10, 8 * n)), jnp.float32)

    def body(a_, b_):
        out = cm.ring_matmul_rs(a_[0], b_[0], "data")
        return out[None]
    got = jax.jit(shard_map(body, mesh=mesh2,
                            in_specs=(P("data"), P("data")),
                            out_specs=P("data"), check_vma=False))(a, b)
    for r in range(n):
        assert jnp.array_equal(got[r], ref.matmul_rs_ref(a, b, r)), r


def test_ring_matmul_rs_sums_to_psum_scatter(mesh2, rng):
    """Summed over ranks (tolerantly): the fused RS == the unfused
    matmul + psum_scatter it replaces."""
    n = 4
    a = jnp.asarray(rng.normal(0, 1, (n, 6, 10)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (n, 10, 8 * n)), jnp.float32)

    def base(a_, b_):
        return jax.lax.psum_scatter(a_[0] @ b_[0], "data",
                                    scatter_dimension=1, tiled=True)[None]
    want = jax.jit(shard_map(base, mesh=mesh2,
                             in_specs=(P("data"), P("data")),
                             out_specs=P("data"), check_vma=False))(a, b)

    def body(a_, b_):
        return cm.ring_matmul_rs(a_[0], b_[0], "data")[None]
    got = jax.jit(shard_map(body, mesh=mesh2,
                            in_specs=(P("data"), P("data")),
                            out_specs=P("data"), check_vma=False))(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# custom_vjp: gradients
# ---------------------------------------------------------------------------

def _grads(mesh, axis, x, w, mode):
    def loss(x_, w_):
        y = cm.fused_matmul(x_, w_, axis, mode)
        return jnp.sum(y * y)
    fn = jax.grad(loss, argnums=(0, 1))
    return jax.jit(shard_map(fn, mesh=mesh,
                             in_specs=(P(), P(None, axis)),
                             out_specs=(P(), P(None, axis)),
                             check_vma=False))(x, w)


def test_ag_matmul_grad_bit_parity(mesh2, rng):
    """mode='ag_matmul' backward replays the unfused op sequence, so
    BOTH cotangents are bit-identical to the unfused path -- the
    property that makes whole training trajectories bit-identical."""
    x = jnp.asarray(rng.normal(0, 1, (6, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (16, 8 * 4)), jnp.float32)

    def base_loss(x_, w_):
        y = x_ @ jax.lax.all_gather(w_, "data", axis=1, tiled=True)
        return jnp.sum(y * y)
    want = jax.jit(shard_map(jax.grad(base_loss, argnums=(0, 1)),
                             mesh=mesh2,
                             in_specs=(P(), P(None, "data")),
                             out_specs=(P(), P(None, "data")),
                             check_vma=False))(x, w)
    got = _grads(mesh2, "data", x, w, "ag_matmul")
    assert jnp.array_equal(got[0], want[0])
    assert jnp.array_equal(got[1], want[1])


def test_both_grad_vs_ring_oracles(mesh2, rng):
    """mode='both' re-associates the dx sum, so it is exact against its
    OWN ring oracles (dx: fused_bwd_dx_ref per rank; dw: matmul_rs_ref)
    -- and only close to the unfused gradients."""
    n = 4
    x = jnp.asarray(rng.normal(0, 1, (6, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (16, 8 * n)), jnp.float32)
    w_chunks = jnp.stack(jnp.split(w, n, axis=1))       # [n, K, Nc]

    def loss(x_, w_):
        y = cm.fused_matmul(x_, w_, "data", "both")
        return jnp.sum(y * y)

    def per_rank(x_, w_):
        dx, dw = jax.grad(loss, argnums=(0, 1))(x_, w_)
        return dx[None], dw
    dx_all, dw = jax.jit(shard_map(
        per_rank, mesh=mesh2, in_specs=(P(), P(None, "data")),
        out_specs=(P("data"), P(None, "data")), check_vma=False))(x, w)

    y = ref.ag_matmul_ref(x, w_chunks)
    g = 2.0 * y                                         # d(sum y^2)/dy
    for r in range(n):
        assert jnp.array_equal(dx_all[r],
                               ref.fused_bwd_dx_ref(g, w_chunks, r)), r
    a_chunks = jnp.broadcast_to(x.T[None], (n,) + x.T.shape)
    b_chunks = jnp.broadcast_to(g[None], (n,) + g.shape)
    want_dw = jnp.concatenate(
        [ref.matmul_rs_ref(a_chunks, b_chunks, r) for r in range(n)],
        axis=1)
    assert jnp.array_equal(dw, want_dw)
    # and the unfused gradient is the same sum in a different order
    base = lambda x_, w_: jnp.sum(
        (x_ @ jax.lax.all_gather(w_, "data", axis=1, tiled=True)) ** 2)
    want = jax.jit(shard_map(jax.grad(base, argnums=(0, 1)), mesh=mesh2,
                             in_specs=(P(), P(None, "data")),
                             out_specs=(P(), P(None, "data")),
                             check_vma=False))(x, w)
    np.testing.assert_allclose(np.asarray(dx_all[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# plan-level eligibility gating (core/strategy.gather_plan)
# ---------------------------------------------------------------------------

def _plan(mode, pdef, mesh, fused="ag_matmul"):
    from repro.core.strategy import resolve_strategy
    s = mode if not isinstance(mode, str) else resolve_strategy(mode)
    return s.gather_plan(pdef, mesh, min_shard_size=0, fused_matmul=fused)


def _proj(**kw):
    from repro.core.partition import ParamDef
    kw.setdefault("fusable", True)
    return ParamDef((256, 128), ("tp", "fsdp"), **kw)


def test_gating_eligible_fcdp_multipod(mesh3):
    p = _plan("fcdp", _proj(), mesh3)
    assert p.is_fused and p.fused == "ag_matmul"
    assert len(p.intra_axes) == 1
    # and the knob off means no fusing anywhere
    assert not _plan("fcdp", _proj(), mesh3, fused="none").is_fused


def test_gating_eligible_stacked_and_zero3(mesh3, mesh2):
    from repro.core.partition import ParamDef
    stacked = ParamDef((4, 256, 128), ("stack", "tp", "fsdp"), fusable=True)
    assert _plan("fcdp", stacked, mesh3).is_fused
    # zero3 regathers stage 2 per use on any mesh: always fusable
    assert _plan("zero3", _proj(), mesh3).is_fused
    assert _plan("zero3", _proj(), mesh2).is_fused


def test_gating_declines_without_opt_in(mesh3):
    """Same shape/dims as a projection, but no ParamDef.fusable -- an
    embedding table is consumed via take, not matmul, and must never be
    wrapped in a FusedParam."""
    assert not _plan("fcdp", _proj(fusable=False), mesh3).is_fused


def test_gating_declines_shapes_and_frozen(mesh3):
    from repro.core.partition import ParamDef
    declined = [
        _proj(frozen=True),                              # FCDP-Comm
        ParamDef((256, 128), ("fsdp", "tp"), fusable=True),   # input-dim
        ParamDef((128,), ("fsdp",), fusable=True),       # 1-D
        # elementwise-consumed leaf (rwkv maa_base shape): the plan rule
        # cannot tell it from a projection -- ParamDef.fusable (default
        # False) is the def-site contract that keeps it unfused
        ParamDef((6, 128), (None, "fsdp")),
    ]
    for pdef in declined[1:]:
        assert not _plan("fcdp", pdef, mesh3).is_fused, pdef
    assert not _plan("fcdp", declined[0], mesh3).is_fused


def test_gating_declines_cached_full_weight(mesh2):
    """Single-pod fcdp/zeropp cache the FULLY gathered weight
    (cache_after=2): no per-use stage-2 gather remains to fuse."""
    for mode in ("fcdp", "zeropp"):
        p = _plan(mode, _proj(), mesh2)
        assert p.cache_after == 2
        assert not p.is_fused, mode


def test_gating_strategy_opt_out(mesh3):
    """A strategy subclass (or mixed-sharding group) that declines keeps
    its unfused stage-2 gather even for eligible leaves."""
    from repro.core.strategy import FCDP

    class Declining(FCDP):
        name = "declining_fused"
        supports_fused_matmul = False

    assert not _plan(Declining(), _proj(), mesh3).is_fused
    assert _plan(FCDP(), _proj(), mesh3).is_fused     # control


def test_sysconfig_validates_fused_knobs():
    from repro.configs.base import SystemConfig
    SystemConfig(fused_matmul="both", fused_impl="pallas")   # ok
    with pytest.raises(ValueError):
        SystemConfig(fused_matmul="everything")
    with pytest.raises(ValueError):
        SystemConfig(fused_impl="cuda")


# ---------------------------------------------------------------------------
# end-to-end: train-step bit-parity fused on vs off
# ---------------------------------------------------------------------------

def _train(mesh, mode, fused, batches):
    from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                    ShapeCell, SystemConfig)
    from repro.core.engine import StepBundle
    from repro.optim.adamw import init_opt_state
    cfg = ModelConfig(name="smoke-dense", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256)
    sysc = SystemConfig(mode=mode, min_shard_size=8, fused_matmul=fused)
    run = RunConfig(model=cfg, shape=ShapeCell("t", "train", 64, 8),
                    system=sysc,
                    optimizer=OptimizerConfig(total_steps=3, warmup_steps=1))
    b = StepBundle(run, mesh)
    n_fused = sum(int(getattr(p, "is_fused", False))
                  for p in jax.tree.leaves(
                      b.plan_leaves, is_leaf=lambda x: hasattr(x, "fused")))
    step = b.make_train_step()
    params = b.init_all_params(seed=0)
    tp, fp = b.split(params)
    opt = jax.jit(functools.partial(init_opt_state, sys=sysc))(tp)
    losses = []
    for batch in batches:
        tp, opt, m = step(tp, fp, opt, batch)
        losses.append(float(m["loss"]))
    return n_fused, losses, tp


def _batches(rng, n=2):
    return [{"ids": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(1, 256, (8, 64)), jnp.int32),
             "mask": jnp.ones((8, 64), bool)} for _ in range(n)]


def test_train_step_bit_parity(mesh3, rng):
    batches = _batches(rng)
    n_off, losses_off, params_off = _train(mesh3, "fcdp", "none", batches)
    n_on, losses_on, params_on = _train(mesh3, "fcdp", "ag_matmul", batches)
    assert n_off == 0 and n_on > 0
    assert losses_on == losses_off          # float-exact, not allclose
    leaves_off = jax.tree.leaves(params_off)
    leaves_on = jax.tree.leaves(params_on)
    assert all(jnp.array_equal(a, b)
               for a, b in zip(leaves_on, leaves_off))


def test_train_step_both_mode_trains(mesh3, rng):
    """mode='both' re-associates the bf16 backward: not bit-identical,
    but the trajectory stays within a tight drift bound."""
    batches = _batches(rng)
    _, losses_off, _ = _train(mesh3, "fcdp", "none", batches)
    n_on, losses_on, _ = _train(mesh3, "fcdp", "both", batches)
    assert n_on > 0
    drift = max(abs(a - b) / abs(b)
                for a, b in zip(losses_on, losses_off))
    assert drift < 5e-2, drift
