"""Continuous-batching serve engine + paged-KV tests.

Covers: serve_batch_dims branches, the PagedKVConfig/PageAllocator
invariants, the paged-plan gate, per-request bit-identity of the paged
scheduler path against the single-request contiguous path, policy
determinism (continuous vs static emit identical tokens), the KV-page
tenant in cache_bytes_per_chip, and plan_serve's documented demotion
order (prefetch depth -> device fraction -> KV pool halving)."""
import types

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeCell, SystemConfig
from repro.core.engine import StepBundle
from repro.core.kv_cache import (SCRATCH_PAGE, PageAllocator, PagedKVConfig,
                                 kv_page_bytes_per_chip)
from repro.core.serve_schedule import PagedServeEngine, Request

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=4, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
DEC_CELL = ShapeCell("t", "decode", 128, 8)


def _bundle(mesh, cell=DEC_CELL):
    run = RunConfig(model=DENSE, shape=cell,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    return StepBundle(run, mesh)


@pytest.fixture(scope="module")
def served(mesh3):
    b = _bundle(mesh3)
    return b, b.init_all_params(seed=0)


# -- serve_batch_dims --------------------------------------------------------

def test_serve_batch_dims_seq_sharded(mesh3):
    """When the sequence dim owns 'data' (long-context), the batch may
    only shard over the remaining fsdp axes."""
    from repro.core.engine.serve import serve_batch_dims
    b = _bundle(mesh3)
    # default: batch over all fsdp axes (data, pod) -> degree 4
    b_local, spec = serve_batch_dims(b, DEC_CELL)
    assert (b_local, spec) == (2, P(("data", "pod")))
    # seq_sharded: 'data' is spoken for, batch keeps only 'pod'
    b_local, spec = serve_batch_dims(b, DEC_CELL, seq_sharded=True)
    assert (b_local, spec) == (4, P(("pod",)))
    assert "data" not in spec[0]


def test_serve_batch_dims_nondivisible_falls_back(mesh3):
    """A batch the fsdp degree doesn't divide must replicate (P()),
    not crash or shard unevenly."""
    from repro.core.engine.serve import paged_replicas, serve_batch_dims
    cell = ShapeCell("t", "decode", 128, 6)      # 6 % (data*pod=4) != 0
    b = _bundle(mesh3, cell)
    b_local, spec = serve_batch_dims(b, cell)
    assert (b_local, spec) == (6, P())
    # replicated batch -> the paged pool has exactly one replica
    assert paged_replicas(b, cell) == 1


# -- paged KV config + allocator ---------------------------------------------

def test_paged_kv_config_invariants():
    kv = PagedKVConfig(page_size=16, pages_per_replica=17,
                       max_pages_per_seq=8)
    assert kv.max_seq_len == 128
    assert kv.pages_needed(1) == 1
    assert kv.pages_needed(16) == 1
    assert kv.pages_needed(17) == 2
    assert kv.pages_needed(128) == 8
    with pytest.raises(ValueError):
        PagedKVConfig(page_size=0, pages_per_replica=17, max_pages_per_seq=8)
    with pytest.raises(ValueError):
        # pool must hold the scratch page + at least one sequence
        PagedKVConfig(page_size=16, pages_per_replica=8, max_pages_per_seq=8)


def test_page_allocator_all_or_nothing():
    kv = PagedKVConfig(page_size=16, pages_per_replica=9, max_pages_per_seq=8)
    al = PageAllocator(kv)
    assert al.n_free == 8                       # scratch page never allocable
    got = al.alloc(8)
    assert sorted(got) == list(range(1, 9))
    assert SCRATCH_PAGE not in got
    assert al.alloc(1) is None and al.n_free == 0
    al.free(got[:3])
    assert al.n_free == 3
    assert al.alloc(4) is None                  # all-or-nothing
    assert al.n_free == 3                       # the failed alloc took nothing
    with pytest.raises(ValueError):
        al.free([SCRATCH_PAGE])
    with pytest.raises(ValueError):
        al.free([kv.pages_per_replica])


def test_check_paged_plan_rejects_recurrent_mixers():
    from repro.core.engine.serve import check_paged_plan
    check_paged_plan(types.SimpleNamespace(plan=(("attn", "mlp"),)))
    with pytest.raises(ValueError, match="mamba"):
        check_paged_plan(types.SimpleNamespace(
            plan=(("attn", "mlp"), ("mamba", "mlp"))))


# -- numerics ----------------------------------------------------------------

def test_paged_decode_bit_identical_to_contiguous(served):
    """A request served through the scheduler (chunked prefill + paged
    decode, riding in a batch of scratch rows) must produce logits
    BIT-identical to the same prompt through the single-request
    contiguous prefill/decode path -- the acceptance bar for the paged
    cache. Also pins the greedy pick to full-vocab argmax semantics."""
    import jax.numpy as jnp
    from repro.core.engine.serve import default_paged_kv
    b, params = served
    rng = np.random.default_rng(1)
    plen, gen = 23, 6
    prompt = rng.integers(1, DENSE.vocab_size, (plen,)).astype(np.int32)

    # reference: contiguous prefill over the prompt, then decode
    B = DEC_CELL.global_batch
    prefill = b.make_prefill_step()
    decode = b.make_decode_step()
    pick = b.make_greedy_pick()
    state = b.init_state(DEC_CELL)
    ids = np.tile(prompt[None, :], (B, 1))
    logits, state = prefill(params, jnp.asarray(ids), state)
    ref_logits = [np.asarray(logits)[0]]
    tok = np.asarray(pick(logits))
    ref_toks = [int(tok[0])]
    cur = jnp.asarray(tok[:, None].astype(np.int32))
    for _ in range(gen - 1):
        logits, state = decode(params, cur, state)
        ref_logits.append(np.asarray(logits)[0])
        tok = np.asarray(pick(logits))
        ref_toks.append(int(tok[0]))
        cur = jnp.asarray(tok[:, None].astype(np.int32))

    # paged: the same request through the scheduler, chunk smaller than
    # the prompt so prefill spans multiple (and one ragged) chunk
    kv = default_paged_kv(b, DEC_CELL)
    assert kv.max_pages_per_seq * kv.page_size == DEC_CELL.seq_len
    eng = PagedServeEngine(b, kv, chunk=8, capture_logits=True)
    results, _ = eng.serve(params, [Request(rid=0, prompt=prompt,
                                            max_new_tokens=gen)])
    r = results[0]
    assert r.tokens == ref_toks
    cap = eng.captured[0]
    assert len(cap) == len(ref_logits)
    for got, want in zip(cap, ref_logits):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)   # bitwise, not allclose
    # greedy pick == argmax over the full gathered vocab (lowest index
    # on ties), despite only per-rank candidates crossing the wire
    for t, lg in zip(r.tokens, cap):
        assert t == int(np.argmax(lg))


def test_policies_emit_identical_tokens(served):
    """Admission policy changes WHEN a request runs, never WHAT it
    generates: continuous and static must emit identical per-request
    token streams on a workload larger than the slot grid."""
    from repro.core.engine.serve import default_paged_kv
    b, params = served
    rng = np.random.default_rng(3)
    plens = [5, 40, 9, 33, 12, 7, 21, 60, 4, 18]          # > B=8 slots
    gens = [4, 2, 7, 3, 1, 5, 2, 6, 3, 4]
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, DENSE.vocab_size,
                                        (p,)).astype(np.int32),
                    max_new_tokens=g)
            for i, (p, g) in enumerate(zip(plens, gens))]
    kv = default_paged_kv(b, DEC_CELL)
    cont = PagedServeEngine(b, kv, chunk=16, policy="continuous")
    stat = PagedServeEngine(b, kv, chunk=16, policy="static",
                            share_steps_with=cont)
    res_c, _ = cont.serve(params, list(reqs))
    res_s, _ = stat.serve(params, list(reqs))
    assert len(res_c) == len(res_s) == len(reqs)
    by_c = {r.rid: r.tokens for r in res_c}
    by_s = {r.rid: r.tokens for r in res_s}
    assert by_c == by_s
    for r in res_c:
        assert len(r.tokens) == gens[r.rid]
    # pages all returned once drained
    assert all(a.n_free == kv.pages_per_replica - 1 for a in cont.allocs)
    # a request that can never fit is rejected up front, not wedged
    with pytest.raises(ValueError, match="exceeds"):
        cont.serve(params, [Request(rid=99,
                                    prompt=np.ones((200,), np.int32),
                                    max_new_tokens=9)])


# -- planner tenancy ---------------------------------------------------------

def test_kv_pages_in_cache_accounting(mesh3):
    """kv_page_bytes_per_chip is schema-stable (0.0 without a paged
    path) and scales linearly with pool capacity when present."""
    from repro.core.cache import cache_bytes_per_chip
    from repro.core.engine.serve import default_paged_kv
    b = _bundle(mesh3)
    assert cache_bytes_per_chip(b)["kv_page_bytes_per_chip"] == 0.0
    kv = default_paged_kv(b, DEC_CELL)
    got = cache_bytes_per_chip(b, kv=kv)["kv_page_bytes_per_chip"]
    want = kv_page_bytes_per_chip(DENSE, b.mi, b.model.plan,
                                  b.model.n_groups, kv)
    assert got == want > 0
    import dataclasses
    kv2 = dataclasses.replace(kv,
                              pages_per_replica=2 * kv.pages_per_replica)
    got2 = cache_bytes_per_chip(b, kv=kv2)["kv_page_bytes_per_chip"]
    assert got2 == 2 * got


def test_plan_serve_demote_order(mesh3):
    """Serve tau search: generous budget keeps the full pool at the
    fastest fraction; impossible budget demotes fractions first and the
    KV pool LAST, halving to the one-sequence floor before giving up."""
    from repro.core.cache import MemoryPlanner
    from repro.core.engine.serve import default_paged_kv
    run = RunConfig(model=DENSE, shape=DEC_CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    b = _bundle(mesh3)
    kv = default_paged_kv(b, DEC_CELL)

    plan = MemoryPlanner(hbm_budget=1 << 40).plan_serve(
        run, mesh3, kv, fractions=(1.0,))
    assert plan.fits and plan.device_fraction == 1.0
    assert plan.kv_pages == kv.pages_per_replica

    plan2 = MemoryPlanner(hbm_budget=1).plan_serve(
        run, mesh3, kv, fractions=(0.0,))
    assert not plan2.fits
    floor = 1 + kv.max_pages_per_seq
    assert plan2.kv_pages == floor
    pools = [it["kv_pages"] for it in plan2.iterations]
    # fraction demotions keep the pool intact; only the tail halves it
    assert pools[0] == kv.pages_per_replica
    assert pools == sorted(pools, reverse=True)
    assert pools[-1] == floor
    # every iteration re-accounts the pool so the search is auditable
    assert all(it["kv_page_bytes"] > 0 for it in plan2.iterations)
