"""FCDP-Cache planner + roofline-walker unit tests."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                ShapeCell, SystemConfig)
from repro.core.engine import StepBundle

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=4, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
CELL = ShapeCell("t", "train", 64, 8)


def test_memory_planner_tau_search(mesh3):
    """The planner demotes device->host placements until the step fits
    the budget; worst case is all-regather (== zero3), per the paper's
    guarantee."""
    from repro.core.cache import MemoryPlanner
    run = RunConfig(model=DENSE, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8),
                    optimizer=OptimizerConfig(total_steps=4, warmup_steps=1))
    # generous budget: the fastest (full device-cache) plan must win
    planner = MemoryPlanner(hbm_budget=1 << 40)
    plan = planner.plan(run, mesh3, fractions=(1.0, 0.0))
    assert plan.fits and plan.device_fraction == 1.0
    # impossible budget: the planner walks every fraction, then tries the
    # block_io activation-remat fallback, and reports the
    # ZeRO-3-equivalent floor without fitting
    planner2 = MemoryPlanner(hbm_budget=1)
    plan2 = planner2.plan(run, mesh3, fractions=(1.0, 0.0))
    assert not plan2.fits and plan2.device_fraction == 0.0
    assert len(plan2.iterations) == 3
    assert plan2.iterations[-1]["activation_policy"] == "block_io"
    assert plan2.iterations[0]["activation_policy"] == "save_all"
    # device-cache fraction must not change peak by more than the cache
    peaks = [it["peak_bytes"] for it in plan2.iterations]
    assert peaks[0] >= peaks[1]  # demoting to host frees HBM (CPU: >=)


def test_memory_planner_block_io_fallback_fits(mesh3):
    """A budget between the save_all and block_io peaks must be rescued
    by the activation-remat fallback rather than declared regather-only."""
    from repro.core.cache import MemoryPlanner
    run = RunConfig(model=DENSE, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8),
                    optimizer=OptimizerConfig(total_steps=4, warmup_steps=1))
    probe = MemoryPlanner(hbm_budget=1).plan(run, mesh3, fractions=(0.0,))
    save_all_peak = probe.iterations[0]["peak_bytes"]
    block_io_peak = probe.iterations[-1]["peak_bytes"]
    assert block_io_peak < save_all_peak  # remat must actually free HBM
    budget = (block_io_peak + save_all_peak) // 2
    plan = MemoryPlanner(hbm_budget=budget).plan(run, mesh3,
                                                 fractions=(0.0,))
    assert plan.fits
    assert plan.activation_policy == "block_io"
    assert plan.device_fraction == 0.0


def test_host_cache_accounting(mesh3, mesh2):
    """Host-cache bytes: stage-1 shards on the multi-pod mesh (W/pod per
    pod), full TP-local weights on the single-pod mesh (W/tp per chip)."""
    from repro.core.cache import cache_bytes_per_chip
    run = RunConfig(model=DENSE, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    b3 = StepBundle(run, mesh3)
    b2 = StepBundle(run, mesh2)
    h3 = cache_bytes_per_chip(b3)["host_cache_bytes_per_chip"]
    h2 = cache_bytes_per_chip(b2)["host_cache_bytes_per_chip"]
    assert h3 > 0 and h2 > 0
    # single-pod caches the fully gathered weight -> strictly larger
    assert h2 > h3


def test_roofline_walker_counts_scan_trips(mesh3):
    """The jaxpr walker must multiply scanned-layer costs by the trip
    count -- doubling num_layers must ~double walked FLOPs."""
    from repro.launch.roofline import flops_bytes_from_jaxpr
    import dataclasses
    flops = {}
    for L in (2, 4):
        cfg = dataclasses.replace(DENSE, num_layers=L)
        run = RunConfig(model=cfg, shape=CELL,
                        system=SystemConfig(mode="fcdp", min_shard_size=8))
        b = StepBundle(run, mesh3)
        step = b.make_train_step()
        closed = step.trace(*b.train_input_sds()).jaxpr
        f, _ = flops_bytes_from_jaxpr(closed, 8)
        flops[L] = f
    # layer-proportional part dominates the embedding/head at this width?
    # it does not at vocab 256 x d 64, so check the layer DELTA instead:
    delta = flops[4] - flops[2]
    assert delta > 0
    # adding 2 more layers again would add the same amount: verify by
    # linear extrapolation against a 6-layer model
    cfg6 = dataclasses.replace(DENSE, num_layers=6)
    run6 = RunConfig(model=cfg6, shape=CELL,
                     system=SystemConfig(mode="fcdp", min_shard_size=8))
    b6 = StepBundle(run6, mesh3)
    closed6 = b6.make_train_step().trace(*b6.train_input_sds()).jaxpr
    f6, _ = flops_bytes_from_jaxpr(closed6, 8)
    np.testing.assert_allclose(f6, flops[4] + delta, rtol=0.02)


def test_collective_walker_axis_attribution(mesh3):
    """pod-axis collectives -> dcn bytes; data/model -> ici."""
    from repro.launch.roofline import collect_collectives
    run = RunConfig(model=DENSE, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8))
    b = StepBundle(run, mesh3)
    step = b.make_train_step()
    closed = step.trace(*b.train_input_sds()).jaxpr
    stats = collect_collectives(closed, {"pod": 2, "data": 2, "model": 2})
    assert stats.dcn_bytes > 0 and stats.ici_bytes > 0
    assert stats.by_axis["pod"] == pytest.approx(stats.dcn_bytes)
    assert (stats.by_axis["data"] + stats.by_axis["model"]
            == pytest.approx(stats.ici_bytes))
    # fcdp invariant: backward pod AG eliminated -> pod AG bytes must be
    # exactly the forward gather volume (one (n-1)/n * shard sweep + CE)
    assert stats.by_op_axis["all_gather/pod"] < stats.by_op_axis[
        "all_gather/data"]


def test_opt_state_dtype_halves_state(mesh3):
    """bf16 optimizer states (the kimi-k2 HBM mitigation recorded in
    EXPERIMENTS.md) produce bf16 m/v leaves and still train."""
    from repro.optim.adamw import init_opt_state
    run = RunConfig(model=DENSE, shape=CELL,
                    system=SystemConfig(mode="fcdp", min_shard_size=8,
                                        opt_state_dtype="bfloat16"),
                    optimizer=OptimizerConfig(total_steps=4, warmup_steps=1))
    b = StepBundle(run, mesh3)
    params = b.init_all_params(seed=0)
    tp, fp = b.split(params)
    opt = jax.jit(functools.partial(init_opt_state, sys=run.system))(tp)
    assert all(m.dtype == jnp.bfloat16 for m in opt["m"])
    batch = {"ids": jnp.ones((8, 64), jnp.int32),
             "labels": jnp.ones((8, 64), jnp.int32) * 2,
             "mask": jnp.ones((8, 64), bool)}
    tp, opt, m = b.make_train_step()(tp, fp, opt, batch)
    assert np.isfinite(float(m["loss"]))
