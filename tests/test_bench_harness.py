"""Benchmark harness unit tests: artifact schema validation, the
regression gate's noise-band semantics, and its failure modes.

Everything here runs on synthetic fixtures -- no StepBundle, no jax
compile -- so the gate's logic is testable in milliseconds.  The
end-to-end path (real axes -> run dir -> compare) is exercised by CI's
timed-smoke job against results/baseline/.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.harness import results  # noqa: E402
from benchmarks.harness.results import (Metric, RunDir, SchemaError,  # noqa: E402
                                        make_artifact, metric, validate)
from benchmarks import compare  # noqa: E402


def _mk_doc(axis="toy", values=None, bands=None, timing=None):
    values = values or {"bytes": 100.0, "speedup": 2.0}
    bands = bands or {}
    metrics = [
        metric("bytes", values["bytes"], direction="lower",
               noise_band=bands.get("bytes", 1e-3), unit="B"),
        metric("speedup", values["speedup"], direction="higher",
               noise_band=bands.get("speedup", 0.05), unit="x"),
    ]
    return make_artifact(axis, {"smoke": True, "rows": []}, metrics,
                         timing=timing)


def _mk_run(tmp_path, name, docs):
    rd = RunDir.create(smoke=True, timed=True, root=tmp_path / name,
                       stamp="stamp")
    for doc in docs:
        rd.write_axis(doc)
    rd.finalize()
    return rd.path


# ---------------------------------------------------------------------------
# schema layer
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_validates():
    doc = _mk_doc()
    validate(doc)                       # no raise
    assert doc["schema_version"] == results.SCHEMA_VERSION
    assert doc["axis"] == "toy"
    # payload keys stay at top level for the legacy flat consumers
    assert doc["smoke"] is True and doc["rows"] == []


def test_schema_version_mismatch_readable():
    doc = _mk_doc()
    doc["schema_version"] = 99
    with pytest.raises(SchemaError, match="schema_version 99"):
        validate(doc)
    with pytest.raises(SchemaError, match="regenerate"):
        validate(doc)


def test_envelope_collision_rejected():
    with pytest.raises(SchemaError, match="collides"):
        make_artifact("toy", {"metrics": []}, [])


def test_metric_field_validation():
    with pytest.raises(SchemaError, match="unknown kind"):
        Metric(name="x", value=1.0, kind="vibes")
    with pytest.raises(SchemaError, match="unknown direction"):
        Metric(name="x", value=1.0, direction="sideways")
    with pytest.raises(SchemaError, match="noise_band"):
        Metric(name="x", value=1.0, noise_band=-0.1)
    doc = _mk_doc()
    doc["metrics"][0]["value"] = float("nan")
    with pytest.raises(SchemaError, match="finite"):
        validate(doc)


def test_timing_block_schema():
    ok = {"timed": True, "warmup_steps": 2, "timed_steps": 5,
          "arms": {"a": {"median_s": 0.1, "p90_s": 0.2, "mean_s": 0.12,
                         "min_s": 0.09, "n": 5}}}
    validate(_mk_doc(timing=ok))
    bad = {"timed": True, "arms": {"a": {"median_s": 0.1}}}
    with pytest.raises(SchemaError, match="missing 'p90_s'"):
        validate(_mk_doc(timing=bad))
    with pytest.raises(SchemaError, match="no.*arms"):
        validate(_mk_doc(timing={"timed": True, "arms": {}}))


def test_axis_validator_plugs_into_shared_gate():
    def extra(doc):
        raise SchemaError("axis invariant violated")
    results.register_axis_validator("picky", extra)
    try:
        with pytest.raises(SchemaError, match="axis invariant"):
            validate(_mk_doc(axis="picky"))
    finally:
        results._AXIS_VALIDATORS.pop("picky")


def test_run_dir_manifest(tmp_path):
    path = _mk_run(tmp_path, "r", [_mk_doc("a"), _mk_doc("b")])
    manifest, docs = results.load_run(path)
    assert manifest["axes"] == ["a", "b"]
    assert set(docs) == {"a", "b"}
    assert (path / "manifest.json").exists()
    # not-a-run-dir error is readable
    with pytest.raises(SchemaError, match="manifest.json"):
        results.load_run(tmp_path)


# ---------------------------------------------------------------------------
# regression gate
# ---------------------------------------------------------------------------

def test_identical_runs_pass(tmp_path):
    base = _mk_run(tmp_path, "base", [_mk_doc()])
    new = _mk_run(tmp_path, "new", [_mk_doc()])
    rows, errors = compare.compare_runs(base, new)
    assert not errors
    assert all(r["status"] == "ok" for r in rows)


def test_within_band_jitter_passes(tmp_path):
    base = _mk_run(tmp_path, "base", [_mk_doc()])
    # bytes +0.05% (band 0.1%), speedup -4% (band 5%)
    new = _mk_run(tmp_path, "new", [
        _mk_doc(values={"bytes": 100.05, "speedup": 1.92})])
    rows, errors = compare.compare_runs(base, new)
    assert not errors, errors


def test_beyond_band_regression_fails(tmp_path):
    base = _mk_run(tmp_path, "base", [_mk_doc()])
    # bytes +10% against a 0.1% band: must gate
    new = _mk_run(tmp_path, "new", [
        _mk_doc(values={"bytes": 110.0, "speedup": 2.0})])
    rows, errors = compare.compare_runs(base, new)
    assert len(errors) == 1
    assert "bytes" in errors[0] and "regressed" in errors[0]
    assert next(r for r in rows if r["metric"] == "bytes")["status"] \
        == "REGRESSED"


def test_direction_aware_higher_is_better(tmp_path):
    base = _mk_run(tmp_path, "base", [_mk_doc()])
    # speedup 2.0 -> 1.7 is -15% against a 5% band: must gate;
    # speedup 2.0 -> 2.5 is an improvement, never gated
    worse = _mk_run(tmp_path, "worse", [
        _mk_doc(values={"bytes": 100.0, "speedup": 1.7})])
    better = _mk_run(tmp_path, "better", [
        _mk_doc(values={"bytes": 100.0, "speedup": 2.5})])
    _, errors = compare.compare_runs(base, worse)
    assert len(errors) == 1 and "speedup" in errors[0]
    rows, errors = compare.compare_runs(base, better)
    assert not errors
    assert next(r for r in rows if r["metric"] == "speedup")["status"] \
        == "improved"


def test_zero_band_demands_equality(tmp_path):
    mk = lambda v: make_artifact(
        "toy", {}, [metric("bit_identical", v, direction="higher",
                           noise_band=0.0)])
    base = _mk_run(tmp_path, "base", [mk(1.0)])
    same = _mk_run(tmp_path, "same", [mk(1.0)])
    broke = _mk_run(tmp_path, "broke", [mk(0.0)])
    assert not compare.compare_runs(base, same)[1]
    _, errors = compare.compare_runs(base, broke)
    assert len(errors) == 1


def test_missing_metric_readable_error(tmp_path):
    base = _mk_run(tmp_path, "base", [_mk_doc()])
    dropped = make_artifact("toy", {}, [
        metric("bytes", 100.0, direction="lower", noise_band=1e-3)])
    new = _mk_run(tmp_path, "new", [dropped])
    _, errors = compare.compare_runs(base, new)
    assert len(errors) == 1
    assert "speedup" in errors[0]
    assert "missing from the new run" in errors[0]
    assert "refresh" in errors[0]


def test_missing_axis_readable_error(tmp_path):
    base = _mk_run(tmp_path, "base", [_mk_doc("a"), _mk_doc("b")])
    new = _mk_run(tmp_path, "new", [_mk_doc("a")])
    _, errors = compare.compare_runs(base, new)
    assert len(errors) == 1 and "'b'" in errors[0]


def test_new_axis_and_metric_do_not_gate(tmp_path):
    base = _mk_run(tmp_path, "base", [_mk_doc("a")])
    extra = make_artifact("a", {}, [
        metric("bytes", 100.0, direction="lower", noise_band=1e-3),
        metric("speedup", 2.0, direction="higher", noise_band=0.05),
        metric("brand_new", 7.0)])
    new = _mk_run(tmp_path, "new", [extra, _mk_doc("b")])
    rows, errors = compare.compare_runs(base, new)
    assert not errors
    statuses = {(r["axis"], r["metric"]): r["status"] for r in rows}
    assert statuses[("a", "brand_new")] == "new"
    assert statuses[("b", "(whole axis)")] == "new"


def test_schema_version_mismatch_fails_gate(tmp_path):
    base = _mk_run(tmp_path, "base", [_mk_doc()])
    new = _mk_run(tmp_path, "new", [_mk_doc()])
    doc = json.load(open(new / "toy.json"))
    doc["schema_version"] = 0
    json.dump(doc, open(new / "toy.json", "w"))
    with pytest.raises(SchemaError, match="schema_version 0"):
        compare.compare_runs(base, new)


def test_band_taken_from_new_run(tmp_path):
    # the tree under test declares its tolerance: widening the band in
    # the new artifact lets a larger delta pass without touching the
    # committed baseline
    base = _mk_run(tmp_path, "base", [_mk_doc()])
    new = _mk_run(tmp_path, "new", [
        _mk_doc(values={"bytes": 110.0, "speedup": 2.0},
                bands={"bytes": 0.2})])
    _, errors = compare.compare_runs(base, new)
    assert not errors


def test_refresh_baseline_rejects_failed_run(tmp_path):
    run = _mk_run(tmp_path, "run", [_mk_doc()])
    manifest = json.load(open(run / "manifest.json"))
    manifest["failures"] = {"toy": "boom"}
    json.dump(manifest, open(run / "manifest.json", "w"))
    with pytest.raises(SchemaError, match="fully green"):
        compare.refresh_baseline(run, tmp_path / "baseline")


def test_refresh_baseline_roundtrip(tmp_path):
    run = _mk_run(tmp_path, "run", [_mk_doc()])
    dest = tmp_path / "baseline"
    compare.refresh_baseline(run, dest)
    rows, errors = compare.compare_runs(dest, run)
    assert not errors and all(r["status"] == "ok" for r in rows)


def test_cli_exit_codes(tmp_path, capsys):
    base = _mk_run(tmp_path, "base", [_mk_doc()])
    good = _mk_run(tmp_path, "good", [_mk_doc()])
    bad = _mk_run(tmp_path, "bad", [
        _mk_doc(values={"bytes": 200.0, "speedup": 2.0})])
    assert compare.main([str(good), "--baseline", str(base)]) == 0
    assert compare.main([str(bad), "--baseline", str(base)]) == 1
    err = capsys.readouterr().err
    assert "regressed" in err
