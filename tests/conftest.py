"""Shared test fixtures. Device count is raised to 8 for the mesh tests
(NOT 512 -- the production meshes are exercised only via the dry-run)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh3():
    """2x2x2 (pod, data, model) mesh on CPU devices."""
    from repro.launch.mesh import make_mesh
    return make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="session")
def mesh2():
    """4x2 (data, model) single-pod-style mesh."""
    from repro.launch.mesh import make_mesh
    return make_mesh((4, 2), ("data", "model"))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
