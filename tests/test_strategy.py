"""Strategy-layer tests: golden parity of the four built-in strategies
against the seed's storage/gather schedule, registry behaviour, and the
layer-ahead prefetch scheduler (numerical equivalence + comm structure).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                ShapeCell, SystemConfig)
from repro.core.engine import StepBundle
from repro.core.partition import ParamDef
from repro.core.strategy import (DEFAULT_STRATEGY, GatherPlan,
                                 ShardingStrategy, get_strategy,
                                 register_strategy, resolve_strategy,
                                 strategy_names)

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                    qkv_bias=True)
CELL = ShapeCell("t", "train", 64, 8)

# a stacked 2D weight with an fsdp dim, as every block weight has
WDEF = ParamDef((2, 64, 128), ("stack", "fsdp", None))
WDEF_FROZEN = ParamDef((2, 64, 128), ("stack", "fsdp", None), frozen=True)
WDEF_TP = ParamDef((64, 128), ("fsdp", "tp"))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_contents():
    names = strategy_names()
    for name in ("zero3", "zeropp", "fcdp", "mics"):
        assert name in names
        assert get_strategy(name).name == name
    assert DEFAULT_STRATEGY in names
    # singletons: SystemConfig.mode resolves to the same object each time
    assert get_strategy("fcdp") is get_strategy("fcdp")
    assert resolve_strategy(get_strategy("zero3")) is get_strategy("zero3")
    with pytest.raises(ValueError, match="unknown system mode"):
        get_strategy("zero17")


def test_register_custom_strategy():
    class Hierarchical(ShardingStrategy):
        name = "test_hier"
        cache_placement = "device"
    try:
        register_strategy(Hierarchical)
        assert get_strategy("test_hier").cache_placement == "device"
        # a full StepBundle builds against the new mode
        run = RunConfig(model=DENSE, shape=CELL,
                        system=SystemConfig(mode="test_hier",
                                            min_shard_size=8))
        from repro.launch.mesh import make_mesh
        b = StepBundle(run, make_mesh((2, 2, 2), ("pod", "data", "model")))
        assert b.strategy.name == "test_hier"
    finally:
        from repro.core import strategy as strat_mod
        strat_mod._REGISTRY.pop("test_hier", None)


# ---------------------------------------------------------------------------
# Golden parity: each strategy reproduces the seed's storage_spec and
# GatherPlan (fsdp dim, inter/intra axes, cache boundary) on both meshes.
# ---------------------------------------------------------------------------

# (mode, frozen) -> expected (spec entry, inter_axes, intra_axes,
# cache_after) on the multi-pod ('pod','data','model') mesh.
# Full sharding tiles INTRA-major (('data','pod'), pod last): the
# two-stage gather runs stage 1 (pod) then stage 2 (data), so data-major
# storage is what makes the staged reconstruction land blocks in true
# global order -- required for per-tensor mixed sharding, where a
# two-stage-gathered leaf contracts against single-stage (mics/hier/
# frozen) leaves and both must agree on the gathered basis.
GOLDEN_MULTIPOD = {
    ("zero3", False): (("data", "pod"), ("pod",), ("data",), 1),
    ("zeropp", False): (("data", "pod"), ("pod",), ("data",), 1),
    ("fcdp", False): (("data", "pod"), ("pod",), ("data",), 1),
    ("mics", False): ("data", (), ("data",), 2),
    # hier: params take the MiCS (pod-replicated) layout; only the
    # OPTIMIZER state widens to ('data','pod') -- see test_hier_opt_spec
    ("hier", False): ("data", (), ("data",), 2),
    # frozen: FCDP-Comm cached layout applies in fcdp only
    ("zero3", True): (("data", "pod"), ("pod",), ("data",), 1),
    ("zeropp", True): (("data", "pod"), ("pod",), ("data",), 1),
    ("fcdp", True): ("data", (), ("data",), 2),
    ("mics", True): ("data", (), ("data",), 2),
    ("hier", True): ("data", (), ("data",), 2),
}


@pytest.mark.parametrize("mode", ["zero3", "zeropp", "fcdp", "mics", "hier"])
@pytest.mark.parametrize("frozen", [False, True])
def test_golden_parity_multipod(mesh3, mode, frozen):
    strat = get_strategy(mode)
    pdef = WDEF_FROZEN if frozen else WDEF
    spec_entry, inter, intra, cache_after = GOLDEN_MULTIPOD[(mode, frozen)]
    spec = strat.storage_spec(pdef, mesh3)
    assert spec == P(None, spec_entry, None), (mode, frozen, spec)
    plan = strat.gather_plan(pdef, mesh3)
    assert plan.is_gathered
    assert plan.fsdp_dim == 0          # stack dim consumed by scan
    assert plan.inter_axes == inter
    assert plan.intra_axes == intra
    assert plan.cache_after == cache_after
    assert plan.frozen == frozen


@pytest.mark.parametrize("mode", ["zero3", "zeropp", "fcdp", "mics", "hier"])
def test_golden_parity_singlepod(mesh2, mode):
    """No pod axis: every strategy collapses to ('data',) storage with an
    empty stage 1 and the cache boundary after the full gather."""
    strat = get_strategy(mode)
    spec = strat.storage_spec(WDEF, mesh2)
    assert spec == P(None, "data", None), (mode, spec)
    plan = strat.gather_plan(WDEF, mesh2)
    assert plan.inter_axes == ()
    assert plan.intra_axes == ("data",)
    assert plan.cache_after == 2
    assert not plan.prefetchable


def test_golden_parity_tp_dim(mesh3):
    for mode in ("zero3", "fcdp"):
        spec = get_strategy(mode).storage_spec(WDEF_TP, mesh3)
        assert spec == P(("data", "pod"), "model"), (mode, spec)


def test_cache_placement_per_mode():
    assert get_strategy("zero3").cache_placement == "regather"
    assert get_strategy("zeropp").cache_placement == "device"
    assert get_strategy("fcdp").cache_placement == "host"
    assert get_strategy("mics").cache_placement == "regather"
    assert get_strategy("hier").cache_placement == "regather"


def test_device_cache_fraction_gating():
    # FCDP-Cache's tau fraction only applies under fcdp
    assert get_strategy("fcdp").device_cache_groups(8, 0.5) == 4
    for mode in ("zero3", "zeropp", "mics", "hier"):
        assert get_strategy(mode).device_cache_groups(8, 0.5) == 0


def test_hier_opt_spec(mesh3, mesh2):
    """hier shards optimizer state wider than params: storage is the
    MiCS (pod-replicated) layout, opt state goes over the full fsdp
    product with the storage axes MAJOR in the tiling order (so the
    widening reduce-scatter lands on the device's opt slice)."""
    hier = get_strategy("hier")
    assert hier.storage_spec(WDEF, mesh3) == P(None, "data", None)
    assert hier.opt_spec(WDEF, mesh3) == P(None, ("data", "pod"), None)
    # no pod axis: opt layout collapses to the param layout
    assert hier.opt_spec(WDEF, mesh2) == hier.storage_spec(WDEF, mesh2)
    # every other built-in keeps opt state at the (full-scope) param layout
    import dataclasses
    for mode in ("zero3", "zeropp", "fcdp", "mics"):
        s = get_strategy(mode)
        assert s.opt_spec(WDEF, mesh3) == s.storage_spec(
            dataclasses.replace(WDEF, fsdp_scope="full"), mesh3)


def test_legacy_module_level_helpers_delegate(mesh3):
    """The partition/fcdp module-level helpers accept mode names and
    produce the strategy's result (back-compat seam)."""
    from repro.core.fcdp import make_gather_plan
    from repro.core.partition import storage_spec
    for mode in strategy_names():
        strat = get_strategy(mode)
        assert storage_spec(WDEF, mesh3, mode) == strat.storage_spec(
            WDEF, mesh3)
        assert make_gather_plan(WDEF, mesh3, mode) == strat.gather_plan(
            WDEF, mesh3)


# ---------------------------------------------------------------------------
# Prefetch scheduler
# ---------------------------------------------------------------------------

def make_bundle(mesh, mode=DEFAULT_STRATEGY, **sys_kw):
    sysd = dict(mode=mode, min_shard_size=8)
    sysd.update(sys_kw)
    run = RunConfig(model=DENSE, shape=CELL, system=SystemConfig(**sysd),
                    optimizer=OptimizerConfig(total_steps=8, warmup_steps=2,
                                              lr=1e-3))
    return StepBundle(run, mesh)


def make_batch(seed=0):
    rng = np.random.default_rng(seed)
    b = {"ids": jnp.asarray(
            rng.integers(1, DENSE.vocab_size,
                         (CELL.global_batch, CELL.seq_len)), jnp.int32),
         "labels": jnp.asarray(
            rng.integers(1, DENSE.vocab_size,
                         (CELL.global_batch, CELL.seq_len)), jnp.int32)}
    b["mask"] = jnp.ones_like(b["labels"], bool)
    return b


def run_one_step(bundle):
    from repro.optim.adamw import init_opt_state
    params = bundle.init_all_params(seed=0)
    tp, fp = bundle.split(params)
    opt = jax.jit(functools.partial(
        init_opt_state, sys=bundle.run.system))(tp)
    step = bundle.make_train_step()
    tp, opt, m = step(tp, fp, opt, make_batch())
    return ({k: float(v) for k, v in m.items()},
            [np.asarray(x, np.float32) for x in tp])


def test_prefetch_gating():
    """Strategy x mesh gating: prefetch needs a pod axis, a willing
    strategy, and the config flag."""
    sys_on = SystemConfig(prefetch_depth=1)
    sys_off = SystemConfig(prefetch_depth=0)

    class M3:
        axis_names = ("pod", "data", "model")

    class M2:
        axis_names = ("data", "model")

    for mode in ("zero3", "zeropp", "fcdp"):
        assert get_strategy(mode).prefetch_active(sys_on, M3())
        assert not get_strategy(mode).prefetch_active(sys_off, M3())
        assert not get_strategy(mode).prefetch_active(sys_on, M2())
    assert not get_strategy("mics").prefetch_active(sys_on, M3())


@pytest.mark.parametrize("mode", ["zero3", "fcdp"])
def test_prefetch_numerical_equivalence(mesh3, mode):
    """The layer-ahead schedule must not change the math: one training
    step with prefetch on/off produces identical loss, grad norm, and
    updated parameters (tolerances absorb reduction-order noise)."""
    m_off, p_off = run_one_step(make_bundle(mesh3, mode=mode,
                                            prefetch_depth=0))
    m_on, p_on = run_one_step(make_bundle(mesh3, mode=mode,
                                          prefetch_depth=1))
    np.testing.assert_allclose(m_on["loss"], m_off["loss"], rtol=1e-4)
    np.testing.assert_allclose(m_on["grad_norm"], m_off["grad_norm"],
                               rtol=1e-3)
    for a, b in zip(p_off, p_on):
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-3)


def test_prefetch_depth_k_equivalence(mesh3):
    """Deepening the ring buffer (k=2, k > num layer groups) must not
    change the math either: train loss and updated params match the
    depth-1 schedule."""
    m_1, p_1 = run_one_step(make_bundle(mesh3, mode="fcdp",
                                        prefetch_depth=1))
    for depth in (2, 7):          # 7 > num_layers: the scheduler clamps
        m_k, p_k = run_one_step(make_bundle(mesh3, mode="fcdp",
                                            prefetch_depth=depth))
        np.testing.assert_allclose(m_k["loss"], m_1["loss"], rtol=1e-4)
        np.testing.assert_allclose(m_k["grad_norm"], m_1["grad_norm"],
                                   rtol=1e-3)
        for a, b in zip(p_1, p_k):
            np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-3)


def test_hier_step_matches_zero3(mesh3):
    """Golden run for the hier strategy: one training step produces the
    same loss/grad norm/updated params as zero3 (identical math, only
    the storage/opt layouts and reduce schedule differ)."""
    m_z, p_z = run_one_step(make_bundle(mesh3, mode="zero3"))
    m_h, p_h = run_one_step(make_bundle(mesh3, mode="hier"))
    np.testing.assert_allclose(m_h["loss"], m_z["loss"], rtol=1e-4)
    np.testing.assert_allclose(m_h["grad_norm"], m_z["grad_norm"],
                               rtol=1e-3)
    for a, b in zip(p_z, p_h):
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-3)


def _collect(bundle):
    from repro.launch.roofline import collect_collectives
    step = bundle.make_train_step()
    closed = step.trace(*bundle.train_input_sds()).jaxpr
    sizes = {a: bundle.mi.size(a) for a in bundle.mi.axis_names}
    return collect_collectives(closed, sizes)


def test_prefetch_comm_structure(mesh3):
    """fcdp already re-runs only stage 2 in the backward, so prefetch
    must leave its total DCN all-gather volume unchanged at EVERY ring
    depth (the schedule moves bytes earlier, it does not add any); the
    gradient reduce-scatter volume is identical too. MiCS is untouched
    entirely."""
    fc_off = _collect(make_bundle(mesh3, mode="fcdp",
                              prefetch_depth=0))
    for depth in (1, 2):
        fc_on = _collect(make_bundle(mesh3, mode="fcdp",
                                     prefetch_depth=depth))
        np.testing.assert_allclose(
            fc_on.by_op_axis.get("all_gather/pod", 0),
            fc_off.by_op_axis.get("all_gather/pod", 0), rtol=1e-6)
        np.testing.assert_allclose(
            fc_on.by_op.get("psum_scatter", 0),
            fc_off.by_op.get("psum_scatter", 0), rtol=1e-6)

    mi_off = _collect(make_bundle(mesh3, mode="mics", prefetch_depth=0))
    mi_on = _collect(make_bundle(mesh3, mode="mics", prefetch_depth=1))
    assert mi_on.by_op_axis.get("all_gather/pod", 0) == 0
    np.testing.assert_allclose(mi_on.dcn_bytes, mi_off.dcn_bytes, rtol=1e-6)
    np.testing.assert_allclose(mi_on.ici_bytes, mi_off.ici_bytes, rtol=1e-6)


def test_prefetch_roofline_overlap_visibility():
    """The roofline model credits prefetch with the stage-1 DCN AG
    overlap and leaves non-prefetch reports unchanged."""
    from repro.launch.roofline import CollectiveStats, roofline_report
    stats = CollectiveStats()
    stats.add("all_gather", "pod", 4e9, is_dcn=True)
    stats.add("all_gather", "data", 8e9, is_dcn=False)
    rep_off = roofline_report(1e15, 1e12, stats, DENSE, CELL, 8,
                              prefetch=False)
    rep_on = roofline_report(1e15, 1e12, stats, DENSE, CELL, 8,
                             prefetch=True)
    assert rep_off["prefetch"]["overlapped_dcn_bytes_per_chip"] == 0
    assert rep_off["prefetch"]["collective_exposed_s"] == pytest.approx(
        rep_off["collective_s"])
    assert rep_on["prefetch"]["overlapped_dcn_bytes_per_chip"] == 4e9
    assert (rep_on["prefetch"]["collective_exposed_s"]
            < rep_on["collective_s"])
    # overlap is capped by the compute term
    assert rep_on["prefetch"]["overlapped_s"] <= rep_on["compute_s"] + 1e-12
