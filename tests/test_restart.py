"""Checkpoint/restart + elastic tests (the restart loop's first
coverage): the flush_fn hook ordering, restart-counter reset after a
clean checkpoint interval, mid-pipeline checkpoint round-trip
bit-exactness (the cross-step carry rides the checkpoint), elastic
downscale with carry invalidation + re-prime, remesh device slicing,
and FailureInjector crash/resume parity through the real launch driver
on both the fused and piped schedules."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                ShapeCell, SystemConfig)
from repro.core.engine import StepBundle
from repro.launch.mesh import make_mesh
from repro.optim.adamw import init_opt_state
from repro.runtime.elastic import mesh_meta, remesh, reshard_state
from repro.runtime.fault_tolerance import (FailureInjector,
                                           run_with_restarts)

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
CELL = ShapeCell("t", "train", 64, 8)


def make_bundle(mesh, **sys_kw):
    sysd = dict(mode="fcdp", min_shard_size=8, async_grad_reduce=True,
                cross_step_pipeline=True)
    sysd.update(sys_kw)
    run = RunConfig(model=DENSE, shape=CELL, system=SystemConfig(**sysd),
                    optimizer=OptimizerConfig(total_steps=8, warmup_steps=2,
                                              lr=1e-3),
                    microbatch=2)
    return StepBundle(run, mesh)


def make_batches(n, vocab=256):
    out = []
    for s in range(n):
        rng = np.random.default_rng(s)
        out.append({"ids": jnp.asarray(
                        rng.integers(1, vocab, (CELL.global_batch,
                                                CELL.seq_len)), jnp.int32),
                    "labels": jnp.asarray(
                        rng.integers(1, vocab, (CELL.global_batch,
                                                CELL.seq_len)), jnp.int32),
                    "mask": jnp.ones((CELL.global_batch, CELL.seq_len),
                                     bool)})
    return out


def _init(bundle):
    params = bundle.init_all_params(seed=0)
    tp, fp = bundle.split(params)
    opt = jax.jit(functools.partial(
        init_opt_state, sys=bundle.run.system))(tp)
    return tp, fp, opt


class PipedRunner:
    """Minimal stand-in for launch.train.RunState's prime/piped/flush
    driving, operating on explicit batches."""

    def __init__(self, bundle):
        self.b = bundle
        self.tp, self.fp, self.opt = _init(bundle)
        self.prime = bundle.make_train_prime() if bundle.cross_step else None
        self.step = bundle.make_train_step()
        self.flush = bundle.make_train_flush() if bundle.cross_step else None
        self.carry = None
        self.losses = {}

    def run(self, batches, start=0):
        for i, batch in enumerate(batches):
            if not self.b.cross_step:
                self.tp, self.opt, m = self.step(self.tp, self.fp, self.opt,
                                                 batch)
            elif self.carry is None:
                self.carry, m = self.prime(self.tp, self.fp, self.opt, batch)
            else:
                self.tp, self.opt, self.carry, m = self.step(
                    self.tp, self.fp, self.opt, self.carry, batch)
            self.losses[start + i] = float(m["loss"])
        return self

    def drain(self):
        if self.carry is not None:
            self.tp, self.opt, _ = self.flush(self.tp, self.opt, self.carry)
            self.carry = None
        return self

    def state_tree(self):
        t = {"params": self.tp, "opt": self.opt}
        if self.carry is not None:
            t["carry"] = self.carry
        return t

    def load(self, state):
        self.tp, self.opt = state["params"], state["opt"]
        self.carry = state.get("carry")

    def params_np(self):
        return [np.asarray(x, np.float32) for x in self.tp]


# ---------------------------------------------------------------------------
# run_with_restarts unit behavior
# ---------------------------------------------------------------------------

def test_flush_fn_runs_before_restore_on_failure():
    events = []
    inj = FailureInjector(fail_at_steps=(2,))

    def step_fn(step):
        inj.maybe_fail(step)
        events.append(("step", step))

    def save(step):
        events.append(("save", step))

    def restore():
        events.append(("restore",))
        return 0

    def flush():
        events.append(("flush",))

    res = run_with_restarts(4, step_fn, save, restore, checkpoint_every=10,
                            flush_fn=flush)
    assert res["final_step"] == 4 and res["restarts"] == 1
    i = events.index(("flush",))
    assert events[i - 1] == ("step", 1)        # failure interrupted step 2
    assert events[i + 1] == ("restore",)       # flush strictly precedes


def test_flush_fn_failure_is_swallowed():
    inj = FailureInjector(fail_at_steps=(1,))

    def step_fn(step):
        inj.maybe_fail(step)

    def flush():
        raise RuntimeError("carry buffers were donated")

    res = run_with_restarts(3, step_fn, lambda s: None, lambda: 0,
                            checkpoint_every=10, flush_fn=flush)
    assert res["final_step"] == 3


def test_restart_counter_resets_after_clean_interval():
    """The satellite bug: a monotone lifetime counter kills a long run
    with sparse transient failures. After a full clean checkpoint
    interval the consecutive counter must reset."""
    ckpt = {"step": 0}

    def save(step):
        ckpt["step"] = step

    def restore():
        return ckpt["step"]

    # one transient failure per interval, 5 intervals: lifetime failures
    # (5) exceed max_restarts (2) but never consecutively
    inj = FailureInjector(fail_at_steps=(1, 11, 21, 31, 41))

    def step_fn(step):
        inj.maybe_fail(step)

    res = run_with_restarts(50, step_fn, save, restore, checkpoint_every=5,
                            max_restarts=2)
    assert res["final_step"] == 50
    assert res["restarts"] == 5                # lifetime total, reported
    assert res["consecutive_restarts"] == 0

    # genuinely consecutive failures still trip the limit
    class AlwaysFail(Exception):
        pass

    def bad_step(step):
        raise AlwaysFail()

    with pytest.raises(AlwaysFail):
        run_with_restarts(10, bad_step, lambda s: None, lambda: 0,
                          checkpoint_every=5, max_restarts=2)


# ---------------------------------------------------------------------------
# mid-pipeline checkpoint round-trip (same mesh) -- the tentpole
# ---------------------------------------------------------------------------

def test_mid_pipeline_checkpoint_roundtrip_bit_exact(tmp_path, mesh3):
    """A checkpoint taken mid-pipeline (carry section riding the
    manifest) restored into a FRESH bundle resumes the piped schedule
    with final losses and params bit-identical to an uninterrupted run
    -- the acceptance criterion's same-mesh leg."""
    batches = make_batches(6)
    ref = PipedRunner(make_bundle(mesh3)).run(batches).drain()

    a = PipedRunner(make_bundle(mesh3)).run(batches[:4])
    assert a.carry is not None                     # mid-pipeline
    ck = Checkpointer(str(tmp_path))
    ck.save(4, a.state_tree(), blocking=True, meta=mesh_meta(mesh3))
    man = ck.manifest(4)
    assert any(l["section"] == "carry" for l in man["leaves"])
    assert man["meta"]["mesh"] == {"shape": [2, 2, 2],
                                   "axes": ["pod", "data", "model"]}

    # "new process": fresh bundle + fresh state, restore, continue
    b2 = make_bundle(mesh3)
    r = PipedRunner(b2)
    state, invalidated = reshard_state(
        ck, 4, b2, {"params": r.tp, "opt": r.opt})
    assert not invalidated and state.get("carry") is not None
    r.load(state)
    r.run(batches[4:], start=4).drain()
    assert {k: r.losses[k] for k in (4, 5)} == \
        {k: ref.losses[k] for k in (4, 5)}
    for x, y in zip(ref.params_np(), r.params_np()):
        np.testing.assert_array_equal(x, y)


def test_crash_between_checkpoints_replays_bit_exact(tmp_path, mesh3):
    """Crash at a step past the last checkpoint: restore + replay of the
    intervening steps lands bit-identically on the uninterrupted
    trajectory (deterministic data keyed by step)."""
    batches = make_batches(6)
    ref = PipedRunner(make_bundle(mesh3)).run(batches).drain()

    a = PipedRunner(make_bundle(mesh3)).run(batches[:3])
    ck = Checkpointer(str(tmp_path))
    ck.save(3, a.state_tree(), blocking=True, meta=mesh_meta(mesh3))
    a.run(batches[3:5], start=3)      # steps 3,4 run, then the crash

    b2 = make_bundle(mesh3)
    r = PipedRunner(b2)
    state, invalidated = reshard_state(ck, 3, b2,
                                       {"params": r.tp, "opt": r.opt})
    assert not invalidated
    r.load(state)
    r.run(batches[3:], start=3).drain()
    assert r.losses[5] == ref.losses[5]
    for x, y in zip(ref.params_np(), r.params_np()):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# elastic: carry invalidation on mesh change + re-prime
# ---------------------------------------------------------------------------

def test_elastic_downscale_invalidates_carry_and_reprimes(tmp_path, mesh3):
    """Pod-internal downscale (2,2,2) -> (2,1,2): the carry's leading
    partial dims are mesh-shaped, so the restore must invalidate it and
    the driver re-runs the last step to re-prime -- never device_put
    stale partials. Restored params/opt are bit-identical to the saved
    ones; the resumed trajectory tracks the uninterrupted run (reduction
    order shifts across meshes, so allclose rather than bit-equal)."""
    batches = make_batches(6)
    ref = PipedRunner(make_bundle(mesh3)).run(batches).drain()

    a = PipedRunner(make_bundle(mesh3)).run(batches[:4])
    ck = Checkpointer(str(tmp_path))
    ck.save(4, a.state_tree(), blocking=True, meta=mesh_meta(mesh3))

    small = make_mesh((2, 1, 2), ("pod", "data", "model"),
                      devices=jax.devices()[:4])
    b2 = make_bundle(small)
    assert b2.cross_step                     # pipeline still live
    r = PipedRunner(b2)
    state, invalidated = reshard_state(ck, 4, b2,
                                       {"params": r.tp, "opt": r.opt})
    assert invalidated and "carry" not in state
    r.load(state)
    # restored params/opt are the saved global arrays, bit-exact
    for x, y in zip(a.params_np(), r.params_np()):
        np.testing.assert_array_equal(x, y)
    # the driver contract: resume at saved_step - 1 -> step 3 re-primes
    # (rebuilding the carry the mesh change destroyed), 4..5 pipe
    r.run(batches[3:], start=3).drain()
    assert r.carry is None
    np.testing.assert_allclose(
        [r.losses[k] for k in (4, 5)],
        [ref.losses[k] for k in (4, 5)], rtol=3e-4)
    # params are bf16: cross-mesh drift lands on neighbouring ulps
    # (one ulp is ~0.8% relative), so the bound is quantization-aware
    for x, y in zip(ref.params_np(), r.params_np()):
        np.testing.assert_allclose(x, y, rtol=2e-2, atol=3e-4)


def test_restore_with_pipeline_off_drops_carry(tmp_path, mesh3):
    """cross_step_pipeline off at restore: the checkpoint's carry
    section must be dropped explicitly (and the driver re-runs the last
    step under the fused schedule) instead of mis-assigning leaves."""
    batches = make_batches(5)
    a = PipedRunner(make_bundle(mesh3)).run(batches[:4])
    ck = Checkpointer(str(tmp_path))
    ck.save(4, a.state_tree(), blocking=True, meta=mesh_meta(mesh3))

    b2 = make_bundle(mesh3, cross_step_pipeline=False,
                     async_grad_reduce=True)
    assert not b2.cross_step
    r = PipedRunner(b2)
    state, invalidated = reshard_state(ck, 4, b2,
                                       {"params": r.tp, "opt": r.opt})
    assert invalidated and "carry" not in state
    r.load(state)
    # re-run step 3 fused: its update (held only by the dropped carry)
    # is re-derived, then step 4 continues -- nothing silently lost
    r.run(batches[3:], start=3)
    ref = PipedRunner(make_bundle(mesh3)).run(batches).drain()
    for x, y in zip(ref.params_np(), r.params_np()):
        np.testing.assert_array_equal(x, y)


def test_no_pod_downscale_also_invalidates(tmp_path, mesh3, mesh2):
    """Downscale that loses the pod axis entirely: the pipeline cannot
    run at all on the new mesh -- carry dropped, fused resume."""
    batches = make_batches(4)
    a = PipedRunner(make_bundle(mesh3)).run(batches[:3])
    ck = Checkpointer(str(tmp_path))
    ck.save(3, a.state_tree(), blocking=True, meta=mesh_meta(mesh3))
    b2 = make_bundle(mesh2)
    assert not b2.cross_step
    r = PipedRunner(b2)
    state, invalidated = reshard_state(ck, 3, b2,
                                       {"params": r.tp, "opt": r.opt})
    assert invalidated and "carry" not in state
    r.load(state)
    r.run(batches[2:], start=2)
    assert all(np.isfinite(v) for v in r.losses.values())


def test_remesh_uses_only_surviving_devices():
    """The satellite bug: remesh computed the used-device count and then
    dropped it, so make_mesh saw every visible device even when the
    surviving shape covers fewer."""
    m = remesh(4, tp=2)
    assert m.axis_names == ("data", "model")
    assert dict(m.shape) == {"data": 2, "model": 2}
    assert m.devices.size == 4
    assert list(m.devices.flat) == jax.devices()[:4]


# ---------------------------------------------------------------------------
# FailureInjector crash/resume parity through the real launch driver
# ---------------------------------------------------------------------------

def _drive(tmp_path, tag, steps, extra):
    from repro.launch.train import main
    argv = ["--arch", "gemma-2b", "--smoke", "--multi-pod",
            "--steps", str(steps), "--batch", "8", "--seq-len", "64",
            "--lr", "1e-3", "--ckpt-dir", str(tmp_path / tag),
            "--ckpt-every", "3"] + extra
    st = main(argv)
    per_step = {}
    for row in st.metrics_log:          # last occurrence wins (replays)
        if "step" in row:
            per_step[row["step"]] = row["loss"]
    return per_step, [np.asarray(x, np.float32) for x in st.train_p]


@pytest.mark.parametrize("schedule,flags", [
    ("fused", ["--microbatch", "2"]),
    ("piped", ["--microbatch", "2", "--async-grad-reduce",
               "--cross-step-pipeline"]),
])
def test_driver_crash_resume_parity(tmp_path, schedule, flags):
    """The acceptance criterion end-to-end: a run killed at an arbitrary
    (piped) step by the FailureInjector and restarted from the last
    checkpoint produces bit-identical per-step losses and final params
    to an uninterrupted run -- on the fused AND the cross-step
    schedules. Step 5 sits past the step-3 checkpoint, so the restart
    replays steps 3..4 before continuing."""
    clean_losses, clean_params = _drive(tmp_path, f"{schedule}-clean", 7,
                                        flags)
    crash_losses, crash_params = _drive(tmp_path, f"{schedule}-crash", 7,
                                        flags + ["--fail-at", "5"])
    assert crash_losses == clean_losses
    for x, y in zip(clean_params, crash_params):
        np.testing.assert_array_equal(x, y)
