"""Substrate tests: checkpoint roundtrip + elastic resharding, data
pipeline determinism, fault-tolerance driver, gradient compression,
partition invariants (hypothesis property tests)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (MambaConfig, ModelConfig, MoEConfig,
                                OptimizerConfig, RWKVConfig, RunConfig,
                                ShapeCell, SystemConfig)
from repro.core.engine import StepBundle
from repro.optim.adamw import init_opt_state

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
CELL = ShapeCell("t", "train", 64, 8)


def _bundle(mesh, **sys_kw):
    sysd = dict(mode="fcdp", min_shard_size=8)
    sysd.update(sys_kw)
    run = RunConfig(model=DENSE, shape=CELL, system=SystemConfig(**sysd),
                    optimizer=OptimizerConfig(total_steps=8, warmup_steps=2))
    return StepBundle(run, mesh)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, mesh3):
    from repro.checkpoint.checkpointer import Checkpointer
    b = _bundle(mesh3)
    params = b.init_all_params(seed=0)
    tp, fp = b.split(params)
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(7, {"params": tp}, blocking=True)
    assert ck.latest_step() == 7
    restored = ck.restore(7, {"params": tp})
    for a, c in zip(tp, restored["params"]):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(c, np.float32))


def test_checkpoint_gc_and_async(tmp_path, mesh3):
    from repro.checkpoint.checkpointer import Checkpointer
    b = _bundle(mesh3)
    tp, _ = b.split(b.init_all_params(seed=0))
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3):
        ck.save(step, {"params": tp}, blocking=False)
    ck.wait()
    assert ck.all_steps() == [2, 3]


def test_elastic_reshard_across_meshes(tmp_path, mesh3, mesh2):
    """A checkpoint written on the 3-axis (multi-pod) mesh restores onto
    the 2-axis mesh with identical values -- the pod-loss recovery path."""
    from repro.checkpoint.checkpointer import Checkpointer
    from jax.sharding import NamedSharding
    b3 = _bundle(mesh3)
    tp3, _ = b3.split(b3.init_all_params(seed=0))
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": tp3}, blocking=True)

    b2 = _bundle(mesh2)
    shardings = {"params": [NamedSharding(b2.mesh, b2.leaf_specs[i])
                            for i in b2.train_idx]}
    restored = ck.restore(1, {"params": tp3}, shardings=shardings)
    for a, c in zip(tp3, restored["params"]):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(c, np.float32))
    # and the restored params actually run a step on the new mesh
    fp2: list = []
    opt = jax.jit(functools.partial(
        init_opt_state, sys=b2.run.system))(restored["params"])
    batch = {"ids": jnp.ones((8, 64), jnp.int32),
             "labels": jnp.ones((8, 64), jnp.int32),
             "mask": jnp.ones((8, 64), bool)}
    tp_new, opt, m = b2.make_train_step()(restored["params"], fp2, opt, batch)
    assert np.isfinite(float(m["loss"]))


def test_surviving_mesh_shapes():
    from repro.runtime.elastic import surviving_mesh_shape
    assert surviving_mesh_shape(512, 16) == ((2, 16, 16),
                                             ("pod", "data", "model"))
    assert surviving_mesh_shape(256, 16) == ((16, 16), ("data", "model"))
    assert surviving_mesh_shape(128, 16) == ((8, 16), ("data", "model"))
    assert surviving_mesh_shape(8, 2) == ((4, 2), ("data", "model"))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism():
    from repro.data.pipeline import DataConfig, SyntheticPackedLM
    ds = SyntheticPackedLM(DENSE, CELL, DataConfig(seed=3))
    b1 = ds.batch_np(step=5)
    b2 = ds.batch_np(step=5)
    np.testing.assert_array_equal(b1["ids"], b2["ids"])
    b3 = ds.batch_np(step=6)
    assert not np.array_equal(b1["ids"], b3["ids"])
    assert b1["ids"].shape == (CELL.global_batch, CELL.seq_len)
    assert (b1["ids"] < DENSE.vocab_size).all()
    assert b1["mask"].dtype == np.bool_


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_restart_driver_recovers_from_failures():
    from repro.runtime.fault_tolerance import (FailureInjector,
                                               StragglerMonitor,
                                               run_with_restarts)
    state = {"x": 0.0, "ckpt": (0, 0.0)}
    inj = FailureInjector(fail_at_steps=(3, 7))

    def step_fn(step):
        inj.maybe_fail(step)
        state["x"] += 1.0

    def save(step):
        state["ckpt"] = (step, state["x"])

    def restore():
        step, x = state["ckpt"]
        state["x"] = x
        return step

    mon = StragglerMonitor(min_samples=2)
    res = run_with_restarts(10, step_fn, save, restore, checkpoint_every=2,
                            monitor=mon)
    assert res["final_step"] == 10
    assert res["restarts"] == 2
    assert state["x"] == 10.0      # no lost or double-applied steps


def test_straggler_monitor_flags_outlier():
    from repro.runtime.fault_tolerance import StragglerMonitor
    mon = StragglerMonitor(min_samples=5, z_threshold=3.0)
    for _ in range(20):
        mon.record(0.1 + np.random.default_rng(1).normal(0, 0.001))
    assert mon.record(5.0) is True
    assert mon.summary()["n_flagged"] == 1


def test_heartbeat_detects_hang():
    import time
    from repro.runtime.fault_tolerance import HeartbeatMonitor
    hb = HeartbeatMonitor(timeout_s=0.2).start()
    hb.beat()
    time.sleep(0.5)
    assert hb.hung
    hb.stop()


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_pod_grad_compression_close_to_exact(mesh3):
    """Training with int8 DCN gradient compression stays close to the
    uncompressed run for one step."""
    outs = {}
    for gc in ("none", "int8_pod"):
        b = _bundle(mesh3, grad_compress=gc)
        params = b.init_all_params(seed=0)
        tp, fp = b.split(params)
        opt = jax.jit(functools.partial(
            init_opt_state, sys=b.run.system))(tp)
        batch = {"ids": jnp.ones((8, 64), jnp.int32) * 3,
                 "labels": jnp.ones((8, 64), jnp.int32) * 5,
                 "mask": jnp.ones((8, 64), bool)}
        tp1, opt, m = b.make_train_step()(tp, fp, opt, batch)
        outs[gc] = (float(m["loss"]), float(m["grad_norm"]))
    l0, g0 = outs["none"]
    l1, g1 = outs["int8_pod"]
    assert abs(l0 - l1) < 1e-4          # fwd identical
    assert abs(g0 - g1) / g0 < 0.05     # int8 grads within 5%


def test_int8_activation_allreduce_training_quality(mesh3):
    """int8 TP activation all-reduce (fwd f-pair + bwd g-bar): training
    loss must track the exact bf16 run closely (the §Perf 2x iteration)."""
    outs = {}
    batch = {"ids": jnp.ones((8, 64), jnp.int32) * 3,
             "labels": jnp.ones((8, 64), jnp.int32) * 5,
             "mask": jnp.ones((8, 64), bool)}
    for ap in ("bf16", "int8"):
        b = _bundle(mesh3, act_psum=ap)
        params = b.init_all_params(seed=0)
        tp, fp = b.split(params)
        opt = jax.jit(functools.partial(
            init_opt_state, sys=b.run.system))(tp)
        step = b.make_train_step()
        losses = []
        for _ in range(3):
            tp, opt, m = step(tp, fp, opt, batch)
            losses.append(float(m["loss"]))
        outs[ap] = losses
    # per-step relative tracking: blockwise-quant noise compounds over
    # steps (and backend reduction order shifts it), so bound the
    # relative drift rather than an absolute gap
    for a, c in zip(outs["bf16"], outs["int8"]):
        assert abs(a - c) / a < 0.08, (outs["bf16"], outs["int8"])
    assert outs["int8"][-1] < outs["int8"][0], outs["int8"]


def test_int8_allreduce_unit(mesh3, rng):
    """int8_psum matches exact psum within blockwise-quant error."""
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.act_compress import int8_psum

    def body(x):
        exact = jax.lax.psum(x, "model")
        approx = int8_psum(x, "model")
        return exact, approx

    x = jnp.asarray(rng.normal(0, 1, (8, 64, 64)), jnp.float32)
    fn = shard_map(body, mesh=mesh3, in_specs=(P("model"),),
                   out_specs=(P("model"), P("model")), check_vma=True)
    exact, approx = fn(x)
    e, a = np.asarray(exact), np.asarray(approx)
    rel = np.abs(e - a) / (np.abs(e).max() + 1e-9)
    assert rel.max() < 0.02, rel.max()


# ---------------------------------------------------------------------------
# partition invariants (hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYP:
    from repro.core.partition import ParamDef, storage_spec
    from repro.core.fcdp import make_gather_plan
    from repro.launch.mesh import make_mesh

    @given(st.integers(1, 8), st.integers(1, 8), st.booleans(),
           st.sampled_from(["zero3", "zeropp", "fcdp", "mics"]))
    @settings(max_examples=40, deadline=None)
    def test_partition_gather_consistency(mult_a, mult_b, frozen, mode):
        """Invariant: the gather plan reconstructs exactly the dims the
        storage spec sharded -- for every (shape x mode x frozen) combo."""
        import jax as _jax
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = (4 * mult_a, 2 * mult_b)
        d = ParamDef(shape, ("fsdp", "tp"), frozen=frozen)
        spec = storage_spec(d, mesh, mode)
        plan = make_gather_plan(d, mesh, mode)
        fsdp_entry = spec[0]
        if plan.is_gathered:
            got = set(plan.inter_axes) | set(plan.intra_axes)
            want = set(fsdp_entry if isinstance(fsdp_entry, tuple)
                       else (fsdp_entry,))
            assert got == want, (spec, plan)
            # cache boundary: stage-1 iff a DCN axis exists
            assert plan.cache_after == (1 if "pod" in got else 2)
        else:
            assert fsdp_entry is None

    @given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_replication_factor_invariant(a, b, c):
        """sum over devices of (elements/replication) == global elements."""
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = (4 * a, 2 * b, 4 * c)
        d = ParamDef(shape, ("fsdp", None, "tp"))
        spec = storage_spec(d, mesh, "fcdp")
        used = set()
        for e in spec:
            if e is None:
                continue
            used.update(e if isinstance(e, tuple) else (e,))
        rep = 1
        for ax, n in (("pod", 2), ("data", 2), ("model", 2)):
            if ax not in used:
                rep *= n
        n_dev = 8
        shard_elems = d.size() / (n_dev / rep)
        assert shard_elems * n_dev / rep == d.size()
